"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json.  Usage:
    PYTHONPATH=src python experiments/make_report.py > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os

DIR = os.path.join(os.path.dirname(__file__), "dryrun")
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(pattern):
    out = {}
    for f in sorted(glob.glob(os.path.join(DIR, pattern))):
        d = json.load(open(f))
        key = (d["arch"], d["shape"], d["mesh"], d.get("tag", ""))
        out[key] = d
    return out


def fmt_row(d):
    r = d["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {r['compute_ms']:.2f} "
            f"| {r['memory_ms']:.2f} | {r['collective_ms']:.2f} "
            f"| **{r['dominant']}** | {r['bound_step_ms']:.2f} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_at_bound']:.4f} |")


def main():
    allruns = load("*.json")
    # --- roofline baseline table (single-pod, unrolled accounting) ---
    print("### §Roofline — per-(arch × shape) baseline, 16x16 mesh, "
          "unrolled-layer accounting\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | bound ms | MODEL/HLO flops | MFU@bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    for shape in SHAPES:
        for (a, s, m, tag), d in sorted(allruns.items()):
            if s == shape and m == "16x16" and tag == "unroll":
                print(fmt_row(d))
    print()
    # --- scan-accounting baselines (compile-proof artifacts) ---
    print("### §Dry-run — all 80 (arch × shape × mesh) lower+compile "
          "(scan accounting)\n")
    print("| arch | shape | mesh | compile s | arg bytes/chip | "
          "temp bytes/chip | collective MB/chip | dominant |")
    print("|---|---|---|---|---|---|---|---|")
    for shape in SHAPES:
        for (a, s, m, tag), d in sorted(allruns.items(),
                                        key=lambda kv: (kv[0][1], kv[0][2],
                                                        kv[0][0])):
            if s != shape or tag:
                continue
            mem = d.get("memory_analysis", {})
            arg = mem.get("argument_size_in_bytes") or 0
            tmp = mem.get("temp_size_in_bytes") or 0
            print(f"| {a} | {s} | {m} | {d['compile_s']:.1f} "
                  f"| {arg/1e9:.2f}G | {tmp/1e9:.2f}G "
                  f"| {d['collective_bytes_per_chip']/1e6:.0f} "
                  f"| {d['roofline']['dominant']} |")
    print()
    # --- perf iterations ---
    print("### §Perf — hillclimb measurements (tagged runs)\n")
    print("| arch | shape | tag | compute ms | memory ms | collective ms | "
          "bound ms |")
    print("|---|---|---|---|---|---|---|")
    for (a, s, m, tag), d in sorted(allruns.items()):
        if not tag or tag == "unroll" or m != "16x16":
            continue
        r = d["roofline"]
        print(f"| {a} | {s} | {tag} | {r['compute_ms']:.2f} "
              f"| {r['memory_ms']:.2f} | {r['collective_ms']:.2f} "
              f"| {r['bound_step_ms']:.2f} |")


if __name__ == "__main__":
    main()
