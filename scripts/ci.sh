#!/usr/bin/env bash
# Minimal CI: fast lane by default (seconds, not minutes); pass --full for
# the whole tier-1 suite (~5 min).
#   scripts/ci.sh           -> pytest -m "not slow"
#   scripts/ci.sh --full    -> full suite
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -q
else
    python -m pytest -q -m "not slow"
fi
