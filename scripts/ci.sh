#!/usr/bin/env bash
# Minimal CI: fast lane by default (seconds, not minutes); pass --full for
# the whole tier-1 suite (~5 min); pass bench-smoke for a tiny-scale run of
# the perf-trajectory benchmarks plus a schema check on their JSON outputs
# (so the perf plumbing can't silently rot); pass chaos-smoke for a
# quick-scale fault-injection run (storage faults + stalls + deadlines)
# that fails on any unhandled exception, unaccounted fault, or recall
# loss at the 10%-fault arm; pass pipeline-smoke for a quick-scale staged
# pipeline run that fails if pipelined throughput drops below sequential
# or pipelined answers drift from the sequential path; pass tenant-smoke
# for a quick-scale multi-tenant run that fails if the shared substrate is
# slower than per-tenant silos or multi-tenancy perturbs single-tenant
# results bitwise; pass pq-smoke for a quick-scale disk-native PQ memmap
# tier run that fails if PQ recall drops below 0.95 of fp32, PQ bytes
# reach the int8 tier, or the byte reduction falls under 8x; pass
# durability-smoke for a quick-scale crash-recovery run that fails if
# post-recovery recall is not exactly 1.0x pre-crash, recovery is slower
# than the cold re-embed rebuild, the WAL steady-state overhead tops 10%,
# or any crashpoint arm leaves a hybrid (neither-pre-nor-post-op) state.
#   scripts/ci.sh                 -> pytest -m "not slow"
#   scripts/ci.sh --full          -> full suite
#   scripts/ci.sh bench-smoke     -> quick benchmarks + BENCH_*.json key check
#   scripts/ci.sh chaos-smoke     -> quick fault-tolerance bench + schema check
#   scripts/ci.sh pipeline-smoke  -> quick pipeline-throughput bench + checks
#   scripts/ci.sh tenant-smoke    -> quick multi-tenant bench + schema check
#   scripts/ci.sh pq-smoke        -> quick pq memmap-tier bench + schema check
#   scripts/ci.sh durability-smoke -> quick crash-recovery bench + checks
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -q
elif [[ "${1:-}" == "bench-smoke" ]]; then
    out="$(mktemp -d)"
    trap 'rm -rf "$out"' EXIT
    python -m benchmarks.batched_retrieval --quick \
        --out "$out/BENCH_retrieval.json"
    python -m benchmarks.quantized_tiers --quick \
        --out "$out/BENCH_quantized_tiers.json"
    python -m benchmarks.online_churn --quick \
        --out "$out/BENCH_online_churn.json"
    python -m benchmarks.slab_scoring --quick \
        --out "$out/BENCH_slab_scoring.json"
    python - "$out" <<'PY'
import json, os, sys

out = sys.argv[1]

r = json.load(open(os.path.join(out, "BENCH_retrieval.json")))
for key in ("n_records", "n_queries", "nlist", "k", "configs",
            "batch16_speedup_np8"):
    assert key in r, f"BENCH_retrieval.json missing key: {key}"
assert r["configs"], "BENCH_retrieval.json has no configs"
for cfg, cells in r["configs"].items():
    assert cells, f"config {cfg} has no cells"
    for cell in cells:
        for key in ("nprobe", "batch", "mode", "qps", "speedup",
                    "dedup_rate", "embed_calls"):
            assert key in cell, f"{cfg} cell missing key: {key}"

q = json.load(open(os.path.join(out, "BENCH_quantized_tiers.json")))
for codec in ("fp32", "fp16", "int8"):
    cell = q["codecs"][codec]
    for key in ("recall_at10", "ttft_edge_s", "storage_bytes",
                "reduction", "recall_ratio_vs_fp32"):
        assert key in cell, f"codec {codec} missing key: {key}"
assert q["recall_criterion_met"], "quantized recall fell below 0.95 of fp32"

c = json.load(open(os.path.join(out, "BENCH_online_churn.json")))
for key in ("n_records", "n_queries", "nlist", "k", "nprobe", "gap_mean_s",
            "churn", "recall", "arms", "p99_speedup_sync_over_deferred",
            "criteria"):
    assert key in c, f"BENCH_online_churn.json missing key: {key}"
for key in ("inserts", "removes", "churn_frac"):
    assert key in c["churn"], f"churn block missing key: {key}"
for key in ("churned_at10", "oracle_at10", "ratio"):
    assert key in c["recall"], f"recall block missing key: {key}"
for arm in ("sync", "deferred"):
    cell = c["arms"][arm]
    for key in ("n_query_reqs", "p50_ttft_s", "p99_ttft_s", "mean_ttft_s",
                "maintenance_edge_s", "maintenance_in_stream_s",
                "maintenance_ops"):
        assert key in cell, f"arm {arm} missing key: {key}"
assert c["criteria"]["recall_ratio_ok"], \
    "churned recall fell below 0.99 of the oracle rebuild"
assert c["criteria"]["deferred_p99_lower"], \
    "deferred maintenance did not beat synchronous on p99 TTFT"

s = json.load(open(os.path.join(out, "BENCH_slab_scoring.json")))
for key in ("n_records", "dim", "nlist", "k", "nprobe", "batch", "repeats",
            "unique_rows", "per_query_concat_rows", "dedup_factor",
            "arms", "speedups", "recall", "criteria"):
    assert key in s, f"BENCH_slab_scoring.json missing key: {key}"
for arm in ("per_query_loop", "slab_fp32", "dequant_int8",
            "slab_int8_fused"):
    cell = s["arms"][arm]
    for key in ("scoring_s_per_batch", "qps", "recall_at10"):
        assert key in cell, f"arm {arm} missing key: {key}"
for key in ("slab_vs_loop_batch16", "int8_fused_vs_dequant"):
    assert key in s["speedups"], f"speedups missing key: {key}"
# regression guard: slab batch-16 scoring must never be SLOWER than the
# per-query loop (the full-scale run's recorded target is >= 2x)
assert s["criteria"]["slab_not_slower"], \
    f"slab scoring regressed below the per-query loop " \
    f"({s['speedups']['slab_vs_loop_batch16']:.2f}x)"
# the fused-dequant edge is real but small (~1.1-1.3x) and at --quick
# scale it sits inside a loaded CI box's noise floor, so the smoke lane
# only reports it; the strict >1x criterion is recorded (and met) in the
# repo-root full-scale BENCH_slab_scoring.json
print(f"int8 fused vs dequant-then-score (informational): "
      f"{s['speedups']['int8_fused_vs_dequant']:.2f}x")
assert s["criteria"]["recall_ratio_ok"], \
    "slab recall@10 fell below 0.99 of the per-query loop"

print("bench-smoke OK: BENCH JSON schemas intact")
PY
elif [[ "${1:-}" == "chaos-smoke" ]]; then
    out="$(mktemp -d)"
    trap 'rm -rf "$out"' EXIT
    python -m benchmarks.fault_tolerance --quick \
        --out "$out/BENCH_fault_tolerance.json"
    python - "$out" <<'PY'
import json, os, sys

f = json.load(open(os.path.join(sys.argv[1], "BENCH_fault_tolerance.json")))
for key in ("n_records", "n_queries", "nlist", "k", "nprobe", "slo_s",
            "gap_mean_s", "deadline_s", "prefill_reserve_frac",
            "churn_frac", "arms", "recall_ratio_vs_clean", "criteria"):
    assert key in f, f"BENCH_fault_tolerance.json missing key: {key}"
for arm in ("clean", "f01_stall", "f10_stall", "stall_heavy",
            "stall_heavy_noshed"):
    cell = f["arms"][arm]
    for key in ("n_query_reqs", "p50_ttft_s", "p99_ttft_s", "mean_ttft_s",
                "outcomes", "degradation", "injected", "io_stats",
                "maintenance_quarantined", "unhandled_exceptions",
                "recall_at10"):
        assert key in cell, f"arm {arm} missing key: {key}"
    for key in ("met", "degraded", "missed", "failed"):
        assert key in cell["outcomes"], f"arm {arm} outcomes missing {key}"
    # hard robustness floor: the retrieval stack must absorb every fault
    assert cell["unhandled_exceptions"] == 0, \
        f"arm {arm}: {cell['unhandled_exceptions']} unhandled exceptions"
    st = cell["io_stats"]
    assert (cell["injected"]["injected_total"] == st["failed_attempts"]
            == st["retries"] + st["exhausted"]), \
        f"arm {arm}: injected faults not fully accounted"
ratio = f["recall_ratio_vs_clean"]["f10_stall"]
assert ratio >= 0.99, \
    f"recall under 10% faults fell to {ratio:.3f}x of fault-free"
print("chaos-smoke OK: faults absorbed, accounted, recall preserved")
PY
elif [[ "${1:-}" == "pipeline-smoke" ]]; then
    out="$(mktemp -d)"
    trap 'rm -rf "$out"' EXIT
    python -m benchmarks.pipeline_throughput --quick \
        --out "$out/BENCH_pipeline.json"
    python - "$out" <<'PY'
import json, os, sys

p = json.load(open(os.path.join(sys.argv[1], "BENCH_pipeline.json")))
for key in ("n_records", "n_queries_corpus", "nlist", "dim", "k", "nprobe",
            "slo_s", "batch", "n_batches", "max_new_tokens", "update_frac",
            "n_updates", "sequential", "pipelined", "qps_ratio",
            "hidden_retrieval_fraction", "ids_identical", "recall_at_k",
            "criteria"):
    assert key in p, f"BENCH_pipeline.json missing key: {key}"
for key in ("makespan_s", "qps", "retrieval_s", "decode_s", "maintenance_s"):
    assert key in p["sequential"], f"sequential block missing key: {key}"
for key in ("makespan_s", "final_drain_s", "qps", "trace"):
    assert key in p["pipelined"], f"pipelined block missing key: {key}"
t = p["pipelined"]["trace"]
for key in ("n_batches", "n_queries", "makespan_s", "replans",
            "final_drain_s", "retrieval_busy_s", "decode_busy_s",
            "hidden_retrieval_s", "hidden_retrieval_fraction",
            "bubble_fraction", "maintenance_in_bubbles_s", "stages"):
    assert key in t, f"trace block missing key: {key}"
for stage in ("s1", "s2", "s3", "s4"):
    cell = t["stages"][stage]
    for key in ("busy_s", "n_fired", "maintenance_s", "maintenance_ops",
                "max_queue_depth"):
        assert key in cell, f"stage {stage} missing key: {key}"
# hard floors at quick scale: the pipeline must never be a pessimization
# and must return bit-identical chunk ids to the sequential path; the
# full-scale >=0.90 hidden-retrieval and >=1.5x QPS targets are recorded
# (and met) in the repo-root BENCH_pipeline.json, where steady state has
# room to amortize the first-batch ramp
assert p["criteria"]["pipelined_not_slower"], \
    f"pipelined QPS fell below sequential ({p['qps_ratio']:.2f}x)"
assert p["criteria"]["ids_identical"], \
    "pipelined chunk ids diverged from the sequential path"
print(f"pipeline-smoke OK: {p['qps_ratio']:.2f}x QPS, "
      f"{p['hidden_retrieval_fraction']:.0%} retrieval hidden, "
      f"ids identical")
PY
elif [[ "${1:-}" == "tenant-smoke" ]]; then
    out="$(mktemp -d)"
    trap 'rm -rf "$out"' EXIT
    python -m benchmarks.multi_tenant --quick \
        --out "$out/BENCH_multi_tenant.json"
    python - "$out" <<'PY'
import json, os, sys

m = json.load(open(os.path.join(sys.argv[1], "BENCH_multi_tenant.json")))
for key in ("n_tenants", "n_records_per_tenant", "nlist", "dim", "k",
            "nprobe", "batch", "n_requests", "zipf_a", "cache_total_bytes",
            "tenant_request_counts", "shared", "silo", "qps_ratio",
            "ids_identical", "single_tenant_bitwise", "noisy_neighbor",
            "criteria"):
    assert key in m, f"BENCH_multi_tenant.json missing key: {key}"
for arm in ("shared", "silo"):
    cell = m[arm]
    for key in ("wall_s", "qps", "cache_hit_rate"):
        assert key in cell, f"arm {arm} missing key: {key}"
for arm in ("admission_off", "admission_on"):
    cell = m["noisy_neighbor"][arm]
    for t in ("big", "small"):
        for key in ("n", "n_served", "n_rejected", "p50_ttft_s",
                    "p99_ttft_s", "slo_hit_rate"):
            assert key in cell[t], f"noisy_neighbor {arm}.{t} missing {key}"
# hard floors at quick scale: sharing the substrate must never be a
# pessimization and fusion must not perturb results; the full-scale
# >=1.3x-at->=8-tenants target is recorded (and met) in the repo-root
# BENCH_multi_tenant.json
assert m["criteria"]["shared_not_slower"], \
    f"shared substrate fell below per-tenant silos ({m['qps_ratio']:.2f}x)"
assert m["criteria"]["ids_identical"], \
    "fused multi-tenant chunk ids diverged from the per-tenant silos"
assert m["criteria"]["single_tenant_bitwise"], \
    "one-tenant router drifted from the standalone index"
print(f"tenant-smoke OK: {m['qps_ratio']:.2f}x vs silos at "
      f"{m['n_tenants']} tenants, ids identical, single-tenant bitwise")
PY
elif [[ "${1:-}" == "pq-smoke" ]]; then
    out="$(mktemp -d)"
    trap 'rm -rf "$out"' EXIT
    python -m benchmarks.pq_tier --quick \
        --out "$out/BENCH_pq_tier.json"
    python - "$out" <<'PY'
import json, os, sys

p = json.load(open(os.path.join(sys.argv[1], "BENCH_pq_tier.json")))
for key in ("n_records", "n_queries", "nlist", "k", "pq_m",
            "corpus_fp32_bytes", "index_memory_budget_bytes",
            "corpus_exceeds_budget", "arms", "criteria"):
    assert key in p, f"BENCH_pq_tier.json missing key: {key}"
for arm in ("fp32", "int8", "pq"):
    cell = p["arms"][arm]
    for key in ("mode", "recall_at10", "ttft_edge_s", "storage_bytes",
                "reduction_vs_fp32", "fits_budget", "n_storage_loads",
                "recall_ratio_vs_fp32", "id_overlap_vs_fp32"):
        assert key in cell, f"arm {arm} missing key: {key}"
assert p["corpus_exceeds_budget"], \
    "pq bench lost its premise: corpus fits the resident budget"
assert p["arms"]["pq"]["mode"] == "memmap", "pq arm is not memmap-backed"
pq = p["arms"]["pq"]
assert pq["recall_ratio_vs_fp32"] >= 0.95, \
    f"pq recall fell to {pq['recall_ratio_vs_fp32']:.3f}x of fp32"
assert pq["storage_bytes"] < p["arms"]["int8"]["storage_bytes"], \
    "pq bytes not below the int8 tier"
assert pq["reduction_vs_fp32"] >= 8.0, \
    f"pq byte reduction fell to {pq['reduction_vs_fp32']:.2f}x"
print(f"pq-smoke OK: {pq['recall_ratio_vs_fp32']:.3f}x recall of fp32 at "
      f"{pq['reduction_vs_fp32']:.1f}x fewer bytes from memmap slabs")
PY
elif [[ "${1:-}" == "durability-smoke" ]]; then
    out="$(mktemp -d)"
    trap 'rm -rf "$out"' EXIT
    python -m benchmarks.crash_recovery --quick \
        --out "$out/BENCH_crash_recovery.json"
    python - "$out" <<'PY'
import json, os, sys

c = json.load(open(os.path.join(sys.argv[1], "BENCH_crash_recovery.json")))
for key in ("n_records", "n_queries", "nlist", "k", "nprobe", "slo_s",
            "checkpoint_every", "steady_state", "crashpoints", "criteria"):
    assert key in c, f"BENCH_crash_recovery.json missing key: {key}"
s = c["steady_state"]
for key in ("n_ops", "edge_s_baseline", "wal_edge_s", "wal_overhead_frac",
            "qps_baseline", "qps_wal", "wal_stats", "recall_at10_pre_crash",
            "recall_at10_post_recovery", "recall_ratio", "results_identical",
            "recovery", "cold_rebuild_edge_s", "recovery_speedup_vs_cold"):
    assert key in s, f"steady_state block missing key: {key}"
for key in ("snapshot_lsn", "replayed_records", "torn_bytes", "orphans_gc",
            "healed", "edge_s", "wall_s"):
    assert key in s["recovery"], f"recovery block missing key: {key}"
assert c["crashpoints"], "no crashpoint arms ran"
for point, arm in c["crashpoints"].items():
    for key in ("crashed_at_op", "landed_prefix", "hybrid", "recovery"):
        assert key in arm, f"crashpoint {point} missing key: {key}"
    assert arm["crashed_at_op"] is not None, \
        f"crashpoint {point} never fired"
    # the atomicity contract: pre-op or post-op, never a torn hybrid
    assert not arm["hybrid"], \
        f"crashpoint {point} left a hybrid recovered state"
assert s["recall_ratio"] == 1.0 and s["results_identical"], \
    f"post-recovery answers drifted (ratio {s['recall_ratio']:.3f})"
# at quick scale recovery must at LEAST beat the cold re-embed; the >=5x
# target is recorded (and met) in the repo-root BENCH_crash_recovery.json
assert s["recovery_speedup_vs_cold"] >= 1.0, \
    f"recovery slower than cold rebuild ({s['recovery_speedup_vs_cold']:.2f}x)"
assert s["wal_overhead_frac"] <= 0.10, \
    f"WAL steady-state overhead hit {s['wal_overhead_frac']:.1%} (> 10%)"
print(f"durability-smoke OK: {s['recovery_speedup_vs_cold']:.1f}x faster "
      f"than cold rebuild at {s['wal_overhead_frac']:.1%} WAL overhead, "
      f"answers identical, no hybrid states")
PY
elif [[ -z "${1:-}" ]]; then
    python -m pytest -q -m "not slow"
else
    echo "unknown lane: $1 (expected: no arg, --full, bench-smoke," \
         "chaos-smoke, pipeline-smoke, tenant-smoke, pq-smoke, or" \
         "durability-smoke)" >&2
    exit 2
fi
