"""Online index maintenance (§5.4): insertion, removal, cluster split and
merge — with the SLO-driven storage invariant checked live, plus the
deferred-maintenance mode where mutations enqueue their heavy follow-up
work and a budgeted scheduler drains it between serving steps.

    PYTHONPATH=src python examples/online_update.py
"""
import numpy as np

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset


def show(index, label):
    s = index.stats()
    print(f"[{label}] clusters={s['active_clusters']} chunks={s['ntotal']} "
          f"stored={s['stored_clusters']} "
          f"mem={s['memory_bytes']/1024:.1f}KiB "
          f"storage={s['storage_bytes']/1024:.1f}KiB")


def main():
    ds = generate_dataset(n_records=1000, dim=48, n_topics=32,
                          n_queries=10, seed=1)
    index = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, EdgeCostModel(),
                         slo_s=0.25, split_max_chars=40_000)
    index.build(ds.chunk_ids, ds.texts, nlist=32, embeddings=ds.embeddings)
    show(index, "built")

    # --- insertions: stream new chunks into the nearest clusters ---
    rng = np.random.default_rng(0)
    next_id = 10_000
    for i in range(200):
        base = ds.embeddings[rng.integers(ds.n)]
        emb = base + 0.05 * rng.standard_normal(48)
        emb = (emb / np.linalg.norm(emb)).astype(np.float32)
        text = f"doc-{next_id} " + "new content " * rng.integers(3, 30)
        ds.add_chunk(next_id, text, emb)
        index.insert(next_id, text)
        next_id += 1
    show(index, "after 200 inserts")

    # SLO invariant: stored == (regeneration cost over SLO)
    bad = [c for c in index.clusters
           if c.active and c.stored != (c.gen_latency_est > index.slo_s)]
    print(f"  Alg-1 invariant violations: {len(bad)}")

    # --- removal until clusters merge ---
    victim_cluster = max((c for c in index.clusters if c.active),
                         key=lambda c: c.size)
    n_before = index.nlist
    for cid_ in list(victim_cluster.ids[:-1]):
        index.remove(int(cid_))
    show(index, "after draining one cluster")
    print(f"  first-level entries: {n_before} -> {index.nlist} "
          f"(active {sum(c.active for c in index.clusters)})")

    # retrieval still works
    ids, _, lat = index.search(ds.query_embs[0], 5, 4)
    print(f"  post-update search -> {ids[0].tolist()} "
          f"({lat.retrieval_s*1e3:.0f} ms edge)")

    # --- deferred maintenance: mutations return fast, a budgeted drain
    # runs the queued split/restore work between serving steps ---
    deferred = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, EdgeCostModel(),
                            slo_s=0.25, split_max_chars=40_000,
                            maintenance="deferred")
    deferred.build(ds.chunk_ids, ds.texts, nlist=32,
                   embeddings=ds.embeddings)
    for i in range(100):
        base = ds.embeddings[rng.integers(ds.n)]
        emb = base + 0.05 * rng.standard_normal(48)
        emb = (emb / np.linalg.norm(emb)).astype(np.float32)
        text = f"doc-{next_id} " + "new content " * rng.integers(3, 30)
        ds.add_chunk(next_id, text, emb)
        deferred.insert(next_id, text)
        next_id += 1
    print(f"\n[deferred] {len(deferred.maintenance)} maintenance ops queued "
          f"after 100 inserts (searches stay correct meanwhile)")
    steps = 0
    while len(deferred.maintenance):
        rep = deferred.maintenance.drain(0.25)      # 250 ms budget per step
        steps += 1
        print(f"  drain step {steps}: ran {rep.n_executed} "
              f"(skipped {len(rep.skipped)}) in {rep.edge_s*1e3:.0f} ms "
              f"edge, {rep.remaining} left")
    bad = [c for c in deferred.clusters
           if c.active and c.stored != (c.gen_latency_est > deferred.slo_s)]
    print(f"  Alg-1 invariant violations after quiescence: {len(bad)}")


if __name__ == "__main__":
    main()
