"""Crash-consistent durability quickstart: build an index, churn it with
a write-ahead log attached, kill the "process" mid-flight, and recover —
the recovered index answers bit-identically to the moment before the
crash, without re-embedding the corpus.

Walkthrough:
  1. build a disk-backed EdgeRAG index and attach a Durability handle
     (every insert/remove/update now appends one CRC-framed WAL record;
     snapshots ride along every ``checkpoint_every`` records)
  2. churn: inserts, removes, updates — then record reference answers
  3. crash: drop the index object (simulated power cut; the WAL's torn
     tail, if any, is truncated at recovery)
  4. ``recover()``: newest valid snapshot + WAL-suffix replay, blob
     reconciliation (orphan GC / self-heal), same answers back

    PYTHONPATH=src python examples/crash_recovery_quickstart.py

Runs in well under 30 seconds on a laptop.
"""
import gc
import shutil
import tempfile
import time

import numpy as np

from repro.core import Durability, EdgeCostModel, EdgeRAGIndex, recover
from repro.data import generate_dataset

K, NPROBE = 8, 6


def main():
    t_start = time.perf_counter()
    ds = generate_dataset(n_records=600, dim=32, n_topics=12, n_queries=6,
                          seed=3)
    cost = EdgeCostModel()
    root = tempfile.mkdtemp(prefix="edgerag_durable_")
    try:
        # 1. durable disk-backed index
        index = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, cost,
                             slo_s=0.01, storage_mode="disk",
                             storage_root=root, maintenance="sync")
        index.build(ds.chunk_ids, ds.texts, nlist=20,
                    embeddings=ds.embeddings)
        dur = index.attach_durability(
            Durability(root, cost_model=cost, checkpoint_every=8))
        print(f"[build]   {index.stats()}")

        # 2. churn under the WAL
        for j in range(12):
            ds.add_chunk(10_000 + j, f"fresh durable chunk {j} " * 12)
            index.insert(10_000 + j, f"fresh durable chunk {j} " * 12)
        for cid in ds.chunk_ids[:5]:
            index.remove(int(cid))
        ds.add_chunk(int(ds.chunk_ids[10]), "rewritten chunk " * 20)
        index.update(int(ds.chunk_ids[10]), "rewritten chunk " * 20)
        st = dur.stats()
        print(f"[churn]   {st['wal_records_total']} WAL records, "
              f"{st['snapshots_total']} snapshots, "
              f"{st['wal_bytes']} WAL bytes on disk")
        ref_ids, ref_vals, _ = index.search_batch(ds.query_embs, K, NPROBE)

        # 3. crash: the process dies; only the disk survives
        del index, dur
        gc.collect()
        print("[crash]   index object dropped (simulated power cut)")

        # 4. recover from snapshot + WAL suffix
        index2, report = recover(root, ds.embedder, ds.get_chunks, cost,
                                 storage_mode="disk", slo_s=0.01,
                                 maintenance="sync")
        print(f"[recover] snapshot lsn={report.snapshot_lsn}, "
              f"replayed={report.replayed_records} records, "
              f"healed={report.healed}, orphans_gc={report.orphans_gc}, "
              f"modeled edge cost {report.edge_s*1e3:.1f} ms "
              f"({report.wall_s*1e3:.1f} ms wall)")

        ids, vals, _ = index2.search_batch(ds.query_embs, K, NPROBE)
        assert np.array_equal(ids, ref_ids), "ids drifted after recovery"
        assert np.array_equal(vals, ref_vals), "scores drifted after recovery"
        cold_s = sum(cost.embed_latency(len(t)) for t in ds.get_chunks(
            sorted(set(index2._chunk_cluster))))
        print(f"[verify]  answers bit-identical to pre-crash; recovery was "
              f"{cold_s / max(report.edge_s, 1e-12):.0f}x cheaper than "
              f"re-embedding the corpus "
              f"({time.perf_counter() - t_start:.1f}s total)")
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
