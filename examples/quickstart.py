"""Quickstart: index a few documents with EdgeRAG and retrieve.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import HashingEmbedder, chunk_text

DOCS = {
    "jax": "JAX is a library for array-oriented numerical computation with "
           "automatic differentiation and JIT compilation to XLA. " * 12,
    "rag": "Retrieval augmented generation looks up relevant chunks in a "
           "vector database and feeds them to a language model. " * 12,
    "tpu": "Tensor processing units accelerate matrix multiplication with "
           "a systolic array fed from high bandwidth memory. " * 12,
}


def main():
    embedder = HashingEmbedder(dim=128)
    ids, chunks = [], []
    for doc in DOCS.values():
        for c in chunk_text(doc, chunk_chars=160, overlap_chars=30):
            ids.append(len(ids))
            chunks.append(c)
    store = dict(zip(ids, chunks))

    index = EdgeRAGIndex(
        dim=128,
        embed_fn=embedder,
        get_chunks=lambda ii: [store[i] for i in ii],
        cost_model=EdgeCostModel(),
        slo_s=0.5,
    )
    index.build(ids, chunks, nlist=6)
    print(f"indexed {index.ntotal} chunks in {index.nlist} clusters; "
          f"resident={index.memory_bytes()} B (embeddings pruned)")

    for query in ("how does jit compilation work",
                  "vector database retrieval",
                  "matrix multiply hardware"):
        q_emb = embedder.embed([query])[0]
        rids, scores, lat = index.search(q_emb, k=3, nprobe=3,
                                         query_chars=len(query))
        print(f"\nQ: {query}")
        for rid, s in zip(rids[0], scores[0]):
            if rid >= 0:
                print(f"  [{s:+.3f}] {store[int(rid)][:70]}...")
        print(f"  edge latency: {lat.retrieval_s*1e3:.1f} ms "
              f"(gen={lat.n_generated} cache={lat.n_cache_hits} "
              f"stored={lat.n_storage_loads})")


if __name__ == "__main__":
    main()
