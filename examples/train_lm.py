"""Train a small LM (any assigned architecture) on synthetic data and watch
the loss fall — exercises the same train_step the train_4k dry-run lowers
for the pod.

    PYTHONPATH=src python examples/train_lm.py --arch olmoe-1b-7b --steps 100
(see also: python -m repro.launch.train for the full launcher with
checkpointing)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.model import init_params
from repro.train.train_step import make_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=96)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch).reduced()
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"pattern={cfg.block_pattern}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = train_state_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3,
                                   total_steps=args.steps),
                   donate_argnums=(0,))

    # fixed tiny corpus -> the model must overfit (loss -> small)
    rng = np.random.default_rng(0)
    data = rng.integers(0, cfg.vocab_size, size=(args.batch, args.seq + 1))
    batch = {"tokens": jnp.asarray(data[:, :-1], jnp.int32),
             "labels": jnp.asarray(data[:, 1:], jnp.int32)}
    if cfg.use_mrope:
        pos = jnp.broadcast_to(jnp.arange(args.seq)[None],
                               (args.batch, args.seq))
        batch["positions"] = jnp.broadcast_to(pos[None],
                                              (3, args.batch, args.seq))
    if cfg.embedding_inputs:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, args.seq, cfg.d_model)) * 0.02,
            jnp.float32)
        batch.pop("tokens")

    first = None
    for i in range(args.steps):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"|g|={float(m['grad_norm']):.2f}")
    print(f"\nloss {first:.3f} -> {float(m['loss']):.3f} "
          f"({'OVERFIT OK' if float(m['loss']) < first * 0.7 else 'check'})")


if __name__ == "__main__":
    main()
