"""Pod-sharded retrieval (beyond-paper, DESIGN.md §2): the EdgeRAG
second-level scan distributed over the data axis with an all-gather-of-
candidates merge.  Runs here on 8 forced host devices standing in for the
pod's data axis.

    PYTHONPATH=src python examples/pod_retrieval.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.core.sharded_retrieval import ShardedFlatSearch
from repro.data import generate_dataset
from repro.kernels.ivf_topk.ops import topk_ip


def main():
    ds = generate_dataset(n_records=20_000, dim=128, n_topics=128,
                          n_queries=16, seed=0)
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    print(f"devices: {jax.device_count()}; corpus: {ds.n} x 128")

    search = ShardedFlatSearch(ds.embeddings, mesh)
    # warm
    search.search(ds.query_embs[:1], 10)
    t0 = time.perf_counter()
    vals, idx = search.search(ds.query_embs, 10)
    t_sharded = time.perf_counter() - t0

    t0 = time.perf_counter()
    rv, ri = topk_ip(ds.embeddings, ds.query_embs, 10)
    t_single = time.perf_counter() - t0

    agree = float((np.asarray(idx) == np.asarray(ri)).mean())
    print(f"sharded top-10 == single-device top-10: {agree:.3f} agreement")
    print(f"wall: sharded {t_sharded*1e3:.1f} ms, "
          f"single {t_single*1e3:.1f} ms (8 host 'chips', CPU)")
    print(f"per-shard rows: {ds.n // 8}; gathered candidates/query: 8 x 10")


if __name__ == "__main__":
    main()
