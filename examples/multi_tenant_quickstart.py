"""Multi-tenant quickstart: two tenants on one shared substrate.

One :class:`TenantRouter` owns the storage backend, the cost-aware LFU
cache, and the fair-share maintenance scheduler; each ``create_tenant``
gets its own EdgeRAG index (centroids, Alg. 3 threshold, SLO) on top of
those shared services.  Queries from both tenants run concurrently through
the router — interleaved batches fuse into a single slab launch — and the
results are bitwise what each tenant would have seen on a private index.

    PYTHONPATH=src python examples/multi_tenant_quickstart.py
"""
import numpy as np

from repro.core import EdgeCostModel, TenantRouter
from repro.data import generate_dataset
from repro.serving.metrics import MetricsRegistry, collect_router


def main():
    cost = EdgeCostModel()
    # SLO near the per-cluster regen cost: heavy clusters go to storage
    # (Alg. 1), light ones stay on the regenerate-and-cache path, so both
    # shared services see real traffic
    router = TenantRouter(dim=64, cost_model=cost, slo_s=0.15,
                          cache_bytes=1 << 22)

    # -- ingest: two tenants with disjoint corpora ----------------------
    corpora = {}
    for tenant, seed in (("alice", 7), ("bob", 8)):
        ds = generate_dataset(n_records=1200, dim=64, n_topics=24,
                              n_queries=16, seed=seed)
        ix = router.create_tenant(tenant, ds.embedder, ds.get_chunks)
        ix.build(ds.chunk_ids, ds.texts, nlist=32,
                 embeddings=ds.embeddings)
        corpora[tenant] = ds
        print(f"[ingest] {tenant}: {ds.n} chunks, "
              f"{ix.stats()['active_clusters']} clusters, "
              f"{ix.stats()['stored_clusters']} stored")

    # -- query: one interleaved batch, one fused slab launch ------------
    tenants, embs = [], []
    for qi in range(8):
        for tenant in ("alice", "bob"):
            tenants.append(tenant)
            embs.append(corpora[tenant].query_embs[qi])
    ids, vals, lats = router.search_batch(np.stack(embs), k=5, nprobe=8,
                                          tenants=tenants)
    for gqi in (0, 1):          # first query of each tenant
        tenant = tenants[gqi]
        hits = corpora[tenant].get_chunks(ids[gqi][:2].tolist())
        print(f"[query] {tenant}: top ids={ids[gqi][:3].tolist()} "
              f"retrieval={lats[gqi].retrieval_s * 1e3:.2f}ms "
              f"first hit: {hits[0][:48]!r}")

    # warm pass: the shared cache now serves both tenants' hot clusters
    router.search_batch(np.stack(embs), k=5, nprobe=8, tenants=tenants)

    # -- per-tenant observability ---------------------------------------
    st = router.stats()
    for tenant in ("alice", "bob"):
        view = router.tenant(tenant).cache
        print(f"[stats] {tenant}: cache_hits={view.hits} "
              f"misses={view.misses} bytes={view.tenant_bytes()} "
              f"storage_bytes={router.storage.tenant_bytes(tenant)}")
    print(f"[stats] shared cache: {st['cache']['total_bytes']}/"
          f"{st['cache']['capacity_bytes']} bytes, "
          f"hit_rate={st['cache']['hit_rate']:.2f}")
    print(f"[stats] device-resident index memory: "
          f"{router.memory_bytes() / 1e6:.2f} MB")

    # Prometheus-style scrape payload (per-tenant labels throughout)
    reg = MetricsRegistry()
    collect_router(reg, router)
    scrape = [ln for ln in reg.render().splitlines()
              if ln.startswith(("edgerag_cache_hits_total",
                                "edgerag_memory_bytes"))]
    print("[metrics]", *scrape, sep="\n  ")


if __name__ == "__main__":
    main()
