"""End-to-end serving driver (the paper's kind: serve a small model with
batched requests).

Pipeline, all real on this machine (reduced model configs):
  synthetic BEIR-like corpus → EdgeRAG index (prune/store/cache)
  → gte embedding model (JAX) embeds queries
  → retrieval → context assembly → Sheared-LLaMA-family generator
  (JAX prefill + decode) → tokens,
with a request scheduler replaying a Poisson arrival trace and reporting
TTFT / SLO statistics under the edge cost model.

    PYTHONPATH=src python examples/edge_serving.py [--requests 30]
"""
import argparse

import numpy as np

from repro import configs
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data.synthetic import scaled_beir
from repro.serving.engine import GeneratorModel, RAGEngine
from repro.serving.scheduler import RequestScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="fever")
    ap.add_argument("--records", type=int, default=1500)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="requests/sec (edge-time)")
    args = ap.parse_args()

    ds = scaled_beir(args.dataset, n_records=args.records,
                     n_queries=args.requests)
    cost = EdgeCostModel()
    slo = ds.spec.slo_s
    index = EdgeRAGIndex(ds.embeddings.shape[1], ds.embedder, ds.get_chunks,
                         cost, slo_s=slo)
    index.build(ds.chunk_ids, ds.texts, nlist=max(32, ds.n // 32),
                embeddings=ds.embeddings)
    print(f"[index] {index.stats()}")

    generator = GeneratorModel(
        configs.get_config("sheared-llama-2.7b").reduced(num_layers=2,
                                                         d_model=256),
        max_prompt=64)
    engine = RAGEngine(index, generator, cost_model=cost, k=8, nprobe=8,
                       max_new_tokens=8)

    sched = RequestScheduler()
    rng = np.random.default_rng(0)
    t = 0.0
    for qi in range(args.requests):
        t += rng.exponential(1.0 / args.arrival_rate)
        sched.submit(arrival_s=t, query=f"query-{qi}",
                     query_emb=ds.query_embs[qi],
                     query_chars=int(ds.query_chars[qi]), slo_s=slo)

    responses = []

    def serve(req):
        resp = engine.answer(req.query, req.query_emb, ds.get_chunks)
        responses.append(resp)
        return resp.ttft_edge_s          # edge service time drives the queue

    done = sched.run(serve)
    ttfts = np.asarray([r.ttft_edge_s for r in responses])
    retr = np.asarray([r.retrieval.retrieval_s for r in responses])
    print(f"\n[serve] {len(done)} requests")
    print(f"  retrieval edge: mean={retr.mean()*1e3:.0f}ms "
          f"p95={np.percentile(retr, 95)*1e3:.0f}ms")
    print(f"  TTFT edge:      mean={ttfts.mean():.2f}s "
          f"p95={np.percentile(ttfts, 95):.2f}s")
    print(f"  e2e (incl. queueing) SLO hit rate: {sched.slo_hit_rate():.2f} "
          f"(slo={slo}s)")
    print(f"  cache: hit_rate={index.cache.hit_rate:.2f} "
          f"entries={len(index.cache)} "
          f"threshold={index.threshold.threshold*1e3:.0f}ms")
    print(f"  sample generation (token ids): {responses[0].output_tokens}")


if __name__ == "__main__":
    main()
