"""Int8 KV-cache: quantization round-trip + quantized decode attention vs
full precision, including the Pallas int8 kernel in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import (decode_attention_pallas,
                                                   decode_attention_pallas_q8)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.models.quantization import (dequantize_kv, init_quant_cache,
                                       quant_insert, quantize_kv)

RNG = np.random.default_rng(9)


def _r(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, jnp.float32)


def test_quantize_roundtrip_error_bounded():
    x = _r((2, 64, 4, 32), scale=3.0)
    q = quantize_kv(x)
    assert q.q.dtype == jnp.int8
    err = float(jnp.abs(dequantize_kv(q) - x).max())
    amax = float(jnp.abs(x).max(axis=-1).max())
    assert err <= amax / 127.0 * 1.01          # half-ulp of the scale grid


def test_quant_bytes_halved():
    cache = init_quant_cache(4, 1024, 8, 128)
    q_bytes = cache.q.size + cache.scale.size * 4
    full_bytes = 4 * 1024 * 8 * 128 * 2        # bf16
    assert q_bytes < 0.6 * full_bytes


def test_quant_insert_matches_full_insert():
    cache = init_quant_cache(2, 16, 2, 8)
    new = _r((2, 1, 2, 8))
    out = quant_insert(cache, new, 5)
    got = dequantize_kv(out)[:, 5]
    np.testing.assert_allclose(np.asarray(got), np.asarray(new[:, 0]),
                               atol=np.abs(np.asarray(new)).max() / 100)
    # per-slot vector insert
    out2 = quant_insert(cache, new, jnp.asarray([3, 9]))
    assert float(jnp.abs(dequantize_kv(out2)[0, 3] - new[0, 0]).max()) < 0.1
    assert float(jnp.abs(dequantize_kv(out2)[1, 9] - new[1, 0]).max()) < 0.1


@pytest.mark.parametrize("b,h,kh,smax,d,clen", [
    (2, 4, 2, 256, 64, 200), (1, 8, 8, 128, 32, 128)])
def test_q8_decode_attention_close_to_fp(b, h, kh, smax, d, clen):
    q = _r((b, h, d))
    kc, vc = _r((b, smax, kh, d)), _r((b, smax, kh, d))
    qk, qv = quantize_kv(kc), quantize_kv(vc)
    o_q8 = decode_attention_pallas_q8(q, qk.q, qk.scale, qv.q, qv.scale,
                                      clen, bk=64, interpret=True)
    o_fp = decode_attention_ref(q, kc, vc, clen)
    # int8 cache error: small relative to the attention output scale
    err = float(jnp.abs(o_q8 - o_fp).max())
    assert err < 0.03, err
    # and the q8 kernel agrees with itself vs a dequantized fp run
    o_deq = decode_attention_pallas(q, dequantize_kv(qk), dequantize_kv(qv),
                                    clen, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_q8), np.asarray(o_deq),
                               atol=2e-5)
