"""Hypothesis property-based tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cache_policy import (CostAwareLFUCache,
                                     MinLatencyThresholdController)
from repro.data.chunking import chunk_text
from repro.data.tokenizer import HashingTokenizer
from repro.kernels.ivf_topk.ref import topk_ip_ref
from repro.kernels.ivf_topk.kernel import topk_ip_pallas
from repro.models.rwkv6 import wkv6_chunked, wkv6_recurrent

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(n=st.integers(1, 300), k=st.integers(1, 32), seed=st.integers(0, 99))
def test_topk_pallas_equals_ref(n, k, seed):
    rng = np.random.default_rng(seed)
    embs = jnp.asarray(rng.standard_normal((n, 32)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 32)), jnp.float32)
    keff = min(k, n)
    pv, pi = topk_ip_pallas(embs, q, keff, block_n=64, interpret=True)
    rv, ri = topk_ip_ref(embs, q, keff)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(rv), atol=1e-4)
    assert (np.asarray(pi) == np.asarray(ri)).all()


@settings(**SETTINGS)
@given(seed=st.integers(0, 50),
       ops=st.lists(st.tuples(st.booleans(), st.floats(0.001, 2.0),
                              st.integers(0, 30)), min_size=1, max_size=60))
def test_cache_capacity_invariant(seed, ops):
    """under any access/insert sequence the cache never exceeds capacity and
    hit/miss counters stay consistent."""
    cache = CostAwareLFUCache(capacity_bytes=512)
    rng = np.random.default_rng(seed)
    accesses = 0
    for is_insert, lat, key in ops:
        if is_insert:
            cache.insert(key, np.ones((rng.integers(1, 4), 8), np.float32),
                         lat)
        else:
            cache.access(key)
            accesses += 1
        assert cache.total_bytes() <= 512
        assert len(cache) * 32 <= 512
    assert cache.hits + cache.misses == accesses


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.booleans(), st.floats(0.0, 3.0)),
                min_size=1, max_size=200))
def test_threshold_never_negative_and_bounded_steps(events):
    ctl = MinLatencyThresholdController(step_s=0.01)
    prev = 0.0
    for miss, lat in events:
        t = ctl.observe(miss, lat)
        assert t >= 0.0
        assert abs(t - prev) <= 0.01 + 1e-12   # moves one step at a time
        prev = t


# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(text=st.text(alphabet=st.characters(codec="ascii",
                                           categories=("L", "N", "Z")),
                    min_size=0, max_size=2000),
       chunk=st.integers(50, 400), overlap=st.integers(0, 40))
def test_chunking_covers_text(text, chunk, overlap):
    chunks = chunk_text(text, chunk_chars=chunk, overlap_chars=overlap)
    if not text:
        assert chunks == []
        return
    assert all(len(c) <= chunk for c in chunks)
    # every character position is covered by some chunk (with overlap,
    # concatenation length >= original)
    assert sum(len(c) for c in chunks) >= len(text) - len(chunks)
    assert chunks[0].startswith(text[:1])
    assert text.endswith(chunks[-1][-1:]) or not chunks[-1]


@settings(**SETTINGS)
@given(st.text(min_size=0, max_size=500), st.integers(8, 64))
def test_tokenizer_deterministic_and_bounded(text, max_len):
    tok = HashingTokenizer(vocab_size=1000)
    a = tok.encode(text, max_len)
    b = tok.encode(text, max_len)
    assert a == b
    assert len(a) <= max_len
    assert all(0 <= t < 1000 for t in a)


# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 20))
def test_wkv6_chunk_size_invariance(s, chunk, seed):
    """the chunked WKV result is independent of chunk size (exactness)."""
    rng = np.random.default_rng(seed)
    b, h, k = 1, 2, 4
    r, kk, v = (jnp.asarray(rng.standard_normal((b, s, h, k)), jnp.float32)
                for _ in range(3))
    logw = -jnp.abs(jnp.asarray(rng.standard_normal((b, s, h, k)),
                                jnp.float32)) - 0.01
    u = jnp.asarray(rng.standard_normal((h, k)), jnp.float32)
    s0 = jnp.zeros((b, h, k, k))
    o1, f1 = wkv6_chunked(r, kk, v, logw, u, s0, chunk=chunk)
    o2, f2 = wkv6_recurrent(r, kk, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=2e-4)
