"""Hypothesis: corruption-detection properties of the storage failure
model (core/faults.py).  Any single bit flip or truncation of a stored
payload — any codec, any array, any byte — is caught by the per-key
checksum and recovered via regeneration + re-put."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.storage import CODECS, StorageBackend

pytestmark = pytest.mark.fast

SETTINGS = dict(max_examples=40, deadline=None)


def _emb(n, d, seed):
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((n, d)).astype(np.float32)
    return e / np.linalg.norm(e, axis=1, keepdims=True)


def _flip_one_bit(stored, rng):
    """Flip one bit of one member array of the STORED blob in place
    (checksum member included — rot there must be caught too)."""
    names = sorted(stored)
    name = names[int(rng.integers(len(names)))]
    a = np.array(stored[name], copy=True)
    flat = a.reshape(-1).view(np.uint8)
    i = int(rng.integers(flat.size))
    flat[i] ^= np.uint8(1 << int(rng.integers(8)))
    stored[name] = a
    return name


@settings(**SETTINGS)
@given(codec=st.sampled_from(CODECS), n=st.integers(2, 24),
       d=st.sampled_from([8, 16, 32]), seed=st.integers(0, 10_000))
def test_single_bitflip_detected_and_recovered(codec, n, d, seed):
    s = StorageBackend("memory", codec=codec, retry_limit=1)
    emb = _emb(n, d, seed)
    s.put(0, emb)
    clean = s.get(0)
    rng = np.random.default_rng(seed + 1)
    _flip_one_bit(s._mem[0], rng)
    # detection: the corrupted blob never decodes; retries exhaust and the
    # rotten blob is quarantine-dropped
    with pytest.raises(KeyError):
        s.get(0)
    assert 0 not in s
    assert s.io_stats["corrupt_dropped"] == 1
    # recovery: regen + re-put (what the resolver's self-heal does)
    s.put(0, emb)
    assert np.array_equal(s.get(0), clean)


@settings(**SETTINGS)
@given(codec=st.sampled_from(CODECS), n=st.integers(2, 24),
       drop=st.integers(1, 3), seed=st.integers(0, 10_000))
def test_truncation_detected_and_recovered(codec, n, drop, seed):
    """Losing trailing rows of the payload array (a torn write surfacing
    on read) is always a checksum mismatch."""
    s = StorageBackend("memory", codec=codec, retry_limit=0)
    emb = _emb(n, 16, seed)
    s.put(0, emb)
    clean = s.get(0)
    stored = s._mem[0]
    name = next(k for k in ("q", "codes", "emb") if k in stored)
    stored[name] = np.array(stored[name][:-min(drop, n - 1)], copy=True)
    assert s.get_many([0]) == [None]
    assert s.io_stats["exhausted"] == 1
    assert 0 not in s
    s.put(0, emb)
    assert np.array_equal(s.get(0), clean)
