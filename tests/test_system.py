"""End-to-end system behaviour: the full EdgeRAG pipeline (index → retrieve
→ generate) against the paper's qualitative claims, plus data substrate."""
import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex, FlatIndex, IVFIndex
from repro.data import HashingEmbedder, chunk_text, generate_dataset
from repro.data.synthetic import BEIR_SPECS, scaled_beir

pytestmark = pytest.mark.slow


def test_full_pipeline_from_raw_text():
    """index raw documents (chunking + real embedder), retrieve by text."""
    docs = [
        "the quick brown fox jumps over the lazy dog " * 20,
        "vector databases enable similarity search over embeddings " * 20,
        "large language models generate text from retrieved context " * 20,
    ]
    embedder = HashingEmbedder(dim=64)
    chunks, ids = [], []
    for doc in docs:
        for c in chunk_text(doc, 120, 20):
            ids.append(len(ids))
            chunks.append(c)
    store = dict(zip(ids, chunks))
    er = EdgeRAGIndex(64, embedder, lambda ii: [store[i] for i in ii],
                      EdgeCostModel(), slo_s=0.05, cache_bytes=1 << 20)
    er.build(ids, chunks, nlist=6)
    q = embedder.embed(["similarity search with vector embeddings"])
    rids, _, lat = er.search(q[0], 5, 3)
    hits = [store[i] for i in rids[0] if i >= 0]
    assert any("similarity" in h for h in hits)
    assert lat.retrieval_s > 0


def test_reuse_ratio_matches_spec_direction():
    """datasets with higher Table 2 reuse ratios produce more repeated
    cluster hits in the synthetic query stream."""
    def realized_reuse(name):
        ds = scaled_beir(name, n_records=2000, n_queries=300, seed=0)
        er = EdgeRAGIndex(ds.embeddings.shape[1], ds.embedder, ds.get_chunks,
                          EdgeCostModel(), slo_s=99.0, cache_bytes=64 << 20)
        er.build(ds.chunk_ids, ds.texts, nlist=60,
                 embeddings=ds.embeddings)
        for qi in range(300):
            er.search(ds.query_embs[qi], 10, 4)
        return er.cache.hit_rate

    hi = realized_reuse("fiqa")      # Table 2 reuse 4.47
    lo = realized_reuse("nq")        # Table 2 reuse 1.25
    assert hi > lo


def test_beir_specs_match_paper_table2():
    assert BEIR_SPECS["fever"].emb_bytes == int(18.5 * 2**30)
    assert BEIR_SPECS["fever"].reuse_ratio == 2.41
    assert not BEIR_SPECS["fever"].fits_in_memory
    assert BEIR_SPECS["scidocs"].fits_in_memory
    assert BEIR_SPECS["nq"].n_records == 2_680_000


def test_memory_hierarchy_ordering():
    """EdgeRAG resident << IVF resident == Flat resident + centroids."""
    ds = generate_dataset(n_records=800, dim=32, n_topics=24, seed=0)
    cost = EdgeCostModel()
    flat = FlatIndex(32, cost)
    flat.add(ds.embeddings, ds.chunk_ids)
    ivf = IVFIndex(32, cost)
    ivf.build(ds.embeddings, ds.chunk_ids, nlist=24)
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, cost, slo_s=0.2)
    er.build(ds.chunk_ids, ds.texts, nlist=24, embeddings=ds.embeddings)
    assert er.memory_bytes() < 0.1 * ivf.memory_bytes()
    assert abs(ivf.memory_bytes() - flat.memory_bytes()) \
        <= ivf.centroids.nbytes


def test_quality_independent_of_memory_optimizations():
    """Table 4 ablations return identical retrievals (only latency differs)."""
    ds = generate_dataset(n_records=700, dim=32, n_topics=20, n_queries=30,
                          seed=2)
    cost = EdgeCostModel()
    variants = {
        "gen": dict(store_heavy=False, cache_bytes=0),
        "gen_load": dict(store_heavy=True, cache_bytes=0),
        "edgerag": dict(store_heavy=True, cache_bytes=1 << 20),
    }
    results = {}
    for name, kw in variants.items():
        er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, cost,
                          slo_s=0.1, **kw)
        er.build(ds.chunk_ids, ds.texts, nlist=20,
                 embeddings=ds.embeddings, seed=9)
        results[name] = [tuple(sorted(
            er.search(ds.query_embs[qi], 8, 4)[0][0].tolist()))
            for qi in range(30)]
    assert results["gen"] == results["gen_load"] == results["edgerag"]
