"""TenantRouter: shared-substrate multi-tenancy.

The contract under test: a one-tenant router replays a standalone
EdgeRAGIndex EXACTLY (ids, scores, modeled charges, Alg. 3 state); a
mixed-tenant fused batch is bitwise identical to serving each tenant's
queries through its own silo; tenants are isolated on the shared storage /
cache / maintenance substrate; and the serving layer (RAGEngine,
StagedPipeline, RequestScheduler + TokenBucketAdmission) threads tenancy
end to end."""
import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex, TenantRouter
from repro.core.maintenance import (FairShareMaintenance,
                                    MaintenanceScheduler)
from repro.data import generate_dataset
from repro.serving.engine import RAGEngine
from repro.serving.pipeline import PipelineBatch, StagedPipeline
from repro.serving.scheduler import RequestScheduler, TokenBucketAdmission

pytestmark = pytest.mark.fast

DIM = 32
K = 5
NPROBE = 3
CACHE = 1 << 20


@pytest.fixture(scope="module")
def corpora():
    return [generate_dataset(n_records=360, dim=DIM, n_topics=8,
                             n_queries=6, seed=40 + t)
            for t in range(3)]


def _cost():
    return EdgeCostModel()


def _standalone(ds, cost, nlist=10, slo_s=0.002, cache_bytes=CACHE):
    ix = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost, slo_s=slo_s,
                      cache_bytes=cache_bytes, maintenance="deferred")
    ix.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=ds.embeddings,
             seed=1)
    return ix


def _router(corpora, cost, nlist=10, slo_s=0.002):
    router = TenantRouter(DIM, cost, slo_s=slo_s, cache_bytes=CACHE)
    for t, ds in enumerate(corpora):
        ix = router.create_tenant(f"t{t}", ds.embedder, ds.get_chunks)
        ix.build(ds.chunk_ids, ds.texts, nlist=nlist,
                 embeddings=ds.embeddings, seed=1)
    return router


# ----------------------------------------------------------------------
# bit-identity
# ----------------------------------------------------------------------
def test_one_tenant_router_matches_standalone(corpora):
    """Same kernel calls, same cache/threshold mutations, same modeled
    charges — cold AND warm passes."""
    ds = corpora[0]
    cost = _cost()
    sa = _standalone(ds, cost)
    router = _router(corpora[:1], cost)
    tix = router.tenant("t0")
    qc = [int(c) for c in ds.query_chars]
    for _ in range(3):
        ids0, vals0, lats0 = sa.search_batch(ds.query_embs, K, NPROBE, qc)
        ids1, vals1, lats1 = router.search_batch(ds.query_embs, K, NPROBE,
                                                 qc, tenants="t0")
        np.testing.assert_array_equal(ids0, ids1)
        np.testing.assert_array_equal(vals0, vals1)
        for l0, l1 in zip(lats0, lats1):
            assert l0.retrieval_s == l1.retrieval_s
            assert l0.n_shared_hits == l1.n_shared_hits
            assert l0.centroid_search_s == l1.centroid_search_s
    assert sa.threshold.threshold == tix.threshold.threshold
    assert sa.cache.hit_rate == tix.cache.hit_rate
    assert sa.memory_bytes() == router.memory_bytes()


def test_mixed_batch_fused_matches_silos(corpora):
    """Interleaved 3-tenant batch through ONE fused slab launch ==
    serving each tenant's queries through its own standalone index."""
    cost = _cost()
    router = _router(corpora, cost)
    silos = [_standalone(ds, cost, cache_bytes=CACHE) for ds in corpora]
    # interleave: t0 q0, t1 q0, t2 q0, t0 q1, ...
    tenants, embs, local = [], [], []
    for qi in range(4):
        for t in range(3):
            tenants.append(f"t{t}")
            embs.append(corpora[t].query_embs[qi])
            local.append((t, qi))
    embs = np.stack(embs)
    for _ in range(2):                      # cold + warm
        mids, mvals, mlats = router.search_batch(embs, K, NPROBE,
                                                 tenants=tenants)
        refs = [silo.search_batch(ds.query_embs[:4], K, NPROBE)
                for silo, ds in zip(silos, corpora)]
        for gqi, (t, qi) in enumerate(local):
            np.testing.assert_array_equal(mids[gqi], refs[t][0][qi])
            np.testing.assert_array_equal(mvals[gqi], refs[t][1][qi])


def test_cross_tenant_plan_keys_are_tenant_scoped(corpora):
    cost = _cost()
    router = _router(corpora, cost)
    state = router.search_begin(
        np.stack([corpora[0].query_embs[0], corpora[1].query_embs[0]]),
        K, NPROBE, tenants=["t0", "t1"])
    assert all(isinstance(k, tuple) and k[0] in ("t0", "t1")
               for k in state.plan.owner)
    # no cluster key can be owned by the wrong tenant's query
    for qi, probed in enumerate(state.plan.probed_per_q):
        assert all(key[0] == state.tenants[qi] for key in probed)


# ----------------------------------------------------------------------
# shared-substrate isolation
# ----------------------------------------------------------------------
def test_storage_isolation_and_budget(corpora):
    cost = _cost()
    # slo_s=0 forces every cluster heavy => everything goes to storage
    router = TenantRouter(DIM, cost, slo_s=0.0, cache_bytes=CACHE)
    for t, ds in enumerate(corpora[:2]):
        ix = router.create_tenant(f"t{t}", ds.embedder, ds.get_chunks,
                                  slo_s=0.0)
        ix.build(ds.chunk_ids, ds.texts, nlist=8,
                 embeddings=ds.embeddings, seed=1)
    b0 = router.storage.tenant_bytes("t0")
    b1 = router.storage.tenant_bytes("t1")
    assert b0 > 0 and b1 > 0
    assert router.storage.total_bytes() == b0 + b1
    # clearing one tenant's view must not touch the other's blobs
    router.tenant("t0").storage.clear()
    assert router.storage.tenant_bytes("t0") == 0
    assert router.storage.tenant_bytes("t1") == b1


def test_shared_cache_per_tenant_accounting(corpora):
    cost = _cost()
    # high SLO: no cluster is stored, every miss regenerates + caches
    router = _router(corpora[:2], cost, slo_s=10.0)
    for rep in range(2):
        for t, ds in enumerate(corpora[:2]):
            router.search_batch(ds.query_embs, K, NPROBE,
                                tenants=f"t{t}")
    pt = router.cache.per_tenant
    for t in ("t0", "t1"):
        view = router.tenant(f"t{t[-1]}").cache
        assert view.hits == pt[t]["hits"]
        assert view.misses == pt[t]["misses"]
    assert (router.cache.hits ==
            sum(st["hits"] for st in pt.values()))
    assert (router.cache.total_bytes() ==
            sum(st["bytes"] for st in pt.values()))


def test_duplicate_and_invalid_tenant_ids(corpora):
    router = TenantRouter(DIM, _cost())
    ds = corpora[0]
    router.create_tenant("a", ds.embedder, ds.get_chunks)
    with pytest.raises(AssertionError):
        router.create_tenant("a", ds.embedder, ds.get_chunks)
    with pytest.raises(AssertionError):
        router.create_tenant("bad/id", ds.embedder, ds.get_chunks)
    with pytest.raises(AssertionError):
        router.search_begin(ds.query_embs[:1], K, NPROBE,
                            tenants=["nope"])


# ----------------------------------------------------------------------
# fair-share maintenance
# ----------------------------------------------------------------------
class _StubIndex:
    """Minimal index for MaintenanceScheduler: one drop_store per cid."""

    dim = 8

    def __init__(self):
        self.cost = EdgeCostModel()
        self.dropped = []
        self.clusters = {}

    def add(self, cid):
        import dataclasses

        @dataclasses.dataclass
        class _Cl:
            generation: int = 0
            active: bool = True
            size: int = 1
            char_count: int = 10
            stored: bool = True
            stored_generation: int = 0
            gen_latency_est: float = 0.0
        self.clusters[cid] = _Cl()

    @property
    def store_heavy(self):
        return True

    @property
    def slo_s(self):
        return 1.0      # gen_latency_est < slo -> revalidates to drop_store

    def _drop_stored(self, cid):
        self.dropped.append(cid)
        self.clusters[cid].stored = False


def test_fair_share_round_robin_alternates():
    """A churn-heavy tenant cannot starve others: execution order
    alternates tenants even when one queue is much longer."""
    fair = FairShareMaintenance()
    stubs = {}
    for t, n_ops in (("heavy", 6), ("light", 2)):
        stub = _StubIndex()
        sched = MaintenanceScheduler(stub)
        for cid in range(n_ops):
            stub.add(cid)
            sched.enqueue("drop_store", cid)
        fair.register(t, sched)
        stubs[t] = stub
    assert len(fair) == 8
    report = fair.drain(None)
    assert len(report.executed) == 8
    order = [key[1][0] for key in report.executed]
    # both of light's ops ran within the first four turns
    assert order[:4].count("light") == 2
    assert len(fair) == 0
    assert fair.stats()["light"]["fair_share_edge_s"] >= 0.0


def test_fair_share_cursor_persists_across_drains():
    fair = FairShareMaintenance()
    for t in ("a", "b"):
        stub = _StubIndex()
        sched = MaintenanceScheduler(stub)
        for cid in range(2):
            stub.add(cid)
            sched.enqueue("drop_store", cid)
        fair.register(t, sched)
    first = fair.drain(1e-12)        # tiny budget: one op (first is free)
    assert len(first.executed) == 1
    second = fair.drain(1e-12)
    assert len(second.executed) == 1
    # the second drain resumed the rotation, not restarted it
    assert first.executed[0][1][0] != second.executed[0][1][0]


def test_router_maintenance_is_fair_share(corpora):
    router = _router(corpora[:2], _cost())
    assert isinstance(router.maintenance, FairShareMaintenance)
    ds = corpora[0]
    tix = router.tenant("t0")
    # an online insert enqueues deferred work under this tenant
    n0 = len(router.maintenance)
    text = "doc-10000 " + "tok " * 20
    rng = np.random.default_rng(7)
    emb = rng.standard_normal(DIM).astype(np.float32)
    emb /= np.linalg.norm(emb)
    ds.add_chunk(10_000, text, emb)
    tix.insert(10_000, text)
    assert len(router.maintenance) >= n0
    router.maintenance.drain(None)
    assert len(router.maintenance) == 0


# ----------------------------------------------------------------------
# serving integration
# ----------------------------------------------------------------------
def test_router_through_engine_and_pipeline(corpora):
    cost = _cost()
    router = _router(corpora, cost)
    eng = RAGEngine(router, None, cost_model=cost, k=K, nprobe=NPROBE,
                    maintenance_owner="external")
    tenants = ["t0", "t1", "t2", "t0"]
    embs = np.stack([corpora[0].query_embs[0], corpora[1].query_embs[0],
                     corpora[2].query_embs[0], corpora[0].query_embs[1]])
    resp = eng.answer_batch(["q"] * 4, embs, tenants=tenants)
    assert len(resp) == 4
    # contexts come from each query's own tenant corpus
    for r, t in zip(resp, tenants):
        ds = corpora[int(t[1])]
        assert all(c in ds.texts for c in r.context)
    pipe = StagedPipeline(eng, None)
    responses, trace = pipe.run([
        PipelineBatch(queries=["q"] * 4, query_embs=embs, arrival_s=0.0,
                      tenants=tenants),
        PipelineBatch(queries=["q"] * 4, query_embs=embs, arrival_s=1e-4,
                      tenants=list(reversed(tenants)))])
    assert len(responses) == 2 and all(len(b) == 4 for b in responses)
    assert trace.stages["s4"].n_fired == 2


def test_run_pipelined_threads_tenants(corpora):
    cost = _cost()
    router = _router(corpora[:2], cost)
    eng = RAGEngine(router, None, cost_model=cost, k=K, nprobe=NPROBE,
                    maintenance_owner="external")
    pipe = StagedPipeline(eng, None)
    sched = RequestScheduler()
    for i in range(8):
        t = f"t{i % 2}"
        ds = corpora[i % 2]
        sched.submit(i * 1e-3, query="q", query_emb=ds.query_embs[i % 4],
                     slo_s=100.0, tenant=t)
    done = sched.run_pipelined(pipe, batch_size=4)
    assert len(done) == 8
    assert all(r.outcome == "met" for r in done)
    assert len(sched.pipeline_responses) == 8


# ----------------------------------------------------------------------
# admission control
# ----------------------------------------------------------------------
def test_admission_rejects_over_share_under_backlog():
    adm = TokenBucketAdmission(rate_per_s=1.0, burst=1.0)
    sched = RequestScheduler(admission=adm)
    for i in range(10):
        sched.submit(i * 0.01, slo_s=100.0, tenant="x")   # 100 req/s burst
    done = sched.run(lambda req: 0.5)                     # service 0.5 s
    counts = sched.outcome_counts()
    assert counts["rejected"] > 0
    assert counts["met"] >= 1
    rejected = [r for r in done if r.rejected]
    assert all(r.outcome == "rejected" and not r.slo_met for r in rejected)
    assert all(r.finish_s == r.start_s for r in rejected)


def test_admission_work_conserving_when_idle():
    """Sparse arrivals never queue: fair share must not bind on an idle
    device even with an empty bucket."""
    adm = TokenBucketAdmission(rate_per_s=0.001, burst=1.0)
    sched = RequestScheduler(admission=adm)
    for i in range(5):
        sched.submit(i * 10.0, slo_s=100.0, tenant="x")   # far apart
    done = sched.run(lambda req: 0.5)
    assert all(r.outcome == "met" for r in done)


def test_admission_sheds_blown_deadline():
    """A request whose queue wait alone exceeds its SLO is shed even
    with tokens available."""
    adm = TokenBucketAdmission(rate_per_s=100.0, burst=10.0)
    sched = RequestScheduler(admission=adm)
    for i in range(6):
        sched.submit(i * 0.01, slo_s=0.2, tenant="x")
    done = sched.run(lambda req: 1.0)       # each service blows the next SLO
    assert sum(r.rejected for r in done) > 0
    assert sum(adm.blown.values()) > 0


def test_admission_degrade_mode_flags_not_rejects():
    adm = TokenBucketAdmission(rate_per_s=1.0, burst=1.0, mode="degrade")
    sched = RequestScheduler(admission=adm)
    for i in range(10):
        sched.submit(i * 0.01, slo_s=100.0, tenant="x")
    done = sched.run(lambda req: 0.5)
    assert sched.outcome_counts()["rejected"] == 0
    assert any(r.pre_degraded for r in done)


def test_admission_protects_small_tenant():
    """Noisy neighbor: with per-tenant fair share, the small tenant's
    served tail collapses versus no admission."""
    def run_arm(admission):
        sched = RequestScheduler(admission=admission)
        for i in range(120):                  # big floods at 3x capacity
            sched.submit(i / 30.0, slo_s=1.0, tenant="big")
        for j in range(12):                   # small trickles
            sched.submit(j * 1.0, slo_s=1.0, tenant="small")
        sched.run(lambda req: 0.1)
        small = [r.latency_s for r in sched.completed
                 if r.tenant == "small" and not r.rejected]
        return float(np.percentile(small, 99))

    p99_off = run_arm(None)
    p99_on = run_arm(TokenBucketAdmission(rate_per_s=5.0, burst=2.0))
    assert p99_on < p99_off


def test_router_stats_shape(corpora):
    router = _router(corpora[:2], _cost())
    router.search_batch(corpora[0].query_embs[:2], K, NPROBE, tenants="t0")
    st = router.stats()
    assert st["n_tenants"] == 2
    assert set(st["tenants"]) == {"t0", "t1"}
    assert st["cache"]["capacity_bytes"] == CACHE
    assert "t0" in st["storage"]["per_tenant"]
    assert st["memory_bytes"] == router.memory_bytes()
