"""Staged serving pipeline (serving/pipeline.py + the engine's staged
path): bitwise parity of the staged search with search_batch and the
sequential loop, maintenance-in-bubbles semantics (including the
ramp-is-not-a-bubble gate), stale-plan S3 re-entry, queue-wait deadline
propagation (a delayed request degrades instead of silently missing), and
explicit drain ownership."""
import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.faults import DegradationPolicy
from repro.data import generate_dataset
from repro.serving.engine import RAGEngine
from repro.serving.pipeline import PipelineBatch, StagedPipeline
from repro.serving.scheduler import RequestScheduler

pytestmark = pytest.mark.fast

DIM = 32
K = 5
NPROBE = 5


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=500, dim=DIM, n_topics=16,
                            n_queries=24, seed=5)


def _fresh(ds, **kw):
    kw.setdefault("slo_s", 0.15)
    er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, EdgeCostModel(), **kw)
    er.build(ds.chunk_ids, ds.texts, nlist=16, embeddings=ds.embeddings,
             seed=1)
    return er


def _engine(er, **kw):
    kw.setdefault("k", K)
    kw.setdefault("nprobe", NPROBE)
    return RAGEngine(er, None, **kw)


def _batches(ds, n_batches, per_batch=4, arrivals=None):
    out = []
    for b in range(n_batches):
        qis = [(b * per_batch + i) % len(ds.query_embs)
               for i in range(per_batch)]
        out.append(PipelineBatch(
            queries=[f"q{qi}" for qi in qis],
            query_embs=np.stack([ds.query_embs[qi] for qi in qis]),
            arrival_s=0.0 if arrivals is None else arrivals[b]))
    return out


def _seed_maintenance(ds, er, n=6, first_id=910_000):
    """Insert near-duplicates so deferred restores queue up (the index is
    built with a tight slo_s, so touched clusters go over it)."""
    rng = np.random.default_rng(11)
    for j in range(n):
        nid = first_id + j
        emb = ds.embeddings[int(rng.integers(ds.n))] \
            + 0.03 * rng.standard_normal(DIM)
        emb = (emb / np.linalg.norm(emb)).astype(np.float32)
        text = f"doc-{nid} " + "tok " * 20
        ds.add_chunk(nid, text, emb)
        er.insert(nid, text)
    return n


# ----------------------------------------------------------------------
# staged search parity
# ----------------------------------------------------------------------
def test_staged_search_bitwise_matches_search_batch(ds):
    staged = _fresh(ds)
    batch = _fresh(ds)
    embs = ds.query_embs[:8]
    state = staged.search_begin(embs, K, NPROBE)
    staged.search_fetch(state)
    s_ids, s_vals, s_lats = staged.search_finish(state)
    b_ids, b_vals, b_lats = batch.search_batch(embs, K, NPROBE)
    assert np.array_equal(s_ids, b_ids)
    assert np.array_equal(s_vals, b_vals)
    for sl, bl in zip(s_lats, b_lats):
        assert sl.retrieval_s == pytest.approx(bl.retrieval_s)


def test_pipeline_answers_match_sequential_answer_batch(ds):
    pipe_er = _fresh(ds)
    seq_er = _fresh(ds)
    batches = _batches(ds, n_batches=3)
    pipe = StagedPipeline(_engine(pipe_er), ds.get_chunks)
    responses, trace = pipe.run(batches)
    seq_eng = _engine(seq_er)
    for b, resp_batch in zip(batches, responses):
        seq = seq_eng.answer_batch(b.queries, b.query_embs, ds.get_chunks)
        assert [r.chunk_ids for r in resp_batch] \
            == [r.chunk_ids for r in seq]
    assert trace.n_batches == 3
    # stage occupancy is the engine's stage accounting, re-aggregated
    assert trace.stages["s4"].busy_s > 0
    assert trace.stages["s2"].busy_s > 0
    assert trace.hidden_retrieval_fraction > 0   # some overlap happened


# ----------------------------------------------------------------------
# maintenance in bubbles
# ----------------------------------------------------------------------
def _seed_offpath_restores(ds, er, batches, n=2):
    """Queue restore work on clusters the batch queries will NOT probe.
    Probed clusters self-heal during S2 (execute re-persists stale stored
    copies — the Alg. 1 self-heal), which would revalidate the queued ops
    away before any bubble; off-path clusters stay dirty until a drain."""
    scratch = _fresh(ds)             # probe-set lookup without touching er
    probed = set()
    for b in batches:
        probed |= set(scratch.plan_batch(b.query_embs, NPROBE).owner)
    targets = [cid for cid in range(er.nlist) if cid not in probed][:n]
    assert targets, "every cluster probed — shrink the batch"
    for cid in targets:
        chunk = int(er.clusters[cid].ids[0])
        # a long in-place rewrite pushes the cluster over the storage SLO:
        # update() enqueues the deferred restore
        text = f"doc-{chunk} rev " + "tok " * 1000
        ds.add_chunk(chunk, text, ds.embedder.table[chunk])
        er.update(chunk, text)
    return targets


def test_maintenance_drains_in_bubbles_without_changing_answers(ds):
    # the same 4 queries every batch: a narrow probe footprint leaves
    # off-path clusters for the seeded restores to wait on
    one = _batches(ds, n_batches=1)[0]
    batches = [PipelineBatch(queries=list(one.queries),
                             query_embs=one.query_embs.copy())
               for _ in range(4)]
    # cache_bytes=0: every batch's fetch is real regeneration, so the S3
    # queue sees op-sized gaps (a warm cache would collapse S2 to
    # microseconds and leave no bubble big enough for a strict drain)
    pipe_er = _fresh(ds, maintenance="deferred", cache_bytes=0)
    seq_er = _fresh(ds, maintenance="deferred", cache_bytes=0)
    targets = _seed_offpath_restores(ds, pipe_er, batches)
    for cid in targets:              # identical churn on the reference arm
        chunk = int(seq_er.clusters[cid].ids[0])
        seq_er.update(chunk, ds.get_chunks([chunk])[0])
    assert len(pipe_er.maintenance) > 0
    pipe = StagedPipeline(_engine(pipe_er, maintenance_owner="external"),
                          ds.get_chunks)
    responses, trace = pipe.run(batches)
    # ops ran inside stage bubbles, and the final drain quiesced the rest
    assert trace.maintenance_in_bubbles_s > 0
    assert sum(s.maintenance_ops for s in trace.stages.values()) > 0
    assert len(pipe_er.maintenance) == 0
    for cid in targets:              # the bubble work really landed
        assert pipe_er.clusters[cid].storage_fresh
    # restores moving under the pipeline never change what is retrieved
    seq_eng = _engine(seq_er)        # engine-owned post-decode drains
    for b, resp_batch in zip(batches, responses):
        seq = seq_eng.answer_batch(b.queries, b.query_embs, ds.get_chunks)
        assert [r.chunk_ids for r in resp_batch] \
            == [r.chunk_ids for r in seq]


def test_ramp_gap_is_not_a_bubble(ds):
    """Before the first decode there is nothing to hide under: a single
    batch must leave the maintenance queue untouched (no pre-S4 drain),
    even with fill_bubbles on."""
    er = _fresh(ds, maintenance="deferred")
    n = _seed_maintenance(ds, er, first_id=920_000)
    assert len(er.maintenance) > 0
    pipe = StagedPipeline(_engine(er, maintenance_owner="external"),
                          ds.get_chunks, final_drain=False)
    _, trace = pipe.run(_batches(ds, n_batches=1))
    assert trace.maintenance_in_bubbles_s == 0
    assert trace.stages["s2"].maintenance_ops == 0
    assert trace.stages["s3"].maintenance_ops == 0
    assert len(er.maintenance) > 0               # still queued, not drained


# ----------------------------------------------------------------------
# stale-plan S3 re-entry
# ----------------------------------------------------------------------
def test_stale_plan_reenters_s1(ds):
    """A content mutation landing in the S2->S3 window forces the batch
    back through S1 (fresh plan + fetch); results match serving the
    post-mutation index directly."""
    er = _fresh(ds)
    ref = _fresh(ds)
    eng = _engine(er)
    embs = ds.query_embs[:4]

    rng = np.random.default_rng(13)
    mutated = {}

    orig_fetch = eng.stage_fetch

    def fetch_then_mutate(job, **kw):
        orig_fetch(job, **kw)
        if not mutated:
            # in-place update of a chunk in a planned cluster: bumps the
            # cluster's content generation after payloads were fetched
            cid = next(iter(job.state.plan.owner))
            chunk_id = int(er.clusters[cid].ids[0])
            emb = ds.embedder.table[chunk_id] \
                + 0.02 * rng.standard_normal(DIM)
            emb = (emb / np.linalg.norm(emb)).astype(np.float32)
            text = f"doc-{chunk_id} rev tok tok tok"
            ds.add_chunk(chunk_id, text, emb)
            mutated["id"] = chunk_id
            mutated["text"] = text
            er.update(chunk_id, text)
        return job

    eng.stage_fetch = fetch_then_mutate
    pipe = StagedPipeline(eng, ds.get_chunks)
    responses, trace = pipe.run([PipelineBatch(
        queries=[f"q{i}" for i in range(4)], query_embs=embs)])
    assert trace.replans == 1
    assert responses[0][0].chunk_ids is not None
    # reference: same mutation applied BEFORE serving, sequential path
    ref.update(mutated["id"], mutated["text"])
    seq = _engine(ref).answer_batch(
        [f"q{i}" for i in range(4)], embs, ds.get_chunks)
    assert [r.chunk_ids for r in responses[0]] \
        == [r.chunk_ids for r in seq]


def test_storage_tier_flip_does_not_replan(ds):
    """A restore/drop between fetch and score bumps ``generation`` but not
    ``content_generation`` — payloads in hand still row-align, so S3 must
    NOT bounce the batch back to S1."""
    er = _fresh(ds)
    plan = er.plan_batch(ds.query_embs[:4], NPROBE)
    cid = next(iter(plan.owner))
    er._restore_cluster(cid)                     # tier flip only
    assert not plan.fresh(cid, er.clusters[cid])  # fetch-time guard trips
    assert er.resolver.stale_cids(plan) == []    # ...but S3 does not


# ----------------------------------------------------------------------
# queue-wait deadline propagation (satellite: degrade, don't silently miss)
# ----------------------------------------------------------------------
def test_queue_wait_degrades_instead_of_silently_missing(ds):
    slo = 2.0
    policy = DegradationPolicy()

    def run(n_batches):
        er = _fresh(ds, cache_bytes=0)   # regen-dominated: real S2 wait
        batches = _batches(ds, n_batches=n_batches)
        batches[-1].slos = [slo] * len(batches[-1].queries)
        batches[-1].policy = policy
        pipe = StagedPipeline(_engine(er), ds.get_chunks)
        responses, _ = pipe.run(batches)
        return responses[-1]

    alone = run(n_batches=1)
    assert all(r.outcome == "ok" for r in alone)  # the SLO is generous...
    behind = run(n_batches=4)
    # ...but behind three batches the S2 queue wait eats the budget: the
    # ladder sheds work (outcome "degraded") instead of serving the full
    # plan late with outcome still reading "ok" (the silent miss)
    assert all(r.outcome != "ok" for r in behind)
    assert any(r.outcome == "degraded" for r in behind)
    assert sum(r.retrieval.retrieval_s for r in behind) \
        < sum(a.retrieval.retrieval_s for a in alone)


def test_run_pipelined_stamps_requests_and_trace(ds):
    er = _fresh(ds)
    sched = RequestScheduler()
    for i in range(6):
        sched.submit(0.05 * i, query=f"q{i}", query_emb=ds.query_embs[i],
                     slo_s=30.0)
    pipe = StagedPipeline(_engine(er), ds.get_chunks)
    done = sched.run_pipelined(pipe, batch_size=3)
    assert len(done) == 6
    assert sched.pipeline_trace is not None
    assert sched.pipeline_trace.n_batches == 2
    assert len(sched.pipeline_responses) == 6
    for r in done:
        assert r.finish_s > r.start_s >= 0.0
        assert r.outcome == "met"
    # second batch decodes after the first entered decode
    assert done[3].start_s > done[0].start_s


# ----------------------------------------------------------------------
# drain ownership
# ----------------------------------------------------------------------
def test_external_owner_engine_never_drains(ds):
    er = _fresh(ds, maintenance="deferred")
    _seed_maintenance(ds, er, first_id=930_000)
    depth = len(er.maintenance)
    assert depth > 0
    eng = _engine(er, maintenance_owner="external")
    out = eng.answer_batch(["q0", "q1"], ds.query_embs[:2], ds.get_chunks)
    assert len(er.maintenance) == depth          # untouched: not the owner
    assert out[0].maintenance_s == 0.0


def test_pipeline_trace_as_dict_schema(ds):
    er = _fresh(ds)
    pipe = StagedPipeline(_engine(er), ds.get_chunks)
    _, trace = pipe.run(_batches(ds, n_batches=2))
    d = trace.as_dict()
    for key in ("n_batches", "n_queries", "makespan_s", "replans",
                "final_drain_s", "retrieval_busy_s", "decode_busy_s",
                "hidden_retrieval_s", "hidden_retrieval_fraction",
                "bubble_fraction", "maintenance_in_bubbles_s", "stages"):
        assert key in d, key
    assert set(d["stages"]) == {"s1", "s2", "s3", "s4"}
    for cell in d["stages"].values():
        for key in ("busy_s", "n_fired", "maintenance_s",
                    "maintenance_ops", "max_queue_depth"):
            assert key in cell, key
    assert 0.0 <= d["hidden_retrieval_fraction"] <= 1.0
    assert d["hidden_retrieval_fraction"] + d["bubble_fraction"] \
        == pytest.approx(1.0)
