"""Packed-slab batch scoring engine: fp32 bitwise parity vs the sequential
per-query concat loop across the Table-4 configs (incl. empty probe lists
and merged-away clusters), fp16/int8 fused-dequant parity vs
dequant-then-score, PQ LUT-scoring differentials (ref + Pallas vs
decode-then-exact, mixed four-representation slabs vs per-segment merge),
slab layout structure, the raw-codec get_many contract, the ragged
multi-query Pallas kernel vs its jnp oracle, and the lazy-decay LFU cache
vs an eager reference."""
import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.cache_policy import CostAwareLFUCache
from repro.core.costs import LatencyBreakdown
from repro.core.resolver import SlabPayload
from repro.data import generate_dataset
from repro.kernels.ivf_topk.ops import topk_ip
from repro.kernels.slab_topk.kernel import slab_topk_pallas
from repro.kernels.slab_topk.ops import NOT_PROBED, slab_topk
from repro.kernels.slab_topk.ref import slab_topk_ref
from repro.models.quantization import dequantize_rows, quantize_rows

pytestmark = pytest.mark.fast

# Table 4 ablation rows (see core/edgerag.py module docstring)
CONFIGS = {
    "embed_gen": dict(store_heavy=False, cache_bytes=0),
    "embed_gen_load": dict(store_heavy=True, cache_bytes=0),
    "edgerag": dict(store_heavy=True, cache_bytes=1 << 20),
}


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=900, dim=32, n_topics=30,
                            n_queries=32, seed=11)


def _fresh(ds, **kw):
    kw.setdefault("slo_s", 0.3)
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(), **kw)
    er.build(ds.chunk_ids, ds.texts, nlist=30, embeddings=ds.embeddings,
             seed=1)
    return er


def _per_query_loop(er, queries, k, nprobe, plan=None):
    """The pre-slab scoring path: resolve (decoded fp32), then per query
    concatenate its probed clusters in probe order and run topk_ip."""
    nq = queries.shape[0]
    if plan is None:
        plan = er.resolver.plan(er._probe(queries, nprobe))
    lats = [LatencyBreakdown() for _ in range(nq)]
    resolved = er.resolver.execute(plan, lats, [False] * nq)
    out_ids = np.full((nq, k), -1, np.int64)
    out_vals = np.full((nq, k), -np.inf, np.float32)
    for qi, probed in enumerate(plan.probed_per_q):
        if not probed:
            continue
        embs = np.concatenate([resolved[c] for c in probed])
        idmap = np.concatenate([er.clusters[c].ids for c in probed])
        if len(embs) == 0:
            continue
        vals, idx = topk_ip(embs, queries[qi:qi + 1], k)
        vals, idx = np.asarray(vals)[0], np.asarray(idx)[0]
        ok = idx >= 0
        out_vals[qi] = np.where(ok, vals, -np.inf)
        out_ids[qi] = np.where(ok, idmap[np.where(ok, idx, 0)], -1)
    return out_ids, out_vals


@pytest.mark.parametrize("cfg", list(CONFIGS))
def test_fp32_slab_bitwise_parity_vs_per_query_loop(ds, cfg):
    """The slab engine's (ids, scores) == the sequential per-query
    concat + top-k loop, bitwise, for every Table-4 ablation config."""
    nq = 16
    slab_er = _fresh(ds, **CONFIGS[cfg])
    loop_er = _fresh(ds, **CONFIGS[cfg])
    s_ids, s_vals, _ = slab_er.search_batch(ds.query_embs[:nq], 10, 5)
    l_ids, l_vals = _per_query_loop(loop_er, ds.query_embs[:nq], 10, 5)
    assert np.array_equal(s_ids, l_ids)
    assert np.array_equal(s_vals, l_vals)


def test_slab_parity_empty_probe_and_merged_away(ds):
    """A query whose probe list is empty and a cluster tombstoned between
    plan and execute (resolves to ZERO slab rows) both degrade exactly like
    the per-query loop: missing lanes pad with (-1, -inf), everything else
    stays bitwise identical."""
    nq = 8
    slab_er = _fresh(ds, **CONFIGS["edgerag"])
    loop_er = _fresh(ds, **CONFIGS["edgerag"])
    plan_s = slab_er.plan_batch(ds.query_embs[:nq], 5)
    plan_l = loop_er.resolver.plan(loop_er._probe(ds.query_embs[:nq], 5))
    assert plan_s.probed_per_q == plan_l.probed_per_q
    # query 3's probe list empties; a cluster probed by several queries
    # tombstones (as a merge would) after both plans were taken
    victim = next(c for c in plan_s.probed_per_q[0]
                  if sum(c in p for p in plan_s.probed_per_q) > 1)
    for er in (slab_er, loop_er):
        plan = plan_s if er is slab_er else plan_l
        plan.probed_per_q[3] = []
        cl = er.clusters[victim]
        cl.active = False
        cl.ids = np.zeros((0,), np.int64)
        cl.char_count = 0
        cl.generation += 1
    s_ids, s_vals, _ = slab_er.search_batch(ds.query_embs[:nq], 10, 5,
                                            plan=plan_s)
    l_ids, l_vals = _per_query_loop(loop_er, ds.query_embs[:nq], 10, 5,
                                    plan=plan_l)
    assert np.array_equal(s_ids, l_ids)
    assert np.array_equal(s_vals, l_vals)
    assert (s_ids[3] == -1).all() and (s_vals[3] == -np.inf).all()


@pytest.mark.parametrize("codec", ["fp16", "int8"])
def test_quantized_fused_dequant_parity(ds, codec):
    """fp16/int8 slabs score with fused in-kernel dequantization; scores
    match dequantize-then-score within codec tolerance (fp16 widening is
    exact; int8 differs only by where the per-row scale multiply rounds)
    and the fused-dequant seconds are charged instead of decode seconds."""
    nq = 12
    fused = _fresh(ds, slo_s=1e-6, store_heavy=True, cache_bytes=0,
                   storage_codec=codec)
    deq = _fresh(ds, slo_s=1e-6, store_heavy=True, cache_bytes=0,
                 storage_codec=codec)
    f_ids, f_vals, lats = fused.search_batch(ds.query_embs[:nq], 10, 5)
    d_ids, d_vals = _per_query_loop(deq, ds.query_embs[:nq], 10, 5)
    np.testing.assert_allclose(f_vals, d_vals, atol=2e-5, rtol=1e-5)
    overlap = np.mean([len(set(f_ids[q]) & set(d_ids[q])) / 10
                       for q in range(nq)])
    assert overlap >= 0.9
    if codec == "fp16":       # lossless widen: bit-identical either way
        assert np.array_equal(f_ids, d_ids)
        assert np.array_equal(f_vals, d_vals)
    assert sum(l.l2_fused_dequant_s for l in lats) > 0
    assert sum(l.l2_dequant_s for l in lats) == 0


def test_mixed_segment_slab_matches_per_query_loop(ds):
    """A batch whose slab mixes representations — int8 storage-tier
    clusters next to fp32 regen/cache clusters (mid-range SLO under a
    quantized codec) — exercises the cross-segment merge: results match
    the per-query dequant-then-score loop within codec tolerance."""
    nq = 12
    kw = dict(slo_s=0.1, store_heavy=True, cache_bytes=1 << 20,
              storage_codec="int8")
    slab_er = _fresh(ds, **kw)
    loop_er = _fresh(ds, **kw)
    # the config must actually produce a mixed slab, else this test rots
    plan = slab_er.plan_batch(ds.query_embs[:nq], 5)
    lats = [LatencyBreakdown() for _ in range(nq)]
    probe_slab = slab_er.resolver.execute_slab(plan, lats, [False] * nq)
    kinds = sorted(seg.kind for seg in probe_slab.segments)
    assert kinds == ["fp32", "int8"], kinds
    # fresh twins (the probe above advanced cache/threshold state)
    slab_er = _fresh(ds, **kw)
    loop_er = _fresh(ds, **kw)
    s_ids, s_vals, _ = slab_er.search_batch(ds.query_embs[:nq], 10, 5)
    l_ids, l_vals = _per_query_loop(loop_er, ds.query_embs[:nq], 10, 5)
    np.testing.assert_allclose(s_vals, l_vals, atol=2e-5, rtol=1e-5)
    overlap = np.mean([len(set(s_ids[q]) & set(l_ids[q])) / 10
                       for q in range(nq)])
    assert overlap >= 0.9
    # lane-aligned wherever scores are distinct enough to pin the order
    gap = np.abs(np.diff(l_vals, axis=1)) > 1e-4
    pinned = np.concatenate([gap, np.ones((nq, 1), bool)], axis=1) \
        & np.concatenate([np.ones((nq, 1), bool), gap], axis=1)
    assert (s_ids == l_ids)[pinned].mean() > 0.95


def test_slab_layout_packs_each_cluster_once(ds):
    """SlabLayout: every unique planned cluster appears exactly once, the
    extents tile the slab, the id slab parallels the embedding rows, and
    view() returns the packed rows."""
    er = _fresh(ds, **CONFIGS["edgerag"])
    nq = 12
    plan = er.plan_batch(ds.query_embs[:nq], 5)
    lats = [LatencyBreakdown() for _ in range(nq)]
    slab = er.resolver.execute_slab(plan, lats, [False] * nq)
    assert set(slab.extent) == set(plan.owner)
    assert len(slab.segments) == 1 and slab.segments[0].kind == "fp32"
    seg = slab.segments[0]
    covered = np.zeros(seg.rows, bool)
    for cid, (kind, off, length) in slab.extent.items():
        assert kind == "fp32"
        assert not covered[off:off + length].any()   # no overlap
        covered[off:off + length] = True
        assert length == er.clusters[cid].size
        assert np.array_equal(seg.ids[off:off + length],
                              er.clusters[cid].ids)
        view = slab.view(cid)
        assert view.base is seg.emb or view.size == 0   # a view, not a copy
        assert slab.nbytes(cid) == view.nbytes
    assert covered.all()                             # extents tile the slab
    # unique rows == sum of unique cluster sizes (each packed ONCE)
    assert seg.rows == sum(er.clusters[c].size for c in plan.owner)
    # pack cost charged once per unique cluster, to owners only
    assert sum(l.l2_slab_pack_s > 0 for l in lats) <= nq
    assert sum(l.l2_slab_pack_s for l in lats) == pytest.approx(
        sum(er.cost.slab_pack_latency(er.clusters[c].size * 32 * 4)
            for c in plan.owner))


@pytest.mark.parametrize("codec", ["fp32", "fp16", "int8", "pq"])
def test_get_many_raw_contract(ds, codec):
    """get_many_raw returns undecoded codec payloads in key order with
    None for missing keys; decode() reproduces get()."""
    er = _fresh(ds, slo_s=1e-6, store_heavy=True, cache_bytes=0,
                storage_codec=codec)
    keys = er.storage.keys()[:4]
    assert keys, "expected stored clusters under a tiny SLO"
    raw = er.storage.get_many_raw(keys + [10**9])
    assert raw[-1] is None
    for key, payload in zip(keys, raw):
        if codec == "int8":
            assert set(payload) == {"q", "scale"}
            assert payload["q"].dtype == np.int8
            assert payload["scale"].dtype == np.float16
        elif codec == "pq":
            assert set(payload) == {"codes", "cbv"}
            assert payload["codes"].dtype == np.uint8
        else:
            assert set(payload) == {"emb"}
            assert payload["emb"].dtype == (
                np.float16 if codec == "fp16" else np.float32)
        assert er.storage.payload_rows(payload) == er.clusters[key].size
        assert np.array_equal(er.storage.decode(payload),
                              er.storage.get(key))
        cb = er.storage.pq if codec == "pq" else None
        assert SlabPayload.from_raw(payload, cb).kind == codec


# ---------------------------------------------------------------------------
# ragged multi-query kernel vs oracle
# ---------------------------------------------------------------------------
def _random_slab_membership(rng, n, nq, n_clusters=6, max_probe=4):
    """Random cluster runs + per-query random probe subsets in random
    order; returns virt (Q, N) int32."""
    bounds = np.sort(rng.choice(np.arange(1, n), n_clusters - 1,
                                replace=False))
    bounds = [0, *bounds.tolist(), n]
    virt = np.full((nq, n), NOT_PROBED, np.int32)
    for q in range(nq):
        sel = rng.permutation(n_clusters)[:rng.integers(0, max_probe + 1)]
        base = 0
        for c in sel:
            o, e = bounds[c], bounds[c + 1]
            virt[q, o:e] = np.arange(base, base + (e - o))
            base += e - o
    return virt


@pytest.mark.parametrize("n,d,q,k,block_q,block_n,dtype", [
    (300, 32, 9, 7, 4, 64, "fp32"),     # ragged, every axis padded
    (256, 64, 8, 10, 8, 128, "fp32"),   # exact tiles
    (200, 32, 5, 33, 4, 64, "fp32"),    # k > some queries' member counts
    (300, 32, 6, 8, 4, 64, "fp16"),     # fused widen
    (300, 32, 6, 8, 4, 64, "int8"),     # fused per-row scales
])
def test_multiquery_slab_pallas_matches_ref(n, d, q, k, block_q, block_n,
                                            dtype):
    rng = np.random.default_rng(99)
    emb = rng.standard_normal((n, d)).astype(np.float32)
    qs = rng.standard_normal((q, d)).astype(np.float32)
    virt = _random_slab_membership(rng, n, q)
    scales = None
    if dtype == "fp16":
        emb = emb.astype(np.float16)
    elif dtype == "int8":
        emb, sc = quantize_rows(emb)
        scales = sc.astype(np.float32)
    keff = min(k, n)
    pv, pr = slab_topk_pallas(emb, qs, virt, keff, scales,
                              block_n=block_n, block_q=block_q,
                              interpret=True)
    rv, rr = slab_topk_ref(emb, qs, virt, keff, scales)
    pv, pr = np.asarray(pv), np.asarray(pr)
    rv, rr = np.asarray(rv), np.asarray(rr)
    valid = rv > -1e29               # lanes with a real candidate
    assert np.array_equal(pr[valid], rr[valid])
    np.testing.assert_allclose(pv[valid], rv[valid], atol=2e-4)
    assert (pv[~valid] <= -1e29).all()


def test_slab_ref_equals_concat_topk_oracle():
    """The (score desc, virt asc) selection == lax.top_k over each query's
    virtual concatenation — the exact contract the engine relies on."""
    rng = np.random.default_rng(3)
    n, d, nq, k = 257, 32, 7, 9
    emb = rng.standard_normal((n, d)).astype(np.float32)
    qs = rng.standard_normal((nq, d)).astype(np.float32)
    virt = _random_slab_membership(rng, n, nq)
    vals, rows = slab_topk(emb, qs, virt, k, impl="ref")
    vals, rows = np.asarray(vals), np.asarray(rows)
    for q in range(nq):
        member = np.where(virt[q] < NOT_PROBED)[0]
        order = member[np.argsort(virt[q][member])]   # virtual concat order
        if len(order) == 0:
            assert (vals[q] <= -1e29).all()
            continue
        rv, ri = topk_ip(emb[order], qs[q:q + 1], min(k, len(order)))
        rv, ri = np.asarray(rv)[0], np.asarray(ri)[0]
        kk = len(rv)
        assert np.array_equal(vals[q][:kk], rv)
        assert np.array_equal(rows[q][:kk], order[ri])


# ---------------------------------------------------------------------------
# PQ LUT scoring differentials (core/pq.py + the kernels' fourth
# representation)
# ---------------------------------------------------------------------------
def test_pq_lut_scoring_matches_decode_then_exact(ds):
    """PQ ADC scoring (ref AND Pallas) over a clustered slab: Pallas is
    bit-identical to the ref path, both agree with decode-then-fp32-exact
    scoring of the same codes to fp32 tolerance, and the selected rows
    overlap the TRUE fp32 top-k by >= 0.9 per query."""
    from repro.core.pq import pq_decode, pq_encode, pq_luts, train_pq
    emb = ds.embeddings.astype(np.float32)
    cb = train_pq(emb, m=16, iters=10, seed=3)
    codes = pq_encode(cb, emb)
    n, nq, k = emb.shape[0], 12, 10
    rng = np.random.default_rng(7)
    virt = _random_slab_membership(rng, n, nq)
    qs = ds.query_embs[:nq]
    luts = pq_luts(cb, qs)
    rv, rr = map(np.asarray, slab_topk(codes, qs, virt, k,
                                       luts=luts, impl="ref"))
    pv, pr = map(np.asarray, slab_topk_pallas(codes, qs, virt, k,
                                              None, luts, interpret=True))
    dv, dr = map(np.asarray,
                 slab_topk_ref(pq_decode(cb, codes), qs, virt, k))
    ev, er = map(np.asarray, slab_topk_ref(emb, qs, virt, k))
    valid = rv > -1e29
    assert (pv[~valid] <= -1e29).all()
    # Pallas one-hot-matmul gather == jnp take gather, bitwise
    assert np.array_equal(pr[valid], rr[valid])
    assert np.array_equal(pv[valid], rv[valid])
    # LUT accumulate == decode-then-dot on the same codes, fp32 tolerance
    np.testing.assert_allclose(rv[valid], dv[valid], atol=2e-5)
    assert np.array_equal(rr[valid], dr[valid])
    # clustered data: PQ top-k tracks the unquantized fp32 top-k
    for q in range(nq):
        truth = set(er[q][ev[q] > -1e29].tolist())
        if truth:
            got = set(rr[q][rv[q] > -1e29].tolist())
            assert len(got & truth) / len(truth) >= 0.9


def test_mixed_four_representation_slab_matches_per_segment_merge(ds):
    """A synthetic slab holding all FOUR representations at once: the
    engine's fused multi-segment scoring (slab_score_topk) is bit-identical
    to scoring each representation's segment separately and merging the
    candidates under the (score desc, virt asc) order."""
    from repro.core.edgerag import slab_score_topk
    from repro.core.pq import pq_encode, pq_luts, train_pq
    from repro.core.resolver import SlabLayout
    emb = ds.embeddings.astype(np.float32)
    cb = train_pq(emb, m=16, iters=8, seed=5)
    n, nq, k, dim = emb.shape[0], 10, 9, emb.shape[1]
    rng = np.random.default_rng(21)
    bounds = [0, *np.sort(rng.choice(np.arange(1, n), 7,
                                     replace=False)).tolist(), n]
    kinds = ["fp32", "fp16", "int8", "pq", "pq", "int8", "fp16", "fp32"]
    payloads, ids_of_map = {}, {}
    for cid, kind in enumerate(kinds):
        rows = emb[bounds[cid]:bounds[cid + 1]]
        ids_of_map[cid] = np.arange(bounds[cid], bounds[cid + 1], dtype=np.int64)
        if kind == "fp32":
            payloads[cid] = SlabPayload("fp32", rows)
        elif kind == "fp16":
            payloads[cid] = SlabPayload("fp16", rows.astype(np.float16))
        elif kind == "int8":
            q8, sc = quantize_rows(rows)
            payloads[cid] = SlabPayload("int8", q8,
                                        sc.astype(np.float32))
        else:
            payloads[cid] = SlabPayload("pq", pq_encode(cb, rows),
                                        codebook=cb)
    order = list(range(len(kinds)))
    slab = SlabLayout.pack(dim, order, payloads, lambda c: ids_of_map[c])
    assert sorted(seg.kind for seg in slab.segments) == \
        ["fp16", "fp32", "int8", "pq"]
    probed = [list(rng.permutation(len(kinds))[:int(rng.integers(1, 7))])
              for _ in range(nq)]
    qs = ds.query_embs[:nq]
    got_ids, got_vals, n_valid = slab_score_topk(slab, qs, k, probed)
    # reference: one kernel launch PER representation, then an independent
    # lexsort merge of the per-segment candidates
    virts, ref_n_valid, n_valid_seg = slab.query_layout(probed)
    cv, ct, ci = [], [], []
    lane = np.arange(k)[None, :]
    for seg in slab.segments:
        luts = pq_luts(seg.codebook, qs) if seg.kind == "pq" else None
        vals, rows = map(np.asarray, slab_topk(
            seg.emb, qs, virts[seg.kind], k, scales=seg.scales, luts=luts))
        ok = lane < n_valid_seg[seg.kind][:, None]
        rows = np.where(ok, rows, 0)
        cv.append(np.where(ok, vals, -np.inf))
        ci.append(np.where(ok, seg.ids[rows], -1))
        ct.append(np.where(ok, virts[seg.kind][np.arange(nq)[:, None], rows],
                           np.int32(NOT_PROBED)))
    cv, ct, ci = (np.concatenate(a, axis=1) for a in (cv, ct, ci))
    merge = np.lexsort((ct, -cv), axis=1)[:, :k]
    ref_vals = np.take_along_axis(cv, merge, axis=1)
    ref_ids = np.take_along_axis(ci, merge, axis=1)
    assert np.array_equal(got_vals, ref_vals)
    assert np.array_equal(got_ids, ref_ids)
    assert np.array_equal(n_valid, ref_n_valid)


def test_mixed_pq_and_fp32_batch_matches_per_query_loop(ds):
    """End-to-end mid-SLO pq-codec index: the batch slab mixes pq storage
    segments with fp32 regen/cache segments; results match the per-query
    decode-then-score loop within PQ tolerance on the scores it can
    reproduce (both paths decode the SAME codes, so ids track wherever the
    score order is pinned)."""
    nq = 12
    kw = dict(slo_s=0.1, store_heavy=True, cache_bytes=1 << 20,
              storage_codec="pq")
    slab_er = _fresh(ds, **kw)
    plan = slab_er.plan_batch(ds.query_embs[:nq], 5)
    lats = [LatencyBreakdown() for _ in range(nq)]
    probe_slab = slab_er.resolver.execute_slab(plan, lats, [False] * nq)
    kinds = sorted(seg.kind for seg in probe_slab.segments)
    assert kinds == ["fp32", "pq"], kinds
    slab_er = _fresh(ds, **kw)
    loop_er = _fresh(ds, **kw)
    s_ids, s_vals, lats = slab_er.search_batch(ds.query_embs[:nq], 10, 5)
    l_ids, l_vals = _per_query_loop(loop_er, ds.query_embs[:nq], 10, 5)
    np.testing.assert_allclose(s_vals, l_vals, atol=2e-5, rtol=1e-5)
    overlap = np.mean([len(set(s_ids[q]) & set(l_ids[q])) / 10
                       for q in range(nq)])
    assert overlap >= 0.9
    # pq cost fields charged; dequant fields untouched by pq segments
    assert sum(l.l2_pq_lut_s for l in lats) > 0
    assert sum(l.l2_pq_gather_s for l in lats) > 0
    assert sum(l.l2_fused_dequant_s for l in lats) == 0


# ---------------------------------------------------------------------------
# lazy-decay LFU == eager reference
# ---------------------------------------------------------------------------
class _EagerLFU:
    """The pre-optimization implementation: O(n) decay walk per access and
    a full byte scan per insert — the behavioral oracle."""

    def __init__(self, capacity_bytes, decay_factor):
        self.capacity = capacity_bytes
        self.f = decay_factor
        self.entries = {}            # cid -> [nbytes, gen, counter]
        self.hits = self.misses = self.evictions = 0

    def total_bytes(self):
        return sum(e[0] for e in self.entries.values())

    def access(self, cid):
        if cid in self.entries:
            self.entries[cid][2] += 1.0
            self.hits += 1
            out = True
        else:
            self.misses += 1
            out = False
        for e in self.entries.values():
            e[2] *= self.f
        return out

    def insert(self, cid, nbytes, gen, thr=0.0):
        if gen < thr or nbytes > self.capacity:
            return
        # the seed implementation overwrote WITHOUT releasing first: the
        # eviction loop counts the old entry's bytes and may evict it
        while self.total_bytes() + nbytes > self.capacity:
            if not self.entries:
                return
            victim = min(self.entries,
                         key=lambda i: (self.entries[i][1]
                                        * self.entries[i][2]))
            del self.entries[victim]
            self.evictions += 1
        self.entries[cid] = [nbytes, gen, 1.0]

    def drop_below(self, thr):
        for cid in [c for c, e in self.entries.items() if e[1] < thr]:
            del self.entries[cid]
            self.evictions += 1

    def invalidate(self, cid):
        self.entries.pop(cid, None)


def test_lazy_decay_cache_matches_eager_reference():
    """Randomized op-sequence equivalence: membership, running byte total,
    hit/miss/eviction counts all match the eager O(n)-per-access oracle."""
    rng = np.random.default_rng(7)
    cache = CostAwareLFUCache(capacity_bytes=40 * 32, decay_factor=0.9)
    ref = _EagerLFU(40 * 32, 0.9)
    for step in range(600):
        op = rng.random()
        cid = int(rng.integers(0, 30))
        if op < 0.45:
            got = cache.access(cid)
            assert (got is not None) == ref.access(cid)
        elif op < 0.8:
            n_rows = int(rng.integers(1, 9))
            emb = np.ones((n_rows, 8), np.float32)      # 32 B per row
            gen = float(rng.random() + 0.01)
            thr = float(rng.random() * 0.2)
            cache.insert(cid, emb, gen, min_latency_threshold=thr)
            ref.insert(cid, emb.nbytes, gen, thr)
        elif op < 0.9:
            cache.invalidate(cid)
            ref.invalidate(cid)
        else:
            thr = float(rng.random() * 0.3)
            cache.drop_below_threshold(thr)
            ref.drop_below(thr)
        assert set(cache._entries) == set(ref.entries), step
        assert cache.total_bytes() == ref.total_bytes(), step
        assert (cache.hits, cache.misses, cache.evictions) == \
            (ref.hits, ref.misses, ref.evictions), step
