"""Pod-sharded retrieval == single-device reference (8-device subprocess)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.sharded_retrieval import ShardedFlatSearch, sharded_topk_ip
from repro.kernels.ivf_topk.ref import topk_ip_ref

mesh = jax.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
for n, d, q, k in [(1000, 64, 3, 10), (63, 32, 1, 5), (4096, 128, 2, 32)]:
    embs = rng.standard_normal((n, d)).astype(np.float32)
    qs = rng.standard_normal((q, d)).astype(np.float32)
    srch = ShardedFlatSearch(embs, mesh)
    vals, idx = srch.search(qs, k)
    rv, ri = topk_ip_ref(jnp.asarray(embs), jnp.asarray(qs), k)
    assert np.allclose(vals, np.asarray(rv), atol=1e-4), (n, k)
    # indices may tie-swap at equal scores; compare score-sets strictly
    assert np.allclose(np.sort(vals, 1), np.sort(np.asarray(rv), 1), atol=1e-4)
    assert (idx == np.asarray(ri)).mean() > 0.95, (n, k)
print("sharded retrieval OK")

# EdgeRAG sharded scoring mode: search_batch(mesh=...) row-shards the batch
# slab through sharded_slab_topk (one collective per batch per
# representation); fp32 tier must match unsharded ids.
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset

ds = generate_dataset(n_records=600, dim=32, n_topics=20, n_queries=8, seed=3)
def fresh():
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.15, cache_bytes=1 << 20)
    er.build(ds.chunk_ids, ds.texts, nlist=20, embeddings=ds.embeddings,
             seed=1)
    return er
ids_u, _, _ = fresh().search_batch(ds.query_embs, 10, 5)
ids_s, _, _ = fresh().search_batch(ds.query_embs, 10, 5, mesh=mesh)
assert np.array_equal(ids_u, ids_s)
print("edgerag sharded mode OK")
'''


def test_sharded_retrieval_matches_reference():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "sharded retrieval OK" in res.stdout
    assert "edgerag sharded mode OK" in res.stdout
