"""StorageBackend: codec roundtrips (memory + disk), get_many ordering,
missing-file recovery through the resolver's regeneration fallback, clear(),
memory-mode root guard, and quantized byte reduction."""
import os

import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.storage import CODECS, StorageBackend
from repro.data import generate_dataset

pytestmark = pytest.mark.fast


def _emb(n=40, d=64, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((n, d)).astype(np.float32)
    return e / np.linalg.norm(e, axis=1, keepdims=True)


@pytest.mark.parametrize("mode", ["memory", "disk", "memmap"])
@pytest.mark.parametrize("codec", CODECS)
def test_roundtrip_all_codecs(mode, codec, tmp_path):
    root = str(tmp_path) if mode != "memory" else None
    s = StorageBackend(mode, root=root, codec=codec)
    emb = _emb()
    s.put(3, emb)
    out = s.get(3)
    assert out.dtype == np.float32 and out.shape == emb.shape
    if codec == "fp32":
        assert np.array_equal(out, emb)          # bit-exact
    elif codec == "pq":
        # n <= 256 training rows: every row owns a centroid -> exact
        np.testing.assert_allclose(out, emb, atol=1e-6)
    else:
        atol = 1e-3 if codec == "fp16" else 0.05
        np.testing.assert_allclose(out, emb, atol=atol)


def test_get_many_ordering_and_missing(tmp_path):
    s = StorageBackend("disk", root=str(tmp_path))
    mats = {k: _emb(seed=k) for k in (5, 1, 9)}
    for k, m in mats.items():
        s.put(k, m)
    out = s.get_many([9, 77, 1, 5])
    assert out[1] is None                         # missing key -> None
    assert np.array_equal(out[0], mats[9])
    assert np.array_equal(out[2], mats[1])
    assert np.array_equal(out[3], mats[5])
    with pytest.raises(KeyError):
        s.get(77)


@pytest.mark.parametrize("mode", ["memory", "disk"])
def test_clear(mode, tmp_path):
    root = str(tmp_path) if mode == "disk" else None
    s = StorageBackend(mode, root=root)
    for k in range(4):
        s.put(k, _emb(n=5, seed=k))
    assert len(s.keys()) == 4 and s.total_bytes() > 0
    s.clear()
    assert s.keys() == [] and s.total_bytes() == 0
    if mode == "disk":
        assert not any(f.endswith(".npz") for f in os.listdir(root))


@pytest.mark.parametrize("codec", CODECS)
def test_reopened_root_is_metadata_only(codec, tmp_path):
    """A fresh StorageBackend on an existing root reports exact payload
    sizes (parsed from npy headers, no array reads) and still decodes."""
    a = StorageBackend("disk", root=str(tmp_path), codec=codec)
    sizes = {k: a.put(k, _emb(n=10 + k, seed=k)) for k in (1, 2)}
    b = StorageBackend("disk", root=str(tmp_path), codec=codec)
    assert {k: b.stored_bytes(k) for k in (1, 2)} == sizes
    assert b.total_bytes() == sum(sizes.values())
    assert np.array_equal(b.get(1), a.get(1))
    with pytest.raises(KeyError):
        b.stored_bytes(99)


@pytest.mark.parametrize("mode,codec", [("disk", "fp32"), ("memmap", "pq")])
def test_byte_accounting_does_no_read_io(mode, codec, tmp_path, monkeypatch):
    """stored_bytes/total_bytes charge ``os.stat`` file sizes, never a
    payload read: with every array loader booby-trapped, byte accounting
    on a reopened root still reports exact sizes and no read I/O."""
    import zipfile
    a = StorageBackend(mode, root=str(tmp_path), codec=codec)
    sizes = {k: a.put(k, _emb(n=10 + k, seed=k)) for k in (1, 2)}
    b = StorageBackend(mode, root=str(tmp_path), codec=codec)

    def no_read(*args, **kw):
        raise AssertionError("byte accounting loaded a payload")

    monkeypatch.setattr(np, "load", no_read)
    monkeypatch.setattr(zipfile, "ZipFile", no_read)
    assert {k: b.stored_bytes(k) for k in (1, 2)} == sizes
    assert b.total_bytes() == sum(sizes.values())
    for k in (1, 2):
        assert b.stored_bytes(k) == os.path.getsize(b._path(k))
    assert b.io_stats["reads"] == 0
    with pytest.raises(KeyError):
        b.stored_bytes(99)


def test_foreign_files_in_root_are_ignored(tmp_path):
    """keys()/clear()/total_bytes tolerate unrelated files in a
    user-supplied storage root."""
    s = StorageBackend("disk", root=str(tmp_path))
    s.put(4, _emb(n=6))
    (tmp_path / "data.npz").write_bytes(b"not ours")
    (tmp_path / "cluster_backup.npz").write_bytes(b"not ours")
    assert s.keys() == [4]
    assert s.total_bytes() == s.stored_bytes(4)
    s.clear()
    assert s.keys() == []
    assert (tmp_path / "data.npz").exists()       # untouched


def test_memory_mode_never_touches_root():
    s = StorageBackend("memory")
    assert s.root is None
    assert s.keys() == [] and s.total_bytes() == 0
    s.put(0, _emb(n=3))
    s.delete(0)
    assert 1 not in s
    with pytest.raises(RuntimeError):
        s._path(0)


def test_quantized_byte_reduction():
    """fp16 halves the payload exactly; int8 approaches 4x (per-row fp16
    scales cost 2 B against 4·d B of fp32 rows)."""
    emb = _emb(n=128, d=64)
    sizes = {c: StorageBackend("memory", codec=c).put(0, emb)
             for c in CODECS}
    assert sizes["fp32"] == emb.nbytes
    assert sizes["fp32"] / sizes["fp16"] == 2.0
    assert sizes["fp32"] / sizes["int8"] >= 3.5


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=700, dim=32, n_topics=24,
                            n_queries=16, seed=11)


def _fresh(ds, **kw):
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.05, **kw)   # tiny SLO: most clusters stored
    er.build(ds.chunk_ids, ds.texts, nlist=24, embeddings=ds.embeddings,
             seed=1)
    return er


def test_disk_missing_file_falls_back_to_regen(ds, tmp_path):
    """Deleting cluster files behind the index's back degrades to online
    regeneration — same results, no crash — and the first search
    re-persists the vanished copies (Alg. 1 self-heal), so later searches
    load from storage again."""
    ref = _fresh(ds)
    er = _fresh(ds, storage_mode="disk", storage_root=str(tmp_path / "s"))
    assert er.storage.keys()
    for f in os.listdir(er.storage.root):
        os.remove(os.path.join(er.storage.root, f))
    r_ids, r_vals, _ = ref.search(ds.query_embs[0], 10, 5)
    ids, vals, lat = er.search(ds.query_embs[0], 10, 5)
    assert np.array_equal(ids, r_ids)
    assert np.array_equal(vals, r_vals)
    assert lat.n_storage_loads == 0 and lat.n_generated > 0
    assert er.storage.keys()             # healed copies persisted
    # the same query now loads every probed cluster from storage again
    r_ids2, r_vals2, _ = ref.search(ds.query_embs[0], 10, 5)
    ids2, vals2, lat2 = er.search(ds.query_embs[0], 10, 5)
    assert np.array_equal(ids2, r_ids2)
    assert np.array_equal(vals2, r_vals2)
    assert lat2.n_generated == 0
    assert lat2.n_storage_loads > 0      # healed clusters load again
    # every probed cluster resolves without regeneration now
    assert (lat2.n_storage_loads + lat2.n_cache_hits
            == lat2.n_clusters_probed)


def test_stale_plan_storage_key_falls_back(ds, tmp_path):
    """A storage key that vanishes between plan and execute reroutes to the
    regeneration group instead of crashing (resolver fallback)."""
    ref = _fresh(ds)
    er = _fresh(ds, storage_mode="disk", storage_root=str(tmp_path / "s"))
    plan = er.plan_batch(ds.query_embs[:6], 5)
    assert plan.storage_clusters
    for f in os.listdir(er.storage.root):
        os.remove(os.path.join(er.storage.root, f))
    ids, vals, lats = er.search_batch(ds.query_embs[:6], 10, 5, plan=plan)
    r_ids, r_vals, _ = ref.search_batch(ds.query_embs[:6], 10, 5)
    assert np.array_equal(ids, r_ids)
    assert np.array_equal(vals, r_vals)
    # the vanished storage clusters were regenerated, not loaded
    assert sum(l.n_storage_loads for l in lats) == 0
    assert sum(l.n_generated for l in lats) >= len(plan.storage_clusters)


def test_rebuild_clears_stale_storage(ds):
    """build() wipes the previous build's stored clusters, so storage never
    accumulates orphans across rebuilds."""
    er = _fresh(ds)
    first_keys = set(er.storage.keys())
    assert first_keys
    er.threshold.threshold = 0.5          # adapted to the old corpus
    er.build(ds.chunk_ids, ds.texts, nlist=12, embeddings=ds.embeddings,
             seed=2)
    stored_now = {cid for cid, cl in enumerate(er.clusters) if cl.stored}
    assert set(er.storage.keys()) == stored_now
    assert er.storage_bytes() == sum(
        er.storage.stored_bytes(k) for k in stored_now)
    # the learned Alg. 3 threshold resets with the corpus
    assert er.threshold.threshold == 0.0
    assert len(er.cache) == 0


# ----------------------------------------------------------------------
# multi-tenancy: namespacing, collision guard, shared budget, views
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["memory", "disk"])
def test_tuple_keys_namespace_tenants(mode, tmp_path):
    """(tenant, cid) keys coexist with bare-int keys; disk mode lands
    them in tenant_<name>/ subdirectories and keys() enumerates both."""
    root = str(tmp_path) if mode == "disk" else None
    s = StorageBackend(mode, root=root)
    a = _emb(n=6, seed=1)
    b = _emb(n=7, seed=2)
    s.put(3, _emb(n=5, seed=0))
    s.put(("alice", 3), a)
    s.put(("bob", 3), b)                 # same cid, different tenant
    assert set(s.keys()) == {3, ("alice", 3), ("bob", 3)}
    assert np.array_equal(s.get(("alice", 3)), a)
    assert np.array_equal(s.get(("bob", 3)), b)
    if mode == "disk":
        assert os.path.exists(
            os.path.join(root, "tenant_alice", "cluster_3.npz"))
    s.delete(("alice", 3))
    assert ("alice", 3) not in s and ("bob", 3) in s and 3 in s


def test_disk_collision_guard_blocks_second_writer(tmp_path):
    """Two LIVE writers on one (root, namespace) slot: the second put
    raises instead of silently interleaving blobs.  Distinct namespaces
    co-locate cleanly; read-only reopens never claim."""
    a = StorageBackend("disk", root=str(tmp_path))
    a.put(1, _emb(n=4))
    b = StorageBackend("disk", root=str(tmp_path))
    with pytest.raises(RuntimeError, match="collision"):
        b.put(2, _emb(n=4))
    # read-only access through a second instance stays legal
    assert np.array_equal(b.get(1), a.get(1))
    assert b.total_bytes() == a.total_bytes()
    # distinct namespaces under the same root: both writers allowed
    c = StorageBackend("disk", root=str(tmp_path), namespace="svc_a")
    d = StorageBackend("disk", root=str(tmp_path), namespace="svc_b")
    assert c.put(1, _emb(n=4)) > 0
    assert d.put(1, _emb(n=4)) > 0
    assert c.keys() == [1] and d.keys() == [1]    # scoped enumerations


def test_disk_collision_claim_dies_with_writer(tmp_path):
    a = StorageBackend("disk", root=str(tmp_path))
    a.put(1, _emb(n=4))
    del a                                # claim is a weakref: released
    b = StorageBackend("disk", root=str(tmp_path))
    assert b.put(2, _emb(n=4)) > 0       # new sole writer


def test_shared_budget_refuses_put(tmp_path):
    """budget_bytes is a SHARED quota across all tenants: an over-budget
    put stores nothing, returns 0, and bumps put_rejected."""
    emb = _emb(n=10, d=64)               # 2560 B fp32
    s = StorageBackend("memory", budget_bytes=3 * emb.nbytes)
    assert s.put(("a", 0), emb) == emb.nbytes
    assert s.put(("a", 1), emb) == emb.nbytes
    assert s.put(("b", 0), emb) == emb.nbytes
    rej = s.put(("b", 1), emb)           # 4th would exceed the quota
    assert rej == 0
    assert ("b", 1) not in s
    assert s.io_stats["put_rejected"] == 1
    assert s.total_bytes() == 3 * emb.nbytes
    # re-putting an EXISTING key charges the delta, not double
    assert s.put(("a", 0), emb) == emb.nbytes
    assert s.total_bytes() == 3 * emb.nbytes


def test_clear_removes_codebook_file_and_stale_tmps(tmp_path):
    """Regression: clear() used to leave the persisted pq_codebook.npz and
    crashed-put ``.tmp`` files on disk — a rebuild on the root would decode
    against the stale codebook version and trip over torn garbage.  Only
    OUR tmp names are swept; foreign files stay untouched."""
    s = StorageBackend("disk", root=str(tmp_path), codec="pq")
    s.put(0, _emb(n=40))                 # lazy-trains + persists codebook
    cb = tmp_path / "pq_codebook.npz"
    assert cb.exists()
    tdir = tmp_path / "tenant_a"
    tdir.mkdir()
    stale = [tmp_path / "cluster_7.npz.tmp", tmp_path / "pq_codebook.npz.tmp",
             tdir / "cluster_0.npz.tmp"]
    foreign = [tmp_path / "backup.npz.tmp", tmp_path / "notes.tmp"]
    for p in stale + foreign:
        p.write_bytes(b"torn")
    s.clear()
    assert not cb.exists()               # no leftover codebook version
    assert not any(p.exists() for p in stale)
    assert all(p.exists() for p in foreign)
    assert s.pq is not None              # in-memory codebook survives:
    v = s.pq.version                     # rebuild's retrain bumps it
    s.train_pq(_emb(n=40, seed=3))
    assert s.pq.version > v


def test_delete_sweeps_stranded_tmp(tmp_path):
    """Regression: a put that crashed mid-write strands ``<blob>.tmp``;
    delete() must take the temp file down with the blob."""
    s = StorageBackend("disk", root=str(tmp_path))
    s.put(3, _emb(n=5))
    tmp = tmp_path / "cluster_3.npz.tmp"
    tmp.write_bytes(b"torn half-write")
    s.delete(3)
    assert not (tmp_path / "cluster_3.npz").exists()
    assert not tmp.exists()
    s.delete(3)                          # idempotent on a gone key


@pytest.mark.parametrize("mode", ["memory", "disk"])
def test_payload_crc_no_payload_read(mode, tmp_path):
    """payload_crc returns the put-time checksum without decoding the
    payload; a fresh instance on an old root lazily reads just the crc
    member; absent keys raise KeyError."""
    root = str(tmp_path) if mode == "disk" else None
    s = StorageBackend(mode, root=root)
    emb = _emb(n=8, seed=4)
    s.put(2, emb)
    crc = s.payload_crc(2)
    assert crc == s.payload_crc(2)       # cached, stable
    if mode == "disk":
        b = StorageBackend(mode, root=root)
        assert b.payload_crc(2) == crc   # lazy member read on reopen
    with pytest.raises(KeyError):
        s.payload_crc(99)
    s.put(2, _emb(n=8, seed=5))          # re-put changes the content...
    assert s.payload_crc(2) != crc       # ...and therefore the crc


def test_tenant_view_scopes_keys_and_clear(tmp_path):
    shared = StorageBackend("disk", root=str(tmp_path))
    from repro.core.storage import TenantStorageView
    va = TenantStorageView(shared, "a")
    vb = TenantStorageView(shared, "b")
    ea, eb = _emb(n=4, seed=1), _emb(n=9, seed=2)
    va.put(0, ea)
    va.put(1, ea)
    vb.put(0, eb)
    assert sorted(va.keys()) == [0, 1] and vb.keys() == [0]
    assert np.array_equal(vb.get(0), eb)          # no cross-tenant bleed
    # disk bytes are os.stat file sizes (payload + npz container)
    sa, sb = shared.stored_bytes(("a", 0)), shared.stored_bytes(("b", 0))
    assert sa >= ea.nbytes and sb >= eb.nbytes
    assert va.total_bytes() == 2 * sa
    assert vb.total_bytes() == sb
    with pytest.raises(KeyError):
        vb.get(1)                                 # a's cid 1 is invisible
    out = vb.get_many([0, 1])
    assert np.array_equal(out[0], eb) and out[1] is None
    va.clear()                                    # scoped: b untouched
    assert va.keys() == [] and vb.keys() == [0]
    assert shared.tenant_bytes("a") == 0
    assert shared.tenant_bytes("b") == sb
