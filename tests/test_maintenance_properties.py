"""Hypothesis: EdgeRAG online-maintenance invariants under random
insert/remove sequences (§5.4)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset

_DS = generate_dataset(n_records=400, dim=24, n_topics=12, n_queries=10,
                       seed=11)


def _fresh_index():
    er = EdgeRAGIndex(24, _DS.embedder, _DS.get_chunks, EdgeCostModel(),
                      slo_s=0.2, cache_bytes=1 << 18,
                      split_max_chars=30_000, merge_min_size=2)
    er.build(_DS.chunk_ids, _DS.texts, nlist=12, embeddings=_DS.embeddings)
    return er


@settings(max_examples=12, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), st.integers(0, 399)),
                    min_size=1, max_size=25),
       seed=st.integers(0, 10_000))
def test_insert_remove_invariants(ops, seed):
    er = _fresh_index()
    rng = np.random.default_rng(seed)
    live = set(int(i) for i in _DS.chunk_ids)
    next_id = 500_000 + seed * 1000
    for is_insert, target in ops:
        if is_insert:
            base = _DS.embeddings[target]
            emb = base + 0.05 * rng.standard_normal(24)
            emb = (emb / np.linalg.norm(emb)).astype(np.float32)
            text = f"doc-{next_id} " + "tok " * int(rng.integers(2, 40))
            _DS.add_chunk(next_id, text, emb)
            er.insert(next_id, text)
            live.add(next_id)
            next_id += 1
        elif target in live and target < 400:
            er.remove(target)
            live.discard(target)
        # --- invariants after every op ---
        assert er.ntotal == len(live)
        total_ids = np.concatenate(
            [c.ids for c in er.clusters if c.active]
            or [np.zeros(0, np.int64)])
        assert len(total_ids) == len(set(total_ids.tolist()))  # no dupes
        assert set(int(i) for i in total_ids) == live          # exact set
        for cid, c in enumerate(er.clusters):
            if not c.active:
                assert c.size == 0
                continue
            # Alg-1 invariant: stored <=> regeneration cost over SLO
            assert c.stored == (c.gen_latency_est > er.slo_s), cid
            assert c.stored == (cid in er.storage)
    # index remains searchable and returns only live ids
    ids, _, _ = er.search(_DS.query_embs[0], 8, er.nlist)
    assert all(int(i) in live for i in ids[0] if i >= 0)
