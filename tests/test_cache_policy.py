"""Algorithm 2 (cost-aware LFU) and Algorithm 3 (adaptive threshold) unit
behaviour, exactly as specified in the paper."""
import numpy as np

from repro.core.cache_policy import (CostAwareLFUCache,
                                     MinLatencyThresholdController)


def _emb(n=4):
    return np.ones((n, 8), np.float32)  # 128 B each


def test_lfu_evicts_min_weight():
    """eviction victim = argmin(genLatency * counter)."""
    cache = CostAwareLFUCache(capacity_bytes=3 * 32, decay_factor=1.0)
    cache.insert(1, _emb(1), gen_latency=1.0)
    cache.insert(2, _emb(1), gen_latency=10.0)
    cache.insert(3, _emb(1), gen_latency=5.0)
    cache.access(1)  # counter(1)=2 -> weight 2.0
    # weights: 1: 1*2=2, 2: 10*1=10, 3: 5*1=5  -> evict 1
    cache.insert(4, _emb(1), gen_latency=2.0)
    assert 1 not in cache and 2 in cache and 3 in cache and 4 in cache


def test_counter_decay_ages_out_stale_entries():
    cache = CostAwareLFUCache(capacity_bytes=2 * 32, decay_factor=0.5)
    cache.insert(1, _emb(1), gen_latency=1.0)
    cache.insert(2, _emb(1), gen_latency=1.0)
    for _ in range(6):
        cache.access(2)   # keeps 2 hot; 1's counter halves every access
    cache.insert(3, _emb(1), gen_latency=1.0)
    assert 1 not in cache and 2 in cache


def test_capacity_never_exceeded():
    cache = CostAwareLFUCache(capacity_bytes=1000)
    for i in range(50):
        cache.insert(i, _emb(2), gen_latency=float(i + 1))
        assert cache.total_bytes() <= 1000


def test_threshold_blocks_cheap_insert():
    cache = CostAwareLFUCache(capacity_bytes=10_000)
    cache.insert(1, _emb(1), gen_latency=0.05, min_latency_threshold=0.1)
    assert 1 not in cache
    cache.insert(2, _emb(1), gen_latency=0.5, min_latency_threshold=0.1)
    assert 2 in cache


def test_drop_below_threshold():
    cache = CostAwareLFUCache(capacity_bytes=10_000)
    cache.insert(1, _emb(1), gen_latency=0.05)
    cache.insert(2, _emb(1), gen_latency=0.50)
    cache.drop_below_threshold(0.1)
    assert 1 not in cache and 2 in cache


def test_alg3_threshold_dynamics():
    """miss + below-average latency => threshold rises; hit => falls."""
    ctl = MinLatencyThresholdController(step_s=0.01)
    ctl.observe(cache_miss=True, last_latency=1.0)   # init avg
    t1 = ctl.observe(cache_miss=True, last_latency=0.1)   # cheap miss -> up
    assert t1 > 0
    t2 = ctl.observe(cache_miss=False, last_latency=0.1)  # hit -> down
    assert t2 < t1
    # threshold never negative
    for _ in range(10):
        t = ctl.observe(cache_miss=False, last_latency=0.1)
    assert t == 0.0


def test_alg3_expensive_miss_does_not_raise():
    ctl = MinLatencyThresholdController(step_s=0.01)
    ctl.observe(cache_miss=True, last_latency=0.1)
    t = ctl.observe(cache_miss=True, last_latency=5.0)  # costly miss
    assert t == 0.0


def test_moving_average_tracks():
    ctl = MinLatencyThresholdController(ema_alpha=0.5)
    ctl.observe(cache_miss=False, last_latency=1.0)
    ctl.observe(cache_miss=False, last_latency=0.0)
    assert abs(ctl.moving_avg_latency - 0.5) < 1e-9


# ----------------------------------------------------------------------
# shared-budget multi-tenancy (tuple keys on ONE cache)
# ----------------------------------------------------------------------
def test_shared_budget_eviction_is_tenant_blind():
    """Eviction is one global argmin(gen_latency x counter): a cold
    tenant's entries lose to a hot tenant's regardless of who inserted
    last — tenants compete exactly as clusters do in the paper."""
    cache = CostAwareLFUCache(capacity_bytes=4 * 32, decay_factor=1.0)
    for cid in range(3):
        cache.insert(("hot", cid), _emb(1), gen_latency=1.0)
    cache.insert(("cold", 0), _emb(1), gen_latency=1.0)
    for _ in range(3):
        for cid in range(3):
            cache.access(("hot", cid))
    # full cache; cold's weight (1*1) is the global minimum
    cache.insert(("hot", 3), _emb(1), gen_latency=1.0)
    assert ("cold", 0) not in cache
    assert all(("hot", c) in cache for c in range(4))
    assert cache.per_tenant["cold"]["evictions"] == 1
    assert cache.per_tenant["hot"]["evictions"] == 0


def test_shared_budget_skewed_access_fairness():
    """Two tenants with identical workloads but skewed access frequency:
    the busy tenant ends up holding more of the shared budget, yet the
    idle tenant's HOT entries survive (frequency wins, not identity)."""
    cache = CostAwareLFUCache(capacity_bytes=6 * 32, decay_factor=1.0)
    cache.insert(("idle", 0), _emb(1), gen_latency=1.0)
    for _ in range(10):
        cache.access(("idle", 0))               # one very hot idle entry
    for round_ in range(4):
        for cid in range(4):
            key = ("busy", cid)
            if cache.access(key) is None:
                cache.insert(key, _emb(1), gen_latency=1.0)
    assert ("idle", 0) in cache                  # survived the churn
    assert (cache.tenant_bytes("busy") > cache.tenant_bytes("idle"))


def test_per_tenant_byte_accounting_exact_after_churn():
    """per_tenant bytes/entries must equal an eager recompute over the
    live entries after arbitrary cross-tenant insert/access/evict/drop
    churn (including replacements and threshold drops)."""
    rng = np.random.default_rng(3)
    cache = CostAwareLFUCache(capacity_bytes=1500, decay_factor=0.95)
    tenants = ("a", "b", "c")
    for step in range(400):
        t = tenants[int(rng.integers(3))]
        cid = int(rng.integers(8))
        op = rng.random()
        if op < 0.55:
            cache.insert((t, cid), _emb(int(rng.integers(1, 4))),
                         gen_latency=float(rng.random() + 0.01),
                         min_latency_threshold=float(rng.random() * 0.2))
        elif op < 0.85:
            cache.access((t, cid))
        elif op < 0.95:
            cache.drop_below_threshold(float(rng.random() * 0.3), tenant=t)
        else:
            cache.invalidate_tenant(t)
    eager_bytes = {t: 0 for t in tenants}
    eager_entries = {t: 0 for t in tenants}
    for key, entry in cache._entries.items():
        eager_bytes[key[0]] += entry.nbytes
        eager_entries[key[0]] += 1
    for t in tenants:
        assert cache.tenant_bytes(t) == eager_bytes[t]
        assert cache.tenant_entries(t) == eager_entries[t]
    assert cache.total_bytes() == sum(eager_bytes.values())
    assert cache.total_bytes() <= 1500


def test_scoped_drop_leaves_other_tenants_alone():
    cache = CostAwareLFUCache(capacity_bytes=10_000)
    cache.insert(("a", 1), _emb(1), gen_latency=0.05)
    cache.insert(("a", 2), _emb(1), gen_latency=0.50)
    cache.insert(("b", 1), _emb(1), gen_latency=0.05)
    cache.drop_below_threshold(0.1, tenant="a")   # a's Alg. 3, not b's
    assert ("a", 1) not in cache
    assert ("a", 2) in cache
    assert ("b", 1) in cache
    cache.invalidate_tenant("b")
    assert ("b", 1) not in cache
    assert cache.tenant_bytes("b") == 0


def test_tenant_view_facade_matches_shared_cache():
    """TenantCacheView: int-keyed single-tenant API over the shared
    cache; counters per tenant, capacity/total shared."""
    from repro.core.cache_policy import TenantCacheView
    shared = CostAwareLFUCache(capacity_bytes=10_000)
    va = TenantCacheView(shared, "a")
    vb = TenantCacheView(shared, "b")
    va.insert(1, _emb(1), gen_latency=0.5)
    vb.insert(1, _emb(2), gen_latency=0.5)
    assert va.access(1) is not None and 1 in va
    assert va.access(2) is None
    assert va.hits == 1 and va.misses == 1
    assert vb.hits == 0 and vb.misses == 0
    assert va.tenant_bytes() == 32 and vb.tenant_bytes() == 64
    # total_bytes is the SHARED figure (memory_bytes parity contract)
    assert va.total_bytes() == shared.total_bytes() == 96
    assert ("a", 1) in shared and ("b", 1) in shared
    va.fresh()                        # scoped reset: only a's entries go
    assert 1 not in va and 1 in vb
