"""Algorithm 2 (cost-aware LFU) and Algorithm 3 (adaptive threshold) unit
behaviour, exactly as specified in the paper."""
import numpy as np

from repro.core.cache_policy import (CostAwareLFUCache,
                                     MinLatencyThresholdController)


def _emb(n=4):
    return np.ones((n, 8), np.float32)  # 128 B each


def test_lfu_evicts_min_weight():
    """eviction victim = argmin(genLatency * counter)."""
    cache = CostAwareLFUCache(capacity_bytes=3 * 32, decay_factor=1.0)
    cache.insert(1, _emb(1), gen_latency=1.0)
    cache.insert(2, _emb(1), gen_latency=10.0)
    cache.insert(3, _emb(1), gen_latency=5.0)
    cache.access(1)  # counter(1)=2 -> weight 2.0
    # weights: 1: 1*2=2, 2: 10*1=10, 3: 5*1=5  -> evict 1
    cache.insert(4, _emb(1), gen_latency=2.0)
    assert 1 not in cache and 2 in cache and 3 in cache and 4 in cache


def test_counter_decay_ages_out_stale_entries():
    cache = CostAwareLFUCache(capacity_bytes=2 * 32, decay_factor=0.5)
    cache.insert(1, _emb(1), gen_latency=1.0)
    cache.insert(2, _emb(1), gen_latency=1.0)
    for _ in range(6):
        cache.access(2)   # keeps 2 hot; 1's counter halves every access
    cache.insert(3, _emb(1), gen_latency=1.0)
    assert 1 not in cache and 2 in cache


def test_capacity_never_exceeded():
    cache = CostAwareLFUCache(capacity_bytes=1000)
    for i in range(50):
        cache.insert(i, _emb(2), gen_latency=float(i + 1))
        assert cache.total_bytes() <= 1000


def test_threshold_blocks_cheap_insert():
    cache = CostAwareLFUCache(capacity_bytes=10_000)
    cache.insert(1, _emb(1), gen_latency=0.05, min_latency_threshold=0.1)
    assert 1 not in cache
    cache.insert(2, _emb(1), gen_latency=0.5, min_latency_threshold=0.1)
    assert 2 in cache


def test_drop_below_threshold():
    cache = CostAwareLFUCache(capacity_bytes=10_000)
    cache.insert(1, _emb(1), gen_latency=0.05)
    cache.insert(2, _emb(1), gen_latency=0.50)
    cache.drop_below_threshold(0.1)
    assert 1 not in cache and 2 in cache


def test_alg3_threshold_dynamics():
    """miss + below-average latency => threshold rises; hit => falls."""
    ctl = MinLatencyThresholdController(step_s=0.01)
    ctl.observe(cache_miss=True, last_latency=1.0)   # init avg
    t1 = ctl.observe(cache_miss=True, last_latency=0.1)   # cheap miss -> up
    assert t1 > 0
    t2 = ctl.observe(cache_miss=False, last_latency=0.1)  # hit -> down
    assert t2 < t1
    # threshold never negative
    for _ in range(10):
        t = ctl.observe(cache_miss=False, last_latency=0.1)
    assert t == 0.0


def test_alg3_expensive_miss_does_not_raise():
    ctl = MinLatencyThresholdController(step_s=0.01)
    ctl.observe(cache_miss=True, last_latency=0.1)
    t = ctl.observe(cache_miss=True, last_latency=5.0)  # costly miss
    assert t == 0.0


def test_moving_average_tracks():
    ctl = MinLatencyThresholdController(ema_alpha=0.5)
    ctl.observe(cache_miss=False, last_latency=1.0)
    ctl.observe(cache_miss=False, last_latency=0.0)
    assert abs(ctl.moving_avg_latency - 0.5) < 1e-9
