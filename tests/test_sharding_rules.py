"""Sharding-rule legality for every assigned architecture: each param /
batch / cache spec must exactly divide its dims on the production mesh.

(The actual 512-device lower+compile is exercised by launch/dryrun.py — a
separate process because it forces the host-device count; here we validate
the rules with an abstract mesh so pytest stays on 1 CPU device.)
"""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.configs.shapes import INPUT_SHAPES
from repro.launch import input_specs as specs
from repro.launch import sharding as shd


class FakeMesh:
    """Just axis names + shape — enough for the rule functions."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESHES = [FakeMesh((16, 16), ("data", "model")),
          FakeMesh((2, 16, 16), ("pod", "data", "model"))]


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _check(tree, shardings, mesh):
    sizes = _axis_sizes(mesh)
    flat_t = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(shardings,
                             is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_t) == len(flat_s)
    for leaf, sh in zip(flat_t, flat_s):
        spec = sh.spec
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = int(np.prod([sizes[a] for a in axes]))
            assert dim % n == 0, (leaf.shape, spec, ax)


def _ns_patch(mesh):
    """monkeypatch NamedSharding to a tuple-carrier for FakeMesh."""
    class NS:
        def __init__(self, mesh_, spec):
            self.spec = spec
    return NS


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", MESHES, ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_all_specs_divisible(arch, mesh, shape_name, monkeypatch):
    monkeypatch.setattr(shd, "NamedSharding", _ns_patch(mesh))
    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    params = specs.param_specs(cfg)
    mode = "train" if shape.kind == "train" else "serve"
    _check(params, shd.param_shardings(params, mesh, mode), mesh)
    sp = specs.input_specs(cfg, shape)
    if shape.kind == "train":
        _check(sp["batch"], shd.batch_pspec(mesh, sp["batch"]), mesh)
    else:
        if "batch" in sp:
            _check(sp["batch"], shd.batch_pspec(mesh, sp["batch"]), mesh)
        if "tokens" in sp:
            _check(sp["tokens"], shd.batch_pspec(mesh, sp["tokens"]), mesh)
        _check(sp["caches"], shd.cache_pspec(cfg, mesh, sp["caches"]), mesh)


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "olmoe-1b-7b"])
def test_moe_expert_sharding_choice(arch):
    """64 experts shard over model; 40 experts fall back to ff-dim sharding."""
    mesh = MESHES[0]
    cfg = configs.get_config(arch)
    params = specs.param_specs(cfg)
    gate = params["blocks"][0]["moe"]["gate"]   # (R, E, d, ff)
    spec = shd.param_pspec(
        (jax.tree_util.DictKey("blocks"), jax.tree_util.SequenceKey(0),
         jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("gate")),
        gate, mesh)
    if cfg.num_experts % 16 == 0:
        assert spec[1] == "model"               # expert-parallel
    else:
        assert spec[1] is None and spec[3] == "model"  # ff fallback


def test_serve_mode_drops_data_axis():
    mesh = MESHES[0]
    cfg = configs.get_config("yi-9b")
    params = specs.param_specs(cfg)
    wq = params["blocks"][0]["wq"]
    path = (jax.tree_util.DictKey("blocks"), jax.tree_util.SequenceKey(0),
            jax.tree_util.DictKey("wq"))
    train_spec = shd.param_pspec(path, wq, mesh, "train")
    serve_spec = shd.param_pspec(path, wq, mesh, "serve")
    assert "data" in tuple(train_spec)
    assert "data" not in tuple(serve_spec)
    assert "model" in tuple(serve_spec)
