"""Fault-injection + graceful-degradation subsystem (core/faults.py):
checksum verification, retry/backoff accounting, corrupt-blob quarantine,
the deadline degradation ladder through resolver/engine, crash-safe disk
puts, maintenance-op quarantine, and per-request scheduler outcomes."""
import os

import numpy as np
import pytest

from repro.core import (DegradationPolicy, EdgeCostModel, EdgeRAGIndex,
                        FaultInjector)
from repro.core.faults import InjectedMissing, TransientIOError
from repro.core.storage import CODECS, StorageBackend, payload_checksum
from repro.data import generate_dataset
from repro.serving.scheduler import RequestScheduler

pytestmark = pytest.mark.fast


def _emb(n=40, d=64, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((n, d)).astype(np.float32)
    return e / np.linalg.norm(e, axis=1, keepdims=True)


class NFaults(FaultInjector):
    """Inject exactly ``n`` faults of one kind, then read clean — pins the
    retry path deterministically."""

    def __init__(self, n, kind, **kw):
        super().__init__(fault_rate=1.0, kind_weights={kind: 1.0}, **kw)
        self.remaining = n

    def perturb(self, key, payload, outcome=None):
        if self.remaining <= 0:
            return payload
        self.remaining -= 1
        return super().perturb(key, payload, outcome)


# ---------------------------------------------------------------------------
# injector
# ---------------------------------------------------------------------------
def test_injector_deterministic_and_counted():
    a = FaultInjector(seed=7, fault_rate=0.5, stall_rate=0.5)
    b = FaultInjector(seed=7, fault_rate=0.5, stall_rate=0.5)
    payload = {"emb": _emb(n=8)}
    for inj in (a, b):
        for key in range(50):
            try:
                inj.perturb(key, payload)
            except (InjectedMissing, TransientIOError):
                pass
    assert a.injected == b.injected and a.injected_total > 0
    assert a.stalls == b.stalls and a.stall_s_total == b.stall_s_total
    assert a.injected_total == sum(a.injected.values())
    # the stored payload is never damaged by flip/truncate injection
    assert np.array_equal(payload["emb"], _emb(n=8))


@pytest.mark.parametrize("kind", ["flip", "truncate"])
def test_corruption_changes_checksum(kind):
    inj = FaultInjector(seed=0, fault_rate=1.0, kind_weights={kind: 1.0})
    payload = {"emb": _emb()}
    bad = inj.perturb(0, payload)
    assert payload_checksum(bad) != payload_checksum(payload)


# ---------------------------------------------------------------------------
# storage: verified, retried reads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["memory", "disk"])
@pytest.mark.parametrize("codec", CODECS)
def test_checksum_verified_on_read(mode, codec, tmp_path):
    root = str(tmp_path) if mode == "disk" else None
    s = StorageBackend(mode, root=root, codec=codec)
    s.put(1, _emb())
    s.get(1)
    assert s.io_stats["verified"] == 1
    assert s.io_stats["failed_attempts"] == 0


@pytest.mark.parametrize("kind", ["flip", "truncate", "missing", "io"])
def test_one_injected_fault_recovers_via_retry(kind):
    s = StorageBackend("memory")
    emb = _emb()
    s.put(1, emb)
    s.faults = NFaults(1, kind)
    assert np.array_equal(s.get(1), emb)    # retry read the clean copy
    assert s.io_stats["retries"] == 1
    assert s.io_stats["failed_attempts"] == 1
    assert s.io_stats["backoff_s"] > 0
    assert s.io_stats["exhausted"] == 0


def test_corrupt_exhausted_quarantine_drops_blob():
    s = StorageBackend("memory", retry_limit=2)
    s.put(1, _emb())
    s.faults = NFaults(10, "flip")
    with pytest.raises(KeyError):
        s.get(1)
    assert s.io_stats["exhausted"] == 1
    assert s.io_stats["corrupt_dropped"] == 1
    assert 1 not in s       # dropped: the self-heal re-puts a fresh copy
    assert s.io_stats["failed_attempts"] == 3     # 1 try + 2 retries


def test_genuine_missing_never_retried():
    s = StorageBackend("memory")
    s.faults = FaultInjector(fault_rate=0.0)
    with pytest.raises(KeyError):
        s.get(42)
    assert s.get_many([42]) == [None]
    assert s.io_stats["retries"] == 0
    assert s.io_stats["failed_attempts"] == 0


def test_stall_charged_to_outcome():
    s = StorageBackend("memory")
    s.put(1, _emb())
    s.faults = FaultInjector(seed=3, stall_rate=1.0, stall_scale_s=0.05)
    outcomes = []
    [payload] = s.get_many_raw([1], outcomes=outcomes)
    assert payload is not None
    assert outcomes[0].stall_s > 0
    assert s.io_stats["stall_s"] == outcomes[0].stall_s
    assert s.faults.stalls == 1


def test_fault_accounting_identity():
    """Every injected (non-stall) fault is a failed attempt, and every
    failed attempt was either retried or ended an exhausted read."""
    s = StorageBackend("memory", retry_limit=3)
    for k in range(20):
        s.put(k, _emb(n=6, seed=k))
    s.faults = FaultInjector(seed=5, fault_rate=0.3, stall_rate=0.2)
    s.get_many_raw(list(range(20)))
    st = s.io_stats
    assert s.faults.injected_total == st["failed_attempts"]
    assert st["failed_attempts"] == st["retries"] + st["exhausted"]


# ---------------------------------------------------------------------------
# end to end: faults under search
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=700, dim=32, n_topics=24,
                            n_queries=16, seed=11)


def _fresh(ds, **kw):
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.05, **kw)   # tiny SLO: most clusters stored
    er.build(ds.chunk_ids, ds.texts, nlist=24, embeddings=ds.embeddings,
             seed=1)
    return er


def test_search_results_unchanged_under_total_corruption(ds):
    """With EVERY storage read corrupt, retrieval degrades to regeneration
    (checksum catch -> retry -> quarantine-drop -> regen + re-put) and
    (ids, scores) stay identical to the fault-free index."""
    ref = _fresh(ds)
    er = _fresh(ds)
    er.storage.faults = FaultInjector(seed=0, fault_rate=1.0,
                                      kind_weights={"flip": 1.0})
    r_ids, r_vals, _ = ref.search_batch(ds.query_embs, 10, 5)
    ids, vals, lats = er.search_batch(ds.query_embs, 10, 5)
    assert np.array_equal(ids, r_ids)
    assert np.array_equal(vals, r_vals)
    assert sum(l.n_storage_loads for l in lats) == 0
    assert sum(l.retries for l in lats) > 0
    assert sum(l.l2_retry_backoff_s for l in lats) > 0
    assert er.storage.io_stats["corrupt_dropped"] > 0


def test_search_results_unchanged_under_partial_faults(ds):
    """10%-ish faults + stalls: identical results, stall seconds charged."""
    ref = _fresh(ds)
    er = _fresh(ds)
    er.storage.faults = FaultInjector(seed=2, fault_rate=0.1,
                                      stall_rate=0.3)
    r_ids, r_vals, _ = ref.search_batch(ds.query_embs, 10, 5)
    ids, vals, lats = er.search_batch(ds.query_embs, 10, 5)
    assert np.array_equal(ids, r_ids)
    assert np.array_equal(vals, r_vals)
    if er.storage.faults.stalls:
        assert sum(l.l2_stall_s for l in lats) > 0


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------
def test_no_deadline_is_bit_identical(ds):
    ref = _fresh(ds)
    er = _fresh(ds)
    r_ids, r_vals, r_lats = ref.search_batch(ds.query_embs, 10, 5)
    ids, vals, lats = er.search_batch(ds.query_embs, 10, 5,
                                      deadlines=[None] * len(ds.query_embs))
    assert np.array_equal(ids, r_ids) and np.array_equal(vals, r_vals)
    for lat, r_lat in zip(lats, r_lats):
        assert lat.retrieval_s == r_lat.retrieval_s
        assert (lat.retries, lat.degraded_clusters, lat.stale_served) \
            == (0, 0, 0)


def test_rung1_deadline_sheds_probes(ds):
    """An impossibly tight deadline trims the probe list down to
    ``min_nprobe`` and records the sheds."""
    er = _fresh(ds, store_heavy=False, cache_bytes=0)   # everything regens
    pol = DegradationPolicy(min_nprobe=2, shed_regen=False,
                            serve_stale=False)
    ids, _, lat = er.search(ds.query_embs[0], 10, 6, deadline_s=1e-9,
                            policy=pol)
    assert lat.n_clusters_probed == 2           # trimmed, never below floor
    assert lat.degraded_clusters == 6 - 2
    assert (ids >= 0).any()                     # still serves an answer


def test_rung2_deadline_sheds_largest_regens(ds):
    """With probe-trimming off, an unaffordable regen queue sheds its most
    expensive clusters (zero rows) instead of blowing the deadline."""
    er = _fresh(ds, store_heavy=False, cache_bytes=0)
    pol = DegradationPolicy(shed_probes=False, serve_stale=False)
    ref = _fresh(ds, store_heavy=False, cache_bytes=0)
    _, _, r_lat = ref.search(ds.query_embs[0], 10, 6)
    # afford about half the regeneration bill: the largest regens shed,
    # the cheap head still serves
    ids, _, lat = er.search(ds.query_embs[0], 10, 6,
                            deadline_s=0.5 * r_lat.l2_generate_s,
                            policy=pol)
    assert lat.n_clusters_probed == 6           # rung 1 disabled
    assert lat.degraded_clusters > 0            # regens shed
    assert lat.l2_generate_s < r_lat.l2_generate_s
    assert (ids >= 0).any()


def test_rung3_serves_stale_cache_flagged(ds):
    """A cached payload invalidated by a same-size mutation between plan
    and execute is scored anyway (flagged) when the deadline cannot afford
    regeneration."""
    er = _fresh(ds, store_heavy=False)
    er.search_batch(ds.query_embs[:4], 10, 5)   # warm the cache
    pol = DegradationPolicy(shed_probes=False, shed_regen=False,
                            serve_stale=True)
    plan = er.plan_batch(ds.query_embs[:4], 5, deadlines=[1e-9] * 4,
                         policy=pol)
    assert plan.cached
    for cid in plan.cached:                      # same-size mutation
        er.clusters[cid].generation += 1
    ids, _, lats = er.search_batch(ds.query_embs[:4], 10, 5, plan=plan)
    assert sum(l.stale_served for l in lats) == len(plan.cached)
    assert (ids >= 0).any()
    for cid in plan.cached:                      # one-shot: evicted after
        assert cid not in er.cache


def test_degraded_recall_still_overlaps_fault_free(ds):
    """Rung-2 shedding keeps the cheap head of the probe list, so top-10
    ids still largely overlap the fault-free answer."""
    ref = _fresh(ds)
    er = _fresh(ds, store_heavy=False, cache_bytes=0)
    pol = DegradationPolicy(shed_probes=False, serve_stale=False)
    r_ids, _, _ = ref.search_batch(ds.query_embs, 10, 5)
    ids, _, lats = er.search_batch(
        ds.query_embs, 10, 5, deadlines=[0.6] * len(ds.query_embs),
        policy=pol)
    assert sum(l.degraded_clusters for l in lats) > 0
    overlap = np.mean([len(set(a[a >= 0]) & set(b[b >= 0])) / 10.0
                       for a, b in zip(ids, r_ids)])
    assert overlap > 0.5


# ---------------------------------------------------------------------------
# in-place updates: the same-size staleness rung 3 exists for
# ---------------------------------------------------------------------------
def _update_stack():
    """Local dataset (updates mutate the chunk store permanently — the
    module fixture must stay pristine) + a deferred-maintenance index."""
    ds2 = generate_dataset(n_records=300, dim=32, n_topics=12, n_queries=8,
                           seed=21)
    er = EdgeRAGIndex(32, ds2.embedder, ds2.get_chunks, EdgeCostModel(),
                      slo_s=0.05, maintenance="deferred")
    er.build(ds2.chunk_ids, ds2.texts, nlist=16, embeddings=ds2.embeddings,
             seed=1)
    cid, cl = next((i, c) for i, c in enumerate(er.clusters)
                   if c.stored and c.size >= 2)
    chunk = int(cl.ids[0])
    rng = np.random.default_rng(5)
    emb = ds2.embedder.table[chunk] + 0.05 * rng.standard_normal(32)
    emb = (emb / np.linalg.norm(emb)).astype(np.float32)
    text = f"doc-{chunk} revised " + "tok " * 8
    ds2.add_chunk(chunk, text, emb)          # same id: in-place overwrite
    return ds2, er, cid, chunk, emb, text


def test_update_in_place_marks_stale_then_self_heals():
    """update(): same rows, bumped generation -> the stored copy goes
    stale; a deadline-free search regenerates EXACTLY (new embedding
    served) and Alg. 1 self-heal refreshes the copy."""
    ds2, er, cid, chunk, emb, text = _update_stack()
    cl = er.clusters[cid]
    rows = cl.size
    assert er.update(chunk, text) == cid
    assert cl.size == rows                       # row-aligned mutation
    assert cid in er.storage and not cl.storage_fresh
    ids, _, lat = er.search(emb, 5, 4)
    assert chunk in ids[0].tolist()              # fresh embedding served
    assert lat.n_generated >= 1                  # stale copy bypassed
    assert cl.storage_fresh                      # regen + re-put healed it


def test_update_stale_stored_copy_served_under_deadline():
    """When the deadline cannot afford regenerating an updated cluster,
    the ladder serves its row-aligned stale STORED copy, flagged."""
    ds2, er, cid, chunk, emb, text = _update_stack()
    er.update(chunk, text)
    pol = DegradationPolicy(shed_probes=False)
    ids, _, lat = er.search(emb, 5, 4, deadline_s=1e-9, policy=pol)
    assert lat.stale_served == 1                 # old copy scored, flagged
    assert lat.degraded_clusters == 0            # nothing zero-rowed
    assert (ids >= 0).any()
    assert not er.clusters[cid].storage_fresh    # copy left stale


def test_update_unknown_chunk_is_noop():
    ds2, er, *_ = _update_stack()
    gens = [c.generation for c in er.clusters]
    assert er.update(10**9, "doc-x") is None
    assert [c.generation for c in er.clusters] == gens


# ---------------------------------------------------------------------------
# crash-safe disk put
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode,codec", [("disk", "fp32"), ("memmap", "pq")])
def test_put_is_atomic_under_crash(mode, codec, tmp_path, monkeypatch):
    s = StorageBackend(mode, root=str(tmp_path), codec=codec)
    emb = _emb()
    s.put(1, emb)
    clean = np.array(s.get(1), copy=True)

    def boom(src, dst):
        raise OSError("simulated crash mid-replace")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError):
        s.put(1, _emb(seed=9))
    monkeypatch.undo()
    # the old payload survives intact and no temp file is left behind
    assert np.array_equal(s.get(1), clean)
    assert not any(f.endswith(".tmp") for f in os.listdir(str(tmp_path)))


# ---------------------------------------------------------------------------
# memmap PQ tier: on-disk rot is caught, quarantined, and self-healed
# ---------------------------------------------------------------------------
def _flip_code_bit(s: StorageBackend, key: int, rng):
    """Flip one bit INSIDE the codes member's mapped extent of the stored
    npz — precisely the bytes ``np.memmap`` scoring would read."""
    mm = s.get_many_raw([key])[0]["codes"]
    assert isinstance(mm, np.memmap)
    pos = int(mm.offset) + int(rng.integers(mm.size))
    path = s._path(key)
    del mm
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)[0]
        f.seek(pos)
        f.write(bytes([b ^ (1 << int(rng.integers(8)))]))


@pytest.mark.parametrize("kind", ["flip", "truncate"])
def test_memmap_pq_rot_detected_and_reput(kind, tmp_path):
    """Storage level: seeded bit-flip / truncation of a memmap PQ payload
    file is caught (CRC for flips, unreadable container for truncation),
    the blob quarantine-drops, and a re-put restores exact reads."""
    s = StorageBackend("memmap", root=str(tmp_path), codec="pq",
                       retry_limit=1)
    emb = _emb(n=30, seed=4)
    s.put(1, emb)
    clean = np.array(s.get(1), copy=True)
    if kind == "flip":
        _flip_code_bit(s, 1, np.random.default_rng(0))
    else:
        with open(s._path(1), "r+b") as f:
            f.truncate(os.path.getsize(s._path(1)) // 2)
    with pytest.raises(KeyError):
        s.get(1)
    assert s.io_stats["corrupt_dropped"] == 1
    assert 1 not in s
    assert not os.path.exists(s._path(1))        # quarantine deleted the rot
    s.put(1, emb)                                # the resolver's self-heal
    assert np.array_equal(s.get(1), clean)       # same codebook: exact codes


def test_memmap_pq_search_self_heals_exactly(ds):
    """End to end: rot one stored cluster of a memmap pq index; the next
    search detects it mid-batch, regenerates the cluster, re-puts it under
    the same codebook — and the search AFTER that scores codes again with
    results identical to the pre-corruption search."""
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        st = StorageBackend("memmap", root=root, codec="pq", retry_limit=0)
        er = _fresh(ds, storage=st, cache_bytes=0)
        q = ds.query_embs[:6]
        ids0, vals0, _ = er.search_batch(q, 10, 4)
        victim = st.keys()[0]
        _flip_code_bit(st, victim, np.random.default_rng(1))
        ids1, _, lats1 = er.search_batch(q, 10, 4)
        assert st.io_stats["corrupt_dropped"] == 1
        assert sum(l.n_generated for l in lats1) >= 1    # regen self-heal
        assert (ids1 >= 0).any()
        assert victim in st                              # re-put happened
        assert er.clusters[victim].storage_fresh
        ids2, vals2, lats2 = er.search_batch(q, 10, 4)
        assert sum(l.n_generated for l in lats2) == 0    # healed: no regen
        assert np.array_equal(ids2, ids0)                # exact results
        assert np.array_equal(vals2, vals0)


# ---------------------------------------------------------------------------
# maintenance quarantine
# ---------------------------------------------------------------------------
def test_drain_quarantines_poison_op(ds, monkeypatch):
    er = _fresh(ds, maintenance="deferred")
    sched = er.maintenance
    # vanished storage copies make the queued restores genuinely runnable
    # (otherwise drain-time revalidation skips them as already satisfied)
    er.storage.delete(0)
    er.storage.delete(2)
    sched.enqueue("restore", 0)
    sched.enqueue("restore", 2)
    real = er._restore_cluster

    def boom(cid):
        if cid == 0:
            raise RuntimeError("poison restore")
        return real(cid)

    monkeypatch.setattr(er, "_restore_cluster", boom)
    for _ in range(sched.max_op_failures):
        report = sched.drain()
        assert ("restore", 0) in report.failed
    # the poison op is quarantined; the queue kept draining around it
    assert ("restore", 0) in sched.quarantined
    assert ("restore", 0) not in [(op.kind, op.cid) for op in sched.pending]
    assert sched.stats()["quarantined"] == 1
    assert sched.n_failures == sched.max_op_failures
    # a fresh enqueue lifts the quarantine and the healed op runs
    monkeypatch.undo()
    sched.enqueue("restore", 0)
    assert ("restore", 0) not in sched.quarantined
    report = sched.drain()
    assert not report.failed


def test_drain_keeps_draining_around_failures(ds, monkeypatch):
    """Ops behind a failing one still run in the same drain."""
    er = _fresh(ds, maintenance="deferred")
    sched = er.maintenance
    er.storage.delete(0)
    er.storage.delete(2)
    sched.enqueue("restore", 0)
    sched.enqueue("restore", 2)
    calls = []
    real = er._restore_cluster

    def flaky(cid):
        calls.append(cid)
        if cid == 0:
            raise RuntimeError("poison")
        return real(cid)

    monkeypatch.setattr(er, "_restore_cluster", flaky)
    report = sched.drain()
    assert ("restore", 0) in report.failed
    assert 2 in calls                      # the later op still ran


# ---------------------------------------------------------------------------
# scheduler outcomes
# ---------------------------------------------------------------------------
def test_scheduler_per_request_outcomes():
    rs = RequestScheduler()
    # spaced arrivals: no queueing delay muddies the per-request outcomes
    rs.submit(0.0, query="a", slo_s=1.0)         # met cleanly
    rs.submit(1.0, query="b", slo_s=0.05)        # degraded (flagged)
    rs.submit(2.0, query="c", slo_s=0.01)        # missed
    rs.submit(3.0, query="d", slo_s=1.0)         # failed (raises)

    def serve(req):
        if req.query == "d":
            raise RuntimeError("backend exploded")
        if req.query == "b":
            req.degraded = True
            return 0.04
        return 0.02 if req.query == "a" else 0.05

    done = rs.run(serve)
    assert len(done) == 4                        # the raise didn't wedge it
    by_q = {r.query: r for r in done}
    assert by_q["a"].outcome == "met"
    assert by_q["b"].outcome == "degraded" and by_q["b"].slo_met
    assert by_q["c"].outcome == "missed"
    assert by_q["d"].outcome == "failed" and not by_q["d"].slo_met
    assert "backend exploded" in by_q["d"].error
    assert rs.outcome_counts() == {"met": 1, "degraded": 1, "missed": 1,
                                   "failed": 1, "rejected": 0}
    assert len(rs.errors) == 1
