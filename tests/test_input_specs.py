"""input_specs: every (arch × shape) builds abstract inputs with the exact
assigned geometry, without allocating anything."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.shapes import INPUT_SHAPES
from repro.launch import input_specs as specs


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_specs_geometry(arch, shape_name):
    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    sp = specs.input_specs(cfg, shape)
    if shape.kind == "train":
        batch = sp["batch"]
        lead = (batch["embeds"] if cfg.embedding_inputs
                else batch["tokens"])
        assert lead.shape[:2] == (shape.global_batch, shape.seq_len)
        assert batch["labels"].shape == (shape.global_batch, shape.seq_len)
        if cfg.use_mrope:
            assert batch["positions"].shape == (3, shape.global_batch,
                                                shape.seq_len)
    elif shape.kind == "prefill":
        batch = sp["batch"]
        assert "labels" not in batch
        assert "caches" in sp
    else:
        toks = sp["tokens"]
        assert toks.shape[0] == shape.global_batch
        assert toks.shape[1] == 1
        assert sp["cache_len"].shape == ()
        # every leaf is abstract — nothing allocated
        for leaf in jax.tree.leaves(sp["caches"]):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-12b", "rwkv6-1.6b",
                                  "zamba2-2.7b"])
def test_long_context_caches_are_sub_quadratic(arch):
    """long_500k must NOT allocate O(seq_len) KV for attention archs."""
    cfg = configs.get_config(arch)
    sp = specs.input_specs(cfg, INPUT_SHAPES["long_500k"])
    total = sum(l.size * l.dtype.itemsize
                for l in jax.tree.leaves(sp["caches"]))
    # budget: well under a full 524288-length cache for even one layer
    full_one_layer = (524_288 * cfg.num_kv_heads * cfg.head_dim * 2 * 2)
    assert total < full_one_layer, (total, full_one_layer)


def test_decode32k_cache_matches_seq_len():
    cfg = configs.get_config("yi-9b")
    sp = specs.input_specs(cfg, INPUT_SHAPES["decode_32k"])
    k = sp["caches"][0].k
    assert k.shape[2] == 32_768      # (R, B, S, KH, D)
    assert k.shape[1] == 128
