"""Property suite for the durability subsystem (core/durability.py).

The tentpole contract: with a seeded :class:`CrashInjector` cutting the
process at ANY durability write boundary (:data:`CRASH_POINTS`), recovery
always lands BIT-IDENTICAL to the index after some prefix of the mutation
sequence — exactly pre-op or post-op of the op that died, never a torn
hybrid.  Identity is checked three ways at once: full active membership,
per-cluster generation-stamp/storage-flag state, and actual search
(ids AND scores) against independently rebuilt reference indexes.

Also checked:
  * WAL replay is idempotent — replaying the suffix twice equals once;
  * any single bit flip anywhere in a WAL frame fails that frame's CRC,
    and reading truncates cleanly at it (the valid prefix still parses);
  * a torn trailing frame is physically truncated by recovery;
  * checkpoints bump NO generation stamp (the pipeline's no-staling
    guarantee) and compaction drops exactly the records a snapshot covers.

Every crashpoint property runs over a deterministic grid (always) spanning
all storage codecs incl. pq and the memmap mode; hypothesis (when
installed) additionally fuzzes the op sequence, crash occurrence, and
seeds — same pattern as test_pq_properties.py.
"""
import gc
import os
import shutil
import tempfile

import numpy as np
import pytest

from repro.core import (CRASH_POINTS, CrashInjector, Durability,
                        EdgeRAGIndex, RecoveryError, SimulatedCrash,
                        WriteAheadLog, recover)
from repro.core.durability import (IndexSnapshot, _replay_record,
                                   pack_record, unpack_record)
from repro.data import generate_dataset

pytestmark = pytest.mark.fast

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=15, deadline=None)

DIM = 16
DS = generate_dataset(n_records=60, dim=DIM, n_topics=4, n_queries=4,
                      seed=7)
TEXTS = {int(i): t for i, t in zip(DS.chunk_ids, DS.texts)}
_ORIG_TEXTS = dict(TEXTS)


def embed_fn(ts):
    out = np.zeros((len(ts), DIM), np.float32)
    for j, t in enumerate(ts):
        r = np.random.default_rng(abs(hash(t)) % (2**31))
        out[j] = r.standard_normal(DIM)
    return out / np.linalg.norm(out, axis=1, keepdims=True)


def get_chunks(ids):
    return [TEXTS[int(i)] for i in ids]


CORPUS_EMB = embed_fn(list(DS.texts))
QUERIES = embed_fn(["durable query one", "durable query two"])


def make_ops(n_insert, n_remove, n_update, seed):
    """A deterministic mutation sequence; inserted texts are fat enough
    that some ops cross the store/split thresholds.  TEXTS is reset to
    the pristine corpus first so the dict is a pure function of ``seed``
    (cached reference signatures stay valid across seeds)."""
    TEXTS.clear()
    TEXTS.update(_ORIG_TEXTS)
    rng = np.random.default_rng(seed)
    ops = []
    for j in range(n_insert):
        nid = 50_000 + seed * 1000 + j
        TEXTS[nid] = (f"inserted chunk {seed}/{j} ") * int(rng.integers(5, 40))
        ops.append(("ins", nid))
    for i in rng.choice(DS.chunk_ids, size=n_remove, replace=False):
        ops.append(("rm", int(i)))
    for i in rng.choice(DS.chunk_ids[n_remove:], size=n_update,
                        replace=False):
        TEXTS[int(i)] = f"updated text {seed} " * int(rng.integers(5, 30))
        ops.append(("up", int(i)))
    rng.shuffle(ops)
    return [tuple(op) for op in ops]


def apply_op(ix, op):
    kind, i = op
    if kind == "ins":
        ix.insert(i, TEXTS[i])
    elif kind == "rm":
        ix.remove(i)
    else:
        ix.update(i, TEXTS[i])


def build_index(codec, mode, root=None, maintenance="sync"):
    ix = EdgeRAGIndex(DIM, embed_fn, get_chunks, storage_mode=mode,
                      storage_root=root, storage_codec=codec,
                      slo_s=0.004, split_max_chars=4000,
                      maintenance=maintenance)
    ix.build(DS.chunk_ids, DS.texts, nlist=5, embeddings=CORPUS_EMB)
    return ix


def state_sig(ix):
    """Content-identity signature: membership + per-cluster content state +
    search (ids AND scores) over fixed queries.  ``generation`` (the
    storage-EVENT stamp) is deliberately excluded: recovery's self-heal
    legitimately bumps it when it regenerates a lost blob, without
    changing any content — ``content_generation`` and the actual scores
    pin content identity."""
    ids, vals, _ = ix.search_batch(QUERIES, 6, 3)
    return (
        tuple(sorted(int(i) for c in ix.clusters if c.active for i in c.ids)),
        tuple((tuple(int(i) for i in c.ids), c.char_count, c.stored,
               c.active, c.content_generation)
              for c in ix.clusters),
        ids.tobytes(), vals.tobytes(),
    )


_REF_CACHE = {}


def reference_sigs(ops, codec, seed):
    """Signature of a fresh index after every prefix of ``ops`` — the
    pre/post states recovery must land on (memory mode: same codec, same
    put sequence, so stored payloads quantize identically)."""
    key = (seed, codec)
    if key not in _REF_CACHE:
        sigs = []
        for j in range(len(ops) + 1):
            ix = build_index(codec, "memory")
            for op in ops[:j]:
                apply_op(ix, op)
            sigs.append(state_sig(ix))
        _REF_CACHE[key] = sigs
    return _REF_CACHE[key]


# ---------------------------------------------------------------- properties
def check_crash_atomicity(point, codec, mode, at, seed):
    """Crash at occurrence ``at`` of ``point``; recovery must equal some
    op-sequence prefix — and specifically pre-op or post-op of the op
    that was running when the crash hit."""
    ops = make_ops(5, 3, 2, seed)
    refs = reference_sigs(ops, codec, seed)
    root = tempfile.mkdtemp(prefix="dur_prop_")
    try:
        crash = CrashInjector(point, at=at, seed=seed)
        ix = build_index(codec, mode, root=root)
        crashed_at = None
        attach_crashed = False
        try:
            # a snap_* crash at occurrence 1 fires here, inside the
            # baseline checkpoint — before any op ran
            ix.attach_durability(Durability(root, checkpoint_every=3,
                                            crash=crash))
        except SimulatedCrash:
            attach_crashed = True
        if not attach_crashed:
            for j, op in enumerate(ops):
                try:
                    apply_op(ix, op)
                except SimulatedCrash:
                    crashed_at = j
                    break
        del ix          # the crashed process is gone: release the root
        gc.collect()    # (index<->scheduler cycle pins the writer claim)
        try:
            ix2, rep = recover(root, embed_fn, get_chunks, slo_s=0.004,
                               storage_mode=mode, maintenance="sync",
                               split_max_chars=4000)
        except RecoveryError:
            # only legitimate when the crash killed the very first
            # snapshot: nothing durable ever landed
            assert attach_crashed, \
                f"{point}/{codec}/{mode}: recovery refused despite a " \
                f"durable baseline existing"
            return
        sig = state_sig(ix2)
        match = [j for j, s in enumerate(refs) if s == sig]
        assert match, \
            f"{point}/{codec}/{mode}: recovered state is a hybrid " \
            f"(matches no prefix; crashed at op {crashed_at})"
        if crashed_at is not None:
            assert crashed_at in match or crashed_at + 1 in match, \
                f"{point}/{codec}/{mode}: recovered to prefix {match}, " \
                f"crash was at op {crashed_at} (want pre- or post-op)"
        del ix2
    finally:
        shutil.rmtree(root, ignore_errors=True)


def check_replay_idempotent(seed):
    """Applying the WAL suffix twice must equal applying it once."""
    root = tempfile.mkdtemp(prefix="dur_idem_")
    try:
        ix = build_index("fp32", "disk", root=root)
        dur = Durability(root, checkpoint_every=10**6)  # never checkpoints
        ix.attach_durability(dur)
        for op in make_ops(4, 2, 1, seed):
            apply_op(ix, op)
        records, _, torn = dur.wal.records()
        assert records and not torn
        found = IndexSnapshot.newest_valid(dur.dir)
        assert found is not None
        pre = state_sig(ix)
        del ix
        gc.collect()

        def replay(times):
            jx = EdgeRAGIndex(DIM, embed_fn, get_chunks,
                              storage_mode="disk", storage_root=root,
                              slo_s=0.004, split_max_chars=4000)
            applied, manifest = IndexSnapshot.apply(jx, found[1])
            for _ in range(times):
                cursor = applied
                for rec in records:
                    if int(rec["lsn"]) <= cursor:
                        continue        # the idempotence mechanism: LSN skip
                    _replay_record(jx, rec, manifest)
                    cursor = int(rec["lsn"])
                applied = cursor
            sig = state_sig(jx)
            del jx
            gc.collect()
            return sig

        once = replay(1)
        twice = replay(2)
        assert once == twice
        assert once == pre      # and both equal the pre-crash live state
    finally:
        shutil.rmtree(root, ignore_errors=True)


def check_bit_flip_truncates(flip_byte_frac, flip_bit, seed):
    """One flipped bit anywhere past the magic fails exactly one frame's
    CRC; reading stops there and truncation leaves a clean prefix."""
    root = tempfile.mkdtemp(prefix="dur_flip_")
    try:
        wal = WriteAheadLog(os.path.join(root, "wal.log"))
        rng = np.random.default_rng(seed)
        bodies = [pack_record({"lsn": j, "op": "t", "nlist": 0, "gone": [],
                               "pq_version": None, "clusters": [],
                               "pad": rng.integers(0, 9, 4).tolist()})
                  for j in range(1, 6)]
        for b in bodies:
            wal.append(b)
        clean, _, torn = wal.frames()
        assert len(clean) == 5 and not torn
        data = bytearray(open(wal.path, "rb").read())
        pos = 8 + int(flip_byte_frac * (len(data) - 8))   # past the magic
        pos = min(pos, len(data) - 1)
        data[pos] ^= (1 << flip_bit)
        with open(wal.path, "wb") as f:
            f.write(bytes(data))
        frames, _, torn = wal.frames()
        assert torn, "a flipped bit must be detected"
        assert len(frames) < 5
        for got, want in zip(frames, bodies):   # prefix is untouched
            assert got == want
        dropped = wal.truncate_torn_tail()
        assert dropped > 0
        frames2, _, torn2 = wal.frames()
        assert not torn2 and frames2 == frames  # clean after truncation
        assert wal.truncate_torn_tail() == 0    # second cut is a no-op
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------- deterministic
CODEC_ARMS = [("fp32", "disk"), ("fp16", "disk"), ("int8", "disk"),
              ("pq", "disk"), ("pq", "memmap"), ("fp32", "memmap")]


@pytest.mark.parametrize("point", CRASH_POINTS)
@pytest.mark.parametrize("codec,mode", CODEC_ARMS)
def test_crashpoint_atomicity_grid(point, codec, mode):
    check_crash_atomicity(point, codec, mode, at=2, seed=11)


def test_crashpoint_first_occurrence():
    # at=1 dies inside attach_durability's baseline snapshot for the snap_*
    # points — there is nothing durable yet, so recovery must refuse
    # rather than fabricate state
    for point in ("wal_pre_append", "wal_torn_append", "wal_post_append"):
        check_crash_atomicity(point, "fp32", "disk", at=1, seed=3)


def test_recover_without_durable_state_raises():
    root = tempfile.mkdtemp(prefix="dur_none_")
    try:
        with pytest.raises(RecoveryError):
            recover(root, embed_fn, get_chunks)
    finally:
        shutil.rmtree(root, ignore_errors=True)


def test_wal_replay_idempotent():
    for seed in (0, 1, 2):
        check_replay_idempotent(seed)


def test_bit_flip_truncates():
    for frac, bit, seed in [(0.02, 0, 0), (0.3, 3, 1), (0.55, 7, 2),
                            (0.85, 4, 3), (0.999, 1, 4)]:
        check_bit_flip_truncates(frac, bit, seed)


def test_record_roundtrip_ndarrays():
    rec = {"lsn": 3, "op": "x",
           "a": np.arange(12, dtype=np.float32).reshape(3, 4) / 7,
           "nested": {"ids": np.array([5, -2], np.int64)},
           "s": "text", "none": None}
    out = unpack_record(pack_record(rec))
    assert out["lsn"] == 3 and out["s"] == "text" and out["none"] is None
    assert np.array_equal(out["a"], rec["a"]) and out["a"].dtype == np.float32
    assert np.array_equal(out["nested"]["ids"], rec["nested"]["ids"])


def test_checkpoint_bumps_no_generation_and_compacts():
    """The pipeline no-staling guarantee: a checkpoint leaves every
    generation stamp untouched, so the S3 replan gate never fires on one;
    and the post-snapshot compaction leaves only uncovered records."""
    root = tempfile.mkdtemp(prefix="dur_ckpt_")
    try:
        ix = build_index("fp32", "disk", root=root, maintenance="deferred")
        dur = ix.attach_durability(Durability(root, checkpoint_every=4))
        for op in make_ops(5, 2, 0, seed=5):
            apply_op(ix, op)
        assert any(op.kind == "checkpoint" for op in ix.maintenance.pending)
        stamps = [(c.generation, c.content_generation) for c in ix.clusters]
        snaps_before = dur.snapshots_total
        ix.maintenance.drain(None)
        assert dur.snapshots_total > snaps_before
        # drained split/merge/restore ops legitimately bump stamps; re-run
        # with a now-idle queue so the only executable op is a checkpoint
        for op in make_ops(0, 0, 0, seed=6):
            apply_op(ix, op)
        dur.records_since_snapshot = dur.checkpoint_every  # force one
        ix.maintenance.enqueue("checkpoint", -1)
        stamps = [(c.generation, c.content_generation) for c in ix.clusters]
        rep = ix.maintenance.drain(None)
        assert ("checkpoint", -1) in rep.executed
        assert stamps == [(c.generation, c.content_generation)
                          for c in ix.clusters]
        assert rep.edge_s > 0.0         # snapshot I/O is charged, not free
        # compaction: every WAL record left is newer than the snapshot
        records, _, _ = dur.wal.records()
        assert all(int(r["lsn"]) > dur.next_lsn - 1 - len(records)
                   for r in records)
        assert dur.records_since_snapshot == len(records) == 0
        del ix
    finally:
        shutil.rmtree(root, ignore_errors=True)


def test_recover_router_restores_every_tenant():
    """One recover_router call restores a whole crashed multi-tenant
    deployment: per-tenant namespaced WALs under the shared root, each
    tenant's answers identical to pre-crash."""
    from repro.core import TenantRouter
    from repro.core.durability import recover_router

    root = tempfile.mkdtemp(prefix="dur_router_")
    try:
        router = TenantRouter(DIM, slo_s=0.004, storage_mode="disk",
                              storage_root=root)
        for t in ("alpha", "beta"):
            ix = router.create_tenant(t, embed_fn, get_chunks,
                                      slo_s=0.004, maintenance="sync")
            ix.build(DS.chunk_ids, DS.texts, nlist=5,
                     embeddings=CORPUS_EMB)
        handles = router.enable_durability(checkpoint_every=4)
        assert set(handles) == {"alpha", "beta"}
        for t, base in (("alpha", 80_000), ("beta", 90_000)):
            ix = router.tenants[t]
            for j in range(5):
                TEXTS[base + j] = f"tenant {t} chunk {j} " * 15
                ix.insert(base + j, TEXTS[base + j])
            ix.remove(int(DS.chunk_ids[0 if t == "alpha" else 1]))
        pre = {t: router.tenants[t].search_batch(QUERIES, 6, 3)[:2]
               for t in ("alpha", "beta")}
        del router, ix
        gc.collect()

        specs = {t: (embed_fn, get_chunks) for t in ("alpha", "beta")}
        router2, reports = recover_router(
            root, specs,
            tenant_kwargs={"slo_s": 0.004, "maintenance": "sync"})
        assert set(reports) == {"alpha", "beta"}
        for t in ("alpha", "beta"):
            assert reports[t].tenant == t
            ids, vals, _ = router2.tenants[t].search_batch(QUERIES, 6, 3)
            assert np.array_equal(ids, pre[t][0])
            assert np.array_equal(vals, pre[t][1])
            assert router2.tenants[t].durability is not None
        # unknown tenants must be impossible to silently drop
        with pytest.raises(AssertionError):
            recover_router(root, {"alpha": specs["alpha"]})
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ------------------------------------------------------------- hypothesis
if HAVE_HYPOTHESIS:

    @settings(**SETTINGS)
    @given(point=st.sampled_from(CRASH_POINTS),
           codec=st.sampled_from(["fp32", "fp16", "int8", "pq"]),
           at=st.integers(min_value=1, max_value=6),
           seed=st.integers(min_value=0, max_value=50))
    def test_hyp_crashpoint_atomicity(point, codec, at, seed):
        check_crash_atomicity(point, codec, "disk", at=at, seed=seed)

    @settings(**SETTINGS)
    @given(seed=st.integers(min_value=0, max_value=100))
    def test_hyp_replay_idempotent(seed):
        check_replay_idempotent(seed)

    @settings(**SETTINGS)
    @given(frac=st.floats(min_value=0.0, max_value=1.0),
           bit=st.integers(min_value=0, max_value=7),
           seed=st.integers(min_value=0, max_value=100))
    def test_hyp_bit_flip_truncates(frac, bit, seed):
        check_bit_flip_truncates(frac, bit, seed)
