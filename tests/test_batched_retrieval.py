"""Batched retrieval fast path: search_batch parity with sequential search
across the Table-4 ablation configs, coalesced-embed call counting, batch
cache/threshold semantics, the chunk->cluster map, and the multi-query
Pallas kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.cache_policy import MinLatencyThresholdController
from repro.data import generate_dataset
from repro.kernels.ivf_topk.kernel import topk_ip_pallas
from repro.kernels.ivf_topk.ref import topk_ip_ref
from repro.serving.engine import RAGEngine

pytestmark = pytest.mark.fast

# Table 4 ablation rows (see core/edgerag.py module docstring)
CONFIGS = {
    "embed_gen": dict(store_heavy=False, cache_bytes=0),
    "embed_gen_load": dict(store_heavy=True, cache_bytes=0),
    "edgerag": dict(store_heavy=True, cache_bytes=1 << 20),
}


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=900, dim=32, n_topics=30,
                            n_queries=64, seed=5)


def _fresh(ds, **kw):
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.3, **kw)
    er.build(ds.chunk_ids, ds.texts, nlist=30, embeddings=ds.embeddings,
             seed=1)
    return er


@pytest.mark.parametrize("cfg", list(CONFIGS))
def test_search_batch_bit_identical_to_sequential(ds, cfg):
    """(ids, scores) from one search_batch == per-query search loop, bitwise,
    for every Table-4 ablation config."""
    seq = _fresh(ds, **CONFIGS[cfg])
    bat = _fresh(ds, **CONFIGS[cfg])
    nq = 24
    s_ids, s_vals = [], []
    for qi in range(nq):
        ids, vals, _ = seq.search(ds.query_embs[qi], 10, 5)
        s_ids.append(ids[0])
        s_vals.append(vals[0])
    b_ids, b_vals, lats = bat.search_batch(ds.query_embs[:nq], 10, 5)
    assert np.array_equal(np.stack(s_ids), b_ids)
    assert np.array_equal(np.stack(s_vals), b_vals)
    assert len(lats) == nq
    # dedup really happened: Zipf queries share clusters
    assert sum(l.n_shared_hits for l in lats) > 0


def test_search_batch_single_coalesced_embed_call(ds):
    """All cache-miss regenerations in a batch coalesce into EXACTLY one
    embed_fn call (acceptance criterion)."""
    er = _fresh(ds, **CONFIGS["embed_gen"])   # every probe regenerates
    for nq in (4, 16):
        calls0 = ds.embedder.calls
        _, _, lats = er.search_batch(ds.query_embs[:nq], 10, 5)
        assert ds.embedder.calls - calls0 == 1
        assert sum(l.n_generated for l in lats) > 1   # many clusters, 1 call


def test_search_is_degenerate_batch(ds):
    """The single-query wrapper is a batch of one: results and the full
    LatencyBreakdown agree field for field."""
    a = _fresh(ds, **CONFIGS["edgerag"])
    b = _fresh(ds, **CONFIGS["edgerag"])
    for qi in range(6):
        ids_a, vals_a, lat_a = a.search(ds.query_embs[qi], 10, 5,
                                        query_chars=50)
        ids_b, vals_b, lats_b = b.search_batch(
            ds.query_embs[qi][None], 10, 5, query_chars=[50])
        assert np.array_equal(ids_a, ids_b)
        assert np.array_equal(vals_a, vals_b)
        da, db = lat_a.as_dict(), lats_b[0].as_dict()
        for key in da:
            if key == "wall_s":
                continue
            assert da[key] == db[key], key


def test_batch_cache_and_threshold_semantics(ds):
    """Documented batch semantics: the cache is consulted once per unique
    cluster, every regenerated cluster admitted under the threshold is
    cached, and the Alg. 3 controller observes once per (non-empty) query
    in batch order."""
    er = _fresh(ds, **CONFIGS["edgerag"])
    misses0, hits0 = er.cache.misses, er.cache.hits
    nq = 16
    _, _, lats = er.search_batch(ds.query_embs[:nq], 10, 5)
    uniq_regen = sum(l.n_generated for l in lats)
    uniq_hit = sum(l.n_cache_hits for l in lats)
    # one cache access per unique non-stored cluster
    assert er.cache.misses - misses0 == uniq_regen
    assert er.cache.hits - hits0 == uniq_hit
    # replay the controller: one observation per query, misses flagged on
    # owners of regenerated clusters
    ctrl = MinLatencyThresholdController()
    for lat in lats:
        if lat.n_clusters_probed == 0:
            continue
        ctrl.observe(lat.n_generated > 0, lat.retrieval_s)
    assert er.threshold.threshold == pytest.approx(ctrl.threshold)
    assert er.threshold.moving_avg_latency == pytest.approx(
        ctrl.moving_avg_latency)


def test_latency_attribution_shared_clusters(ds):
    """Owner pays resolution; peers record shared DRAM hits; counters add
    up per query."""
    er = _fresh(ds, **CONFIGS["embed_gen"])
    q = np.stack([ds.query_embs[0]] * 4)      # identical queries: max overlap
    _, _, lats = er.search_batch(q, 10, 5)
    # owner (first query) resolved everything
    assert lats[0].n_generated == lats[0].n_clusters_probed
    assert lats[0].n_shared_hits == 0
    for lat in lats[1:]:
        assert lat.n_generated == 0
        assert lat.n_shared_hits == lat.n_clusters_probed
        assert lat.l2_mem_load_s > 0
    for lat in lats:
        assert (lat.n_generated + lat.n_storage_loads + lat.n_cache_hits
                + lat.n_shared_hits == lat.n_clusters_probed)


def test_chunk_cluster_map_consistency(ds):
    """The chunk->cluster map survives insert / remove / split / merge and
    always matches a recomputed ground truth."""
    er = _fresh(ds, split_max_chars=4000, merge_min_size=2)

    def check():
        truth = {}
        for cid, cl in enumerate(er.clusters):
            if not cl.active:
                continue
            for i in cl.ids:
                truth[int(i)] = cid
        assert er._chunk_cluster == truth

    check()
    rng = np.random.default_rng(0)
    next_id = 900_000
    live = [int(i) for i in ds.chunk_ids]
    for step in range(40):
        if step % 3 != 2:
            emb = ds.embeddings[int(rng.integers(ds.n))]
            text = f"doc-{next_id} " + "pad " * int(rng.integers(10, 200))
            ds.add_chunk(next_id, text, emb)
            er.insert(next_id, text)
            live.append(next_id)
            next_id += 1
        else:
            victim = live.pop(int(rng.integers(len(live))))
            assert er.remove(victim) is not None
        check()
    assert er.remove(123_456_789) is None     # unknown id


def test_answer_batch_matches_answer(ds):
    """Sim-only serving: answer_batch returns the same contexts as
    per-query answer."""
    seq_engine = RAGEngine(_fresh(ds, **CONFIGS["edgerag"]), None,
                           k=5, nprobe=4)
    bat_engine = RAGEngine(_fresh(ds, **CONFIGS["edgerag"]), None,
                           k=5, nprobe=4)
    queries = [f"query number {i}" for i in range(8)]
    singles = [seq_engine.answer(q, ds.query_embs[i], ds.get_chunks)
               for i, q in enumerate(queries)]
    batched = bat_engine.answer_batch(queries, ds.query_embs[:8],
                                      ds.get_chunks)
    assert len(batched) == 8
    for s, b in zip(singles, batched):
        assert s.chunk_ids == b.chunk_ids
        assert s.context == b.context
        assert b.ttft_edge_s > 0


@pytest.mark.slow
def test_answer_batch_with_continuous_batcher(ds):
    """Retrieval batching composes with decode batching: answer_batch feeds
    prompts through ContinuousBatcher.admit and every query gets tokens."""
    import jax
    from repro import configs
    from repro.models import model as M
    from repro.serving.batching import ContinuousBatcher

    cfg = configs.get_config("stablelm-1.6b").reduced(num_layers=1,
                                                      d_model=64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batcher = ContinuousBatcher(cfg, params, num_slots=2, max_len=64)
    engine = RAGEngine(_fresh(ds, **CONFIGS["edgerag"]), None,
                       k=4, nprobe=3, max_new_tokens=3)
    queries = [f"query {i}" for i in range(5)]
    responses = engine.answer_batch(queries, ds.query_embs[:5],
                                    ds.get_chunks, batcher=batcher)
    assert len(responses) == 5
    for r in responses:
        assert len(r.output_tokens) == 3
        assert r.chunk_ids and r.decode_wall_s > 0


# ---------------------------------------------------------------------------
# multi-query Pallas kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,q,k,block_q,block_n", [
    (300, 32, 17, 7, 8, 64),     # q not a block_q multiple (padded)
    (64, 32, 16, 9, 8, 64),      # exact tiles
    (130, 128, 5, 10, 4, 64),    # both axes padded
    (512, 64, 1, 5, 8, 128),     # single query, degenerate block
    (33, 32, 9, 33, 8, 32),      # k == n
])
def test_multiquery_pallas_matches_ref(n, d, q, k, block_q, block_n):
    rng = np.random.default_rng(1234)
    embs = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    qs = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    keff = min(k, n)
    pv, pi = topk_ip_pallas(embs, qs, keff, block_n=block_n,
                            block_q=block_q, interpret=True)
    rv, ri = topk_ip_ref(embs, qs, keff)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(rv), atol=2e-4)
    assert (np.asarray(pi) == np.asarray(ri)).all()
