"""Generation-stamped maintenance subsystem (core/maintenance.py):
plan staleness by generation compare (same-size mutations included),
budget-bounded deferred draining, probe behavior on merge-heavy indexes,
the §5.4 bugfixes (insert assignment, merge stored-flag, post-split insert
return), and property-style churn invariants over the Table-4 configs."""
import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.maintenance import (OP_MERGE, OP_RESTORE, OP_SPLIT,
                                    MaintenanceScheduler)
from repro.data import generate_dataset
from repro.data.embedder import TableEmbedder
from repro.serving.engine import RAGEngine
from repro.serving.scheduler import RequestScheduler

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=500, dim=32, n_topics=16,
                            n_queries=24, seed=5)


def _fresh(ds, **kw):
    kw.setdefault("slo_s", 0.15)
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(), **kw)
    er.build(ds.chunk_ids, ds.texts, nlist=16, embeddings=ds.embeddings,
             seed=1)
    return er


def _mk_chunk(ds, next_id, near_emb, rng, n_words=20):
    emb = near_emb + 0.03 * rng.standard_normal(len(near_emb))
    emb = (emb / np.linalg.norm(emb)).astype(np.float32)
    text = f"doc-{next_id} " + "tok " * n_words
    ds.add_chunk(next_id, text, emb)
    return text, emb


def _check_invariants(er, *, quiescent=True):
    """The §5.4 correctness contract: map bijection, char accounting,
    storage consistency, and (at quiescence) Alg. 1 + the split bound."""
    live = []
    for cid, cl in enumerate(er.clusters):
        if not cl.active:
            assert cl.size == 0 and cl.char_count == 0
            assert not cl.stored, f"tombstoned {cid} still flagged stored"
            assert cid not in er.storage
            continue
        ids = [int(i) for i in cl.ids]
        live.extend(ids)
        assert len(set(ids)) == len(ids)
        for i in ids:
            assert er._chunk_cluster[i] == cid
        assert cl.char_count == sum(er._chunk_chars[i] for i in ids)
        if cl.stored:
            assert cid in er.storage
        if quiescent:
            assert cl.stored == (er.store_heavy
                                 and cl.gen_latency_est > er.slo_s), cid
            # split bound: any MUTATED cluster fits (build never splits, so
            # generation-0 clusters may be born oversized and heal on touch)
            assert (cl.char_count <= er.split_max_chars or cl.size <= 1
                    or cl.generation == 0), cid
    assert sorted(live) == sorted(er._chunk_cluster)
    assert er.ntotal == len(er._chunk_cluster)


# ----------------------------------------------------------------------
# generation stamps
# ----------------------------------------------------------------------
def test_generation_bumps_on_mutations(ds):
    er = _fresh(ds, slo_s=0.05)
    cid = er._chunk_cluster[int(ds.chunk_ids[0])]
    g0 = er.clusters[cid].generation
    nid = 600_000
    text, _ = _mk_chunk(ds, nid, er.centroids[cid], np.random.default_rng(0))
    assert er.insert(nid, text) == cid
    assert er.clusters[cid].generation > g0          # insert (+ restore)
    g1 = er.clusters[cid].generation
    er.remove(nid)
    assert er.clusters[cid].generation > g1
    # restore keeps the storage stamp in sync with the cluster stamp
    for cl in er.clusters:
        if cl.stored:
            assert cl.stored_generation == cl.generation


def test_stale_cached_plan_same_size_mutation(ds):
    """Acceptance: a plan whose cached payload predates a SAME-SIZE mutation
    (remove one + insert one) regenerates instead of scoring stale ids —
    the old row-count guard cannot see this."""
    er = _fresh(ds, store_heavy=False, cache_bytes=8 << 20)
    er.search_batch(ds.query_embs[:6], 10, 5)        # populate the cache
    plan = er.plan_batch(ds.query_embs[:6], 5)
    assert plan.cached
    victim = next(c for c in plan.cached if er.clusters[c].size >= 3)
    size0 = er.clusters[victim].size
    gone = int(er.clusters[victim].ids[0])
    er.remove(gone)
    nid = 610_000
    text, _ = _mk_chunk(ds, nid, er.centroids[victim],
                        np.random.default_rng(1))
    assert er.insert(nid, text) == victim
    assert er.clusters[victim].size == size0         # same-size mutation
    ids, vals, lats = er.search_batch(ds.query_embs[:6], 10, 5, plan=plan)
    f_ids, f_vals, _ = er.search_batch(ds.query_embs[:6], 10, 5)
    assert np.array_equal(ids, f_ids)
    assert np.array_equal(vals, f_vals)
    assert gone not in set(ids.ravel().tolist())
    assert sum(l.n_generated for l in lats) >= 1     # victim regenerated


def test_prefetched_plan_survives_insert_and_remove(ds):
    """Acceptance: a plan prefetched before an insert/remove of a probed
    STORED cluster executes without crashing or scoring stale rows, even
    though a synchronous restore refreshed the storage copy after the
    prefetch."""
    er = _fresh(ds, slo_s=0.05, cache_bytes=0)
    plan = er.plan_batch(ds.query_embs[:6], 5, prefetch_storage=True)
    assert plan.storage_clusters and plan.prefetched
    victim = next(c for c in plan.storage_clusters
                  if er.clusters[c].size >= 3)
    gone = int(er.clusters[victim].ids[0])
    er.remove(gone)
    nid = 620_000
    text, _ = _mk_chunk(ds, nid, er.centroids[victim],
                        np.random.default_rng(2))
    assert er.insert(nid, text) == victim
    ids, vals, lats = er.search_batch(ds.query_embs[:6], 10, 5, plan=plan)
    f_ids, f_vals, _ = er.search_batch(ds.query_embs[:6], 10, 5)
    assert np.array_equal(ids, f_ids)
    assert np.array_equal(vals, f_vals)
    assert gone not in set(ids.ravel().tolist())


def test_stale_plan_survives_split_and_merge(ds):
    """A probed cluster split (or merged away) between plan and execute
    resolves over its current membership — merged-away clusters drop to
    zero rows instead of crashing the scorer."""
    er = _fresh(ds, slo_s=10.0, store_heavy=False, cache_bytes=0,
                split_max_chars=20_000, merge_min_size=2)
    plan = er.plan_batch(ds.query_embs[:4], 4)
    probed = sorted({c for p in plan.probed_per_q for c in p})
    assert len(probed) >= 2
    # split: balloon one probed cluster over the limit
    fat = probed[0]
    nid = 630_000
    rng = np.random.default_rng(3)
    text, _ = _mk_chunk(ds, nid, er.centroids[fat], rng, n_words=6000)
    er.insert(nid, text)
    assert er.nlist > 16                              # split appended
    # merge: drain another probed cluster until it tombstones
    small = probed[-1]
    while er.clusters[small].active and er.clusters[small].size > 0:
        er.remove(int(er.clusters[small].ids[0]))
    ids, _, _ = er.search_batch(ds.query_embs[:4], 10, 4, plan=plan)
    live = set(er._chunk_cluster)
    assert all(int(i) in live for i in ids.ravel() if i >= 0)
    _check_invariants(er)


# ----------------------------------------------------------------------
# deferred maintenance
# ----------------------------------------------------------------------
def test_budget_bounded_draining(ds):
    er = _fresh(ds, slo_s=0.02, maintenance="deferred",
                split_max_chars=8_000, merge_min_size=2)
    rng = np.random.default_rng(4)
    nid = 640_000
    for k in range(40):
        text, _ = _mk_chunk(ds, nid, ds.embeddings[rng.integers(ds.n)], rng,
                            n_words=int(rng.integers(5, 120)))
        er.insert(nid, text)
        nid += 1
    assert len(er.maintenance) > 1
    rep = er.maintenance.drain(1e-9, strict=True)    # strict: nothing fits
    assert rep.n_executed == 0
    assert rep.remaining > 0
    rep = er.maintenance.drain(1e-9)                 # tiny budget
    assert rep.n_executed == 1                       # ≥1 op always runs
    assert rep.remaining > 0
    drains = 0
    while len(er.maintenance):
        rep = er.maintenance.drain(0.5)
        assert rep.edge_s <= 0.5 or rep.n_executed == 1
        drains += 1
        assert drains < 500
    _check_invariants(er, quiescent=True)
    st = er.maintenance.stats()
    assert st["executed"] >= 1 and st["total_edge_s"] > 0


def test_deferred_matches_sync_at_quiescence(ds):
    """The same mutation stream through sync and deferred maintenance ends
    with the same live corpus and the same quiescent invariants."""
    sync = _fresh(ds, slo_s=0.05, split_max_chars=10_000, merge_min_size=2)
    defer = _fresh(ds, slo_s=0.05, split_max_chars=10_000, merge_min_size=2,
                   maintenance="deferred")
    rng = np.random.default_rng(5)
    nid = 650_000
    for k in range(60):
        if rng.random() < 0.5:
            text, _ = _mk_chunk(ds, nid, ds.embeddings[rng.integers(ds.n)],
                                rng, n_words=int(rng.integers(5, 200)))
            sync.insert(nid, text)
            defer.insert(nid, text)
            nid += 1
        else:
            victim = int(rng.choice(sorted(sync._chunk_cluster)))
            assert (sync.remove(victim) is None) == \
                (defer.remove(victim) is None)
    defer.maintenance.drain(None)                    # run to quiescence
    assert len(defer.maintenance) == 0
    assert sorted(sync._chunk_cluster) == sorted(defer._chunk_cluster)
    _check_invariants(sync, quiescent=True)
    _check_invariants(defer, quiescent=True)
    for er in (sync, defer):
        ids, _, _ = er.search(ds.query_embs[0], 10, 8)
        assert all(int(i) in er._chunk_cluster for i in ids[0] if i >= 0)


def test_deferred_search_correct_with_pending_ops(ds):
    """Queries between mutation and drain see correct (current-membership)
    results: un-restored clusters regenerate, stale storage is bypassed."""
    er = _fresh(ds, slo_s=0.05, cache_bytes=0, maintenance="deferred")
    ref = _fresh(ds, slo_s=0.05, cache_bytes=0)
    rng = np.random.default_rng(6)
    nid = 660_000
    for k in range(10):
        text, _ = _mk_chunk(ds, nid, ds.embeddings[rng.integers(ds.n)], rng)
        er.insert(nid, text)
        ref.insert(nid, text)
        nid += 1
    assert len(er.maintenance) > 0                   # restores still queued
    ids, vals, _ = er.search_batch(ds.query_embs[:8], 10, 5)
    r_ids, r_vals, _ = ref.search_batch(ds.query_embs[:8], 10, 5)
    assert np.array_equal(ids, r_ids)
    assert np.array_equal(vals, r_vals)


def test_engine_drains_after_decode(ds):
    er = _fresh(ds, slo_s=0.05, maintenance="deferred")
    rng = np.random.default_rng(7)
    nid = 670_000
    for k in range(6):
        text, _ = _mk_chunk(ds, nid, ds.embeddings[rng.integers(ds.n)], rng)
        er.insert(nid, text)
        nid += 1
    assert len(er.maintenance) > 0
    eng = RAGEngine(er, None, k=5, nprobe=4)
    out = eng.answer_batch(["q0", "q1"], ds.query_embs[:2], ds.get_chunks)
    assert len(er.maintenance) == 0                  # drained post-decode
    assert out[0].maintenance_s > 0
    # maintenance is off the TTFT critical path
    assert out[0].ttft_edge_s == pytest.approx(
        out[0].retrieval.retrieval_s + out[0].prefill_edge_s)


def test_request_scheduler_maintenance_hook():
    sched = RequestScheduler()
    for arrival in (0.0, 10.0, 10.1):
        sched.submit(arrival)
    gaps = []

    def maintenance(gap_s):
        gaps.append(gap_s)
        return 5.0

    done = sched.run(lambda r: 1.0, maintenance_fn=maintenance)
    # r0: 0→1, idle until 10 → maintenance runs 1→6 (fully hidden),
    #     and is told the 9 s gap so it can size its drain to fit
    # r1: 10→11; r2 already waiting (10.1) → maintenance YIELDS
    # r2: 11→12; queue empty → maintenance runs 12→17 (gap None)
    assert done[0].latency_s == pytest.approx(1.0)
    assert done[1].latency_s == pytest.approx(1.0)
    assert done[2].latency_s == pytest.approx(12.0 - 10.1)
    assert sched.maintenance_s == pytest.approx(10.0)
    assert gaps == [pytest.approx(9.0), None]


# ----------------------------------------------------------------------
# §5.4 bugfixes (satellites)
# ----------------------------------------------------------------------
def test_probe_fills_nprobe_on_merge_heavy_index(ds):
    """Tombstoned centroids must not crowd live clusters out of the probe
    set: after heavy merging every query still probes min(nprobe, live)."""
    er = _fresh(ds, slo_s=10.0, store_heavy=False, cache_bytes=0,
                merge_min_size=3)
    victims = [cid for cid, c in enumerate(er.clusters) if c.size >= 3]
    for cid in victims[:8]:                          # merge 8 clusters away
        while er.clusters[cid].active and er.clusters[cid].size > 0:
            er.remove(int(er.clusters[cid].ids[0]))
    n_dead = sum(not c.active for c in er.clusters)
    assert n_dead >= 4
    n_live = sum(1 for c in er.clusters if c.active and c.size > 0)
    nprobe = 8
    _, _, lats = er.search_batch(ds.query_embs, 10, nprobe)
    for lat in lats:
        assert lat.n_clusters_probed == min(nprobe, n_live)
    _check_invariants(er)


def test_insert_assigns_by_raw_ip_unnormalized_embedder():
    """Insert uses the same un-normalized IP assignment as build/probe, so
    a non-unit-norm embedder cannot land chunks in clusters the chunk's own
    embedding never probes."""
    rng = np.random.default_rng(9)
    dim, n = 16, 80
    embs = (rng.standard_normal((n, dim)) * 5.0).astype(np.float32)
    table = {i: embs[i] for i in range(n)}
    store = {i: f"doc-{i} body text" for i in range(n)}
    er = EdgeRAGIndex(
        dim, TableEmbedder(table, dim),
        lambda ids: [store[int(i)] for i in ids],
        EdgeCostModel(), slo_s=10.0, store_heavy=False, cache_bytes=0)
    er.build(list(range(n)), [store[i] for i in range(n)], nlist=8,
             embeddings=embs, seed=0)
    for nid, scale in ((200, 7.3), (201, 0.02)):
        new = (rng.standard_normal(dim) * scale).astype(np.float32)
        table[nid] = new
        store[nid] = f"doc-{nid} fresh"
        cid = er.insert(nid, store[nid])
        # assignment == what a probe with the chunk's own raw embedding
        # sees, at ANY norm: nprobe=1 probes exactly the home cluster
        assert cid == int(np.argmax(er.centroids @ new))
        probed = er._probe(new[None], 1)[0]
        assert probed == [cid]
    # at a dominant norm the chunk is also retrieved outright
    ids, _, _ = er.search(table[200], 5, 1)
    assert 200 in ids[0].tolist()


def test_insert_never_lands_in_tombstoned_cluster(ds):
    """Buried tombstone centroids can outrank every live centroid (the
    _probe premise); insert must mask them or the chunk is appended to an
    inactive cluster no search ever returns."""
    er = _fresh(ds, slo_s=10.0, store_heavy=False, cache_bytes=0,
                merge_min_size=3)
    victims = [cid for cid, c in enumerate(er.clusters) if c.size >= 3][:3]
    for cid in victims:
        while er.clusters[cid].active and er.clusters[cid].size > 0:
            er.remove(int(er.clusters[cid].ids[0]))
    assert any(not c.active for c in er.clusters)
    nid = 740_000
    emb = -np.ones(32, np.float32)       # maximal IP with buried centroids
    text = f"doc-{nid} adversarial"
    ds.add_chunk(nid, text, emb)
    cid = er.insert(nid, text)
    assert er.clusters[cid].active
    ids, _, _ = er.search(emb, 5, er.nlist)
    assert nid in ids[0].tolist()
    _check_invariants(er)


def test_revalidated_split_still_reconciles_storage(ds):
    """A queued split supersedes the restore at enqueue time; if the
    cluster shrinks back under the bound before the drain, the revalidated
    split must fall through to storage reconciliation (Alg. 1) instead of
    vanishing with the restore it absorbed."""
    er = _fresh(ds, slo_s=0.2, maintenance="deferred")
    target = max((cid for cid, c in enumerate(er.clusters)
                  if c.active and not c.stored),
                 key=lambda c: er.clusters[c].char_count)
    cl = er.clusters[target]
    er.split_max_chars = cl.char_count + 4_000
    shrink_by = int(cl.ids[0])           # an original ~300-char chunk
    # one insert crosses BOTH the SLO and the split bound by a whisker:
    # only OP_SPLIT is enqueued (it supersedes the restore)
    need = er.split_max_chars - cl.char_count + 100
    nid = 730_000
    text, _ = _mk_chunk(ds, nid, er.centroids[target],
                        np.random.default_rng(14), n_words=need // 4 + 1)
    assert er.insert(nid, text) == target
    assert cl.char_count > er.split_max_chars
    assert cl.gen_latency_est > er.slo_s
    assert (OP_SPLIT, target) in er.maintenance._queue
    assert (OP_RESTORE, target) not in er.maintenance._queue
    er.remove(shrink_by)                 # back under the bound, still >SLO
    assert cl.char_count <= er.split_max_chars
    assert cl.gen_latency_est > er.slo_s
    rep = er.maintenance.drain(None)
    assert (OP_RESTORE, target) in rep.executed
    assert cl.stored and target in er.storage
    _check_invariants(er, quiescent=True)


def test_degenerate_split_still_reconciles_storage():
    """A cluster of duplicate embeddings cannot split (k=2 puts everything
    in one part); the degenerate split must still perform the storage
    reconciliation it superseded, or an over-SLO cluster stays un-stored
    forever."""
    rng = np.random.default_rng(15)
    dim = 16
    dup = rng.standard_normal(dim).astype(np.float32)
    dup /= np.linalg.norm(dup)
    n_dup, n = 10, 40
    embs = rng.standard_normal((n, dim)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    embs[:n_dup] = dup                   # one cluster of identical vectors
    store = {i: f"doc-{i} " + "tok " * 45 for i in range(n)}   # ~190 chars
    table = {i: embs[i] for i in range(n)}
    er = EdgeRAGIndex(
        dim, TableEmbedder(table, dim),
        lambda ids: [store[int(i)] for i in ids],
        EdgeCostModel(), slo_s=0.05, store_heavy=True, cache_bytes=0,
        split_max_chars=2_500)
    er.build(list(range(n)), [store[i] for i in range(n)], nlist=6,
             embeddings=embs, seed=0)
    target = er._chunk_cluster[0]
    assert all(er._chunk_cluster[i] == target for i in range(n_dup))
    cl = er.clusters[target]
    assert not cl.stored                 # under the SLO at build time
    nid = 100
    table[nid] = dup
    store[nid] = f"doc-{nid} " + "tok " * 250        # pushes over both bounds
    target = er.insert(nid, store[nid])
    cl = er.clusters[target]                         # split may replace slot
    assert nid in cl.ids.tolist()
    assert cl.char_count > er.split_max_chars        # duplicates can't split
    assert cl.gen_latency_est > er.slo_s
    assert cl.stored and target in er.storage        # reconciled anyway
    assert cl.storage_fresh


def test_merge_resets_stored_flag(ds):
    er = _fresh(ds, slo_s=1e-6, merge_min_size=3)    # everything stored
    victim = next(cid for cid, c in enumerate(er.clusters)
                  if c.active and 3 <= c.size <= 30)
    assert er.clusters[victim].stored
    while er.clusters[victim].active and er.clusters[victim].size > 0:
        er.remove(int(er.clusters[victim].ids[0]))   # ends in a merge
    cl = er.clusters[victim]
    assert not cl.active
    assert not cl.stored                             # the fixed flag
    assert victim not in er.storage
    _check_invariants(er)


def test_insert_returns_post_split_cluster(ds):
    er = _fresh(ds, slo_s=10.0, store_heavy=False, cache_bytes=0,
                split_max_chars=6_000)
    rng = np.random.default_rng(10)
    nid = 680_000
    moved = False
    for k in range(60):
        target = rng.integers(ds.n)
        text, _ = _mk_chunk(ds, nid, ds.embeddings[target], rng,
                            n_words=int(rng.integers(20, 200)))
        pre = int(np.argmax(er.centroids @ ds.embedder.table[nid]))
        ret = er.insert(nid, text)
        assert ret == er._chunk_cluster[nid]
        assert nid in er.clusters[ret].ids.tolist()  # the actual home
        moved = moved or (ret != pre)
        nid += 1
    assert er.nlist > 16                             # splits happened
    assert moved            # at least one split relocated the fresh chunk
    _check_invariants(er)


# ----------------------------------------------------------------------
# property-style churn over the Table-4 configs
# ----------------------------------------------------------------------
TABLE4 = [
    dict(store_heavy=False, cache_bytes=0),          # IVF+Embed.Gen.
    dict(store_heavy=True, cache_bytes=0),           # IVF+Embed.Gen.+Load
    dict(store_heavy=True, cache_bytes=1 << 20),     # EdgeRAG
]


@pytest.mark.parametrize("cfg", TABLE4,
                         ids=["gen", "gen+load", "edgerag"])
def test_churn_invariants_across_table4_configs(ds, cfg):
    er = _fresh(ds, slo_s=0.05, split_max_chars=10_000, merge_min_size=2,
                **cfg)
    rng = np.random.default_rng(11)
    nid = 700_000
    for step in range(90):
        r = rng.random()
        if r < 0.35:
            text, _ = _mk_chunk(ds, nid, ds.embeddings[rng.integers(ds.n)],
                                rng, n_words=int(rng.integers(5, 250)))
            er.insert(nid, text)
            nid += 1
        elif r < 0.70 and er.ntotal > 10:
            er.remove(int(rng.choice(sorted(er._chunk_cluster))))
        else:
            qi = int(rng.integers(len(ds.query_embs)))
            ids, _, _ = er.search(ds.query_embs[qi], 10, 6)
            assert all(int(i) in er._chunk_cluster
                       for i in ids[0] if i >= 0)
        _check_invariants(er, quiescent=True)        # after EVERY op


def test_churn_stream_matches_oracle_rebuild(ds):
    """Tentpole acceptance: after a churn stream + full drain, the index's
    chunk-assignment invariants are bit-identical to an oracle index built
    from scratch on the surviving corpus."""
    er = _fresh(ds, slo_s=0.1, split_max_chars=12_000, merge_min_size=2,
                maintenance="deferred")
    rng = np.random.default_rng(12)
    nid = 710_000
    for step in range(80):
        if rng.random() < 0.5:
            text, _ = _mk_chunk(ds, nid, ds.embeddings[rng.integers(ds.n)],
                                rng, n_words=int(rng.integers(5, 150)))
            er.insert(nid, text)
            nid += 1
        else:
            er.remove(int(rng.choice(sorted(er._chunk_cluster))))
        if step % 7 == 0:
            er.maintenance.drain(0.4)                # budgeted mid-stream
    er.maintenance.drain(None)
    live = sorted(er._chunk_cluster)
    oracle = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(),
                          slo_s=0.1, split_max_chars=12_000,
                          merge_min_size=2)
    texts = ds.get_chunks(live)
    oracle.build(live, texts,
                 nlist=max(4, sum(1 for c in er.clusters if c.active)),
                 embeddings=np.stack([ds.embedder.table[i] for i in live]))
    assert sorted(oracle._chunk_cluster) == live     # identical live set
    assert oracle.ntotal == er.ntotal
    assert (sum(c.char_count for c in oracle.clusters if c.active)
            == sum(c.char_count for c in er.clusters if c.active))
    assert er._chunk_chars == oracle._chunk_chars
    _check_invariants(er, quiescent=True)
    _check_invariants(oracle, quiescent=True)


def test_scheduler_revalidates_stale_ops(ds):
    """Queued ops are re-validated at drain time: a split whose cluster
    shrank back and a restore whose cluster became cheap are skipped or
    redirected instead of blindly applied."""
    er = _fresh(ds, slo_s=0.05, merge_min_size=2, maintenance="deferred")
    rng = np.random.default_rng(13)
    nid = 720_000
    target = max((cid for cid, c in enumerate(er.clusters) if c.active),
                 key=lambda c: er.clusters[c].char_count)
    # cap just above the biggest cluster so only OUR inserts cross it
    er.split_max_chars = er.clusters[target].char_count + 3_000
    added = []
    while er.clusters[target].char_count <= er.split_max_chars:
        text, _ = _mk_chunk(ds, nid, er.centroids[target], rng, n_words=300)
        if er.insert(nid, text) == target:
            added.append(nid)
        nid += 1
    assert (OP_SPLIT, target) in er.maintenance._queue
    for i in added:                                  # shrink it back
        er.remove(i)
    assert er.clusters[target].char_count <= er.split_max_chars
    rep = er.maintenance.drain(None)
    # the stale split is never applied: it is skipped outright or
    # redirected to the storage reconciliation it superseded at enqueue
    assert (OP_SPLIT, target) not in rep.executed
    assert ((OP_SPLIT, target) in rep.skipped
            or (OP_RESTORE, target) in rep.executed)
    _check_invariants(er, quiescent=True)
