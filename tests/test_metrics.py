"""Prometheus-style metrics: primitive semantics, exposition format, and
the three collectors (scheduler, pipeline trace, tenant router)."""
import numpy as np
import pytest

from repro.core import EdgeCostModel, TenantRouter
from repro.data import generate_dataset
from repro.serving.engine import RAGEngine
from repro.serving.metrics import (DEFAULT_BUCKETS, Counter, Gauge,
                                   Histogram, MetricsRegistry,
                                   collect_durability,
                                   collect_pipeline_trace, collect_router,
                                   collect_scheduler)
from repro.serving.pipeline import PipelineBatch, StagedPipeline
from repro.serving.scheduler import RequestScheduler, TokenBucketAdmission

pytestmark = pytest.mark.fast


# ----------------------------------------------------------------------
# primitives
# ----------------------------------------------------------------------
def test_counter_inc_and_labels():
    c = Counter("edgerag_requests_total", "Requests.")
    c.inc(labels={"tenant": "a", "outcome": "met"})
    c.inc(2.0, labels={"tenant": "a", "outcome": "met"})
    c.inc(labels={"tenant": "b", "outcome": "missed"})
    assert c.value({"tenant": "a", "outcome": "met"}) == 3.0
    assert c.value({"outcome": "met", "tenant": "a"}) == 3.0   # order-free
    assert c.value({"tenant": "b", "outcome": "missed"}) == 1.0
    assert c.value({"tenant": "zz", "outcome": "met"}) == 0.0
    with pytest.raises(AssertionError):
        c.inc(-1.0, labels={"tenant": "a"})     # counters only go up


def test_gauge_set_and_overwrite():
    g = Gauge("edgerag_cache_bytes", "Bytes.")
    g.set(10.0, labels={"tenant": "a"})
    g.set(4.0, labels={"tenant": "a"})
    assert g.value({"tenant": "a"}) == 4.0
    g.inc(1.5, labels={"tenant": "a"})
    assert g.value({"tenant": "a"}) == 5.5
    g.set(7.0)                                  # label-less sample
    assert g.value() == 7.0


def test_histogram_buckets_are_cumulative():
    h = Histogram("h_seconds", "H.", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    samples = {kv[-1][1]: value for suffix, kv, value in h.samples()
               if suffix == "_bucket"}
    assert samples["0.1"] == 1
    assert samples["1"] == 3            # cumulative: includes the 0.05
    assert samples["10"] == 4
    assert samples["+Inf"] == 5
    assert h.count() == 5
    assert h.sum() == pytest.approx(56.05)


def test_histogram_quantile_interpolates():
    h = Histogram("h_seconds", "H.", buckets=(1.0, 2.0, 4.0))
    for v in [0.5] * 50 + [1.5] * 50:
        h.observe(v)
    assert 0.0 < h.quantile(0.25) <= 1.0
    q99 = h.quantile(0.99)
    assert 1.0 < q99 <= 2.0
    # empty histogram: quantile is 0, not NaN
    assert Histogram("e", "E.").quantile(0.5) == 0.0


def test_default_buckets_span_serving_range():
    assert DEFAULT_BUCKETS[0] <= 1e-3
    assert DEFAULT_BUCKETS[-1] >= 60.0
    assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)


# ----------------------------------------------------------------------
# registry + exposition format
# ----------------------------------------------------------------------
def test_registry_render_format():
    reg = MetricsRegistry()
    c = reg.counter("edgerag_requests_total", "Total requests.")
    c.inc(labels={"tenant": "alice"})
    reg.gauge("edgerag_memory_bytes", "Resident bytes.").set(123.0)
    h = reg.histogram("edgerag_ttft_seconds", "TTFT.", buckets=(1.0,))
    h.observe(0.5)
    text = reg.render()
    assert text.endswith("\n")
    lines = text.splitlines()
    assert "# HELP edgerag_requests_total Total requests." in lines
    assert "# TYPE edgerag_requests_total counter" in lines
    assert 'edgerag_requests_total{tenant="alice"} 1' in lines
    assert "# TYPE edgerag_memory_bytes gauge" in lines
    assert "edgerag_memory_bytes 123" in lines
    assert "# TYPE edgerag_ttft_seconds histogram" in lines
    assert 'edgerag_ttft_seconds_bucket{le="1"} 1' in lines
    assert 'edgerag_ttft_seconds_bucket{le="+Inf"} 1' in lines
    assert "edgerag_ttft_seconds_sum 0.5" in lines
    assert "edgerag_ttft_seconds_count 1" in lines


def test_registry_same_name_returns_same_metric():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "X.")
    b = reg.counter("x_total", "X.")
    assert a is b
    assert "x_total" in reg
    with pytest.raises(AssertionError):
        reg.gauge("x_total", "X.")      # name collision across types


def test_label_value_escaping():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "X.")
    c.inc(labels={"tenant": 'we"ird\\te\nnant'})
    text = reg.render()
    assert r'x_total{tenant="we\"ird\\te\nnant"} 1' in text


# ----------------------------------------------------------------------
# collectors
# ----------------------------------------------------------------------
def test_collect_scheduler_counts_and_admission():
    adm = TokenBucketAdmission(rate_per_s=1.0, burst=1.0)
    sched = RequestScheduler(admission=adm)
    for i in range(10):
        sched.submit(i * 0.01, slo_s=100.0, tenant="a")
    sched.run(lambda req: 0.5)
    reg = MetricsRegistry()
    collect_scheduler(reg, sched)
    counts = sched.outcome_counts()
    req_total = reg.get("edgerag_requests_total")
    assert req_total.value(
        {"tenant": "a", "outcome": "met"}) == counts["met"]
    assert req_total.value(
        {"tenant": "a", "outcome": "rejected"}) == counts["rejected"]
    dec = reg.get("edgerag_admission_decisions_total")
    assert (dec.value({"tenant": "a", "decision": "admitted"})
            == adm.admitted["a"])
    assert dec.value({"tenant": "a", "decision": "shed"}) == adm.shed["a"]
    ttft = reg.get("edgerag_request_ttft_seconds")
    # rejected requests never started: only served ones have a TTFT sample
    served = counts["met"] + counts["missed"]
    assert ttft.count({"tenant": "a"}) == served
    assert served + counts["rejected"] == 10


def _serving_stack(corpora, cost):
    router = TenantRouter(32, cost, slo_s=0.002, cache_bytes=1 << 20)
    for t, ds in enumerate(corpora):
        ix = router.create_tenant(f"t{t}", ds.embedder, ds.get_chunks)
        ix.build(ds.chunk_ids, ds.texts, nlist=10,
                 embeddings=ds.embeddings, seed=1)
    eng = RAGEngine(router, None, cost_model=cost, k=4, nprobe=3,
                    maintenance_owner="external")
    return router, eng


def test_collect_pipeline_trace_and_router():
    cost = EdgeCostModel()
    corpora = [generate_dataset(n_records=300, dim=32, n_topics=8,
                                n_queries=4, seed=60 + t) for t in range(2)]
    router, eng = _serving_stack(corpora, cost)
    pipe = StagedPipeline(eng, None)
    embs = np.stack([corpora[0].query_embs[0], corpora[1].query_embs[0]])
    _, trace = pipe.run([
        PipelineBatch(queries=["q", "q"], query_embs=embs, arrival_s=0.0,
                      tenants=["t0", "t1"]),
        PipelineBatch(queries=["q", "q"], query_embs=embs, arrival_s=1e-4,
                      tenants=["t1", "t0"])])
    reg = MetricsRegistry()
    collect_pipeline_trace(reg, trace)
    busy = reg.get("edgerag_stage_busy_seconds")
    assert busy.value({"stage": "s2"}) == pytest.approx(
        trace.stages["s2"].busy_s)
    assert (reg.get("edgerag_stage_fired_total").value({"stage": "s4"})
            == trace.stages["s4"].n_fired)
    assert (reg.get("edgerag_pipeline_makespan_seconds").value()
            == pytest.approx(trace.makespan_s))
    collect_router(reg, router)
    pt = router.cache.per_tenant
    for t in ("t0", "t1"):
        labels = {"tenant": t}
        assert (reg.get("edgerag_cache_bytes").value(labels)
                == pt.get(t, {}).get("bytes", 0))
        assert (reg.get("edgerag_storage_bytes").value(labels)
                == router.storage.tenant_bytes(t))
    assert (reg.get("edgerag_cache_capacity_bytes").value()
            == router.cache.capacity_bytes)
    assert (reg.get("edgerag_memory_bytes").value()
            == router.memory_bytes())
    # one registry renders all three collectors without duplicate blocks
    text = reg.render()
    assert text.count("# TYPE edgerag_stage_busy_seconds") == 1


def test_collect_durability_fields(tmp_path):
    """collect_durability mirrors Durability.stats() exactly: WAL record
    and byte counters, snapshot/compaction counters, modeled fsync edge
    seconds, and the last-recovery gauge (0 until a recovery ran)."""
    from repro.core import Durability, EdgeRAGIndex
    ds = generate_dataset(n_records=80, dim=16, n_topics=4, n_queries=2,
                          seed=31)
    ix = EdgeRAGIndex(16, ds.embedder, ds.get_chunks, slo_s=0.004,
                      storage_mode="disk", storage_root=str(tmp_path),
                      maintenance="sync")
    ix.build(ds.chunk_ids, ds.texts, nlist=4, embeddings=ds.embeddings)
    dur = ix.attach_durability(Durability(str(tmp_path), cost_model=None,
                                          checkpoint_every=3))
    for j in range(5):
        ds.add_chunk(9_000 + j, f"fresh chunk {j} " * 20)
        ix.insert(9_000 + j, f"fresh chunk {j} " * 20)
    st = dur.stats()
    assert st["wal_records_total"] == 5 and st["snapshots_total"] >= 2
    reg = MetricsRegistry()
    collect_durability(reg, dur)
    assert reg.get("edgerag_wal_records_total").value() == 5
    assert (reg.get("edgerag_wal_bytes").value() == st["wal_bytes"]
            == dur.wal.nbytes())
    assert (reg.get("edgerag_snapshots_total").value()
            == st["snapshots_total"])
    assert (reg.get("edgerag_wal_compactions_total").value()
            == st["wal_compactions_total"])
    assert (reg.get("edgerag_wal_fsync_edge_seconds_total").value()
            == pytest.approx(st["fsync_edge_s_total"])) and \
        st["fsync_edge_s_total"] > 0.0
    assert reg.get("edgerag_recovery_seconds").value() == 0.0  # none ran
    text = reg.render()
    assert "# TYPE edgerag_wal_records_total counter" in text
    assert "# TYPE edgerag_recovery_seconds gauge" in text


def test_collect_router_emits_per_tenant_durability(tmp_path):
    """With router durability enabled, collect_router labels every
    durability series by tenant; without it, the series are absent."""
    cost = EdgeCostModel()
    corpora = [generate_dataset(n_records=200, dim=32, n_topics=6,
                                n_queries=2, seed=70 + t) for t in range(2)]
    router, _ = _serving_stack(corpora, cost)
    reg0 = MetricsRegistry()
    collect_router(reg0, router)
    assert "edgerag_wal_records_total" not in reg0
    router.enable_durability(str(tmp_path), checkpoint_every=100)
    for t, ds in zip(("t0", "t1"), corpora):
        ds.add_chunk(5_000, "tenant-local new chunk " * 10)
        router.tenants[t].insert(5_000, "tenant-local new chunk " * 10)
    reg = MetricsRegistry()
    collect_router(reg, router)
    for t in ("t0", "t1"):
        labels = {"tenant": t}
        st = router.tenants[t].durability.stats()
        assert (reg.get("edgerag_wal_records_total").value(labels)
                == st["wal_records_total"] >= 1)
        assert (reg.get("edgerag_snapshots_total").value(labels)
                == st["snapshots_total"] >= 1)   # enable() baselines
        assert (reg.get("edgerag_wal_bytes").value(labels)
                == st["wal_bytes"] > 0)
