import os
import sys

# tests run on ONE cpu device (the dry-run alone forces 512 — never here)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
