"""Sequence-mixer correctness: chunked algorithms vs token-by-token oracles,
and attention implementations against each other."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attend_chunked, attend_decode,
                                    attend_reference)
from repro.models.mamba2 import ssd_chunked, ssd_reference
from repro.models.moe import moe_block, init_moe
from repro.models.rwkv6 import wkv6_chunked, wkv6_recurrent

RNG = np.random.default_rng(7)


def _r(shape, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32), (17, 64), (128, 128)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    b, nh, hd, n = 2, 3, 8, 16
    x = _r((b, s, nh, hd))
    log_a = -jnp.abs(_r((b, s, nh), 0.5))
    bb, cc = _r((b, s, n)), _r((b, s, n))
    s0 = _r((b, nh, hd, n), 0.1)
    y1, f1 = ssd_chunked(x, log_a, bb, cc, s0, chunk=chunk)
    y2, f2 = ssd_reference(x, log_a, bb, cc, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=1e-4, rtol=1e-4)


def test_ssd_state_handoff_decode():
    """prefill chunked then 1-token steps == full recurrence."""
    b, nh, hd, n, s = 1, 2, 4, 8, 40
    x = _r((b, s, nh, hd))
    log_a = -jnp.abs(_r((b, s, nh), 0.5))
    bb, cc = _r((b, s, n)), _r((b, s, n))
    s0 = jnp.zeros((b, nh, hd, n))
    y_all, _ = ssd_reference(x, log_a, bb, cc, s0)
    y_pre, state = ssd_chunked(x[:, :32], log_a[:, :32], bb[:, :32],
                               cc[:, :32], s0, chunk=16)
    outs = [y_pre]
    for t in range(32, s):
        y_t, state = ssd_reference(x[:, t:t+1], log_a[:, t:t+1],
                                   bb[:, t:t+1], cc[:, t:t+1], state)
        outs.append(y_t)
    y_cat = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_all),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,chunk", [(64, 16), (50, 32), (16, 16), (96, 32)])
def test_wkv6_chunked_matches_recurrence(s, chunk):
    b, h, k = 2, 3, 8
    r, kk, v = _r((b, s, h, k)), _r((b, s, h, k)), _r((b, s, h, k))
    logw = -jnp.abs(_r((b, s, h, k), 0.5)) - 0.05
    u = _r((h, k), 0.2)
    s0 = _r((b, h, k, k), 0.1)
    o1, f1 = wkv6_chunked(r, kk, v, logw, u, s0, chunk=chunk)
    o2, f2 = wkv6_recurrent(r, kk, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=1e-4, rtol=1e-4)


def test_wkv6_strong_decay_stable():
    """extreme decay (w -> 0) must not overflow the chunked path."""
    b, s, h, k = 1, 64, 2, 4
    r, kk, v = _r((b, s, h, k)), _r((b, s, h, k)), _r((b, s, h, k))
    logw = jnp.full((b, s, h, k), -30.0)        # near-total forgetting
    u = _r((h, k))
    s0 = jnp.zeros((b, h, k, k))
    o1, _ = wkv6_chunked(r, kk, v, logw, u, s0, chunk=16)
    o2, _ = wkv6_recurrent(r, kk, v, logw, u, s0)
    assert np.isfinite(np.asarray(o1)).all()
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


# ---------------------------------------------------------------------------
# attention impls agree
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_chunked_attention_matches_reference(causal, window):
    b, s, h, kh, d = 2, 160, 4, 2, 32
    q, k, v = _r((b, s, h, d)), _r((b, s, kh, d)), _r((b, s, kh, d))
    o1 = attend_chunked(q, k, v, causal=causal, window=window, block_kv=64)
    o2 = attend_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_decode_attention_matches_reference_row():
    b, s, h, kh, d = 2, 33, 4, 2, 32
    q, k, v = _r((b, s, h, d)), _r((b, s, kh, d)), _r((b, s, kh, d))
    full = attend_reference(q, k, v, causal=True)
    out = attend_decode(q[:, -1:], k, v, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1:]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_dropless_capacity_exact():
    """with capacity >= T the block equals the dense per-token expert mix."""
    d, ff, e, k = 16, 32, 4, 2
    params = init_moe(jax.random.PRNGKey(0), d, ff, e)
    x = _r((2, 6, d))
    out, aux = moe_block(params, x, num_experts=e, top_k=k, capacity=12)
    # dense reference
    xf = np.asarray(x).reshape(-1, d)
    logits = xf @ np.asarray(params["router"])
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=1)[:, :k]
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        gates = probs[t, top[t]]
        gates = gates / gates.sum()
        for j, ei in enumerate(top[t]):
            g = np.asarray(params["gate"][ei])
            u = np.asarray(params["up"][ei])
            dn = np.asarray(params["down"][ei])
            h = xf[t] @ g
            h = h / (1 + np.exp(-h)) * (xf[t] @ u)
            ref[t] += gates[j] * (h @ dn)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, d), ref,
                               atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_are_partial():
    """tiny capacity zeroes some tokens' expert output (residual passthrough
    happens in the block wrapper, not here)."""
    d, ff, e, k = 8, 16, 4, 2
    params = init_moe(jax.random.PRNGKey(1), d, ff, e)
    x = _r((1, 16, d))
    full, _ = moe_block(params, x, num_experts=e, top_k=k, capacity=32)
    tiny, _ = moe_block(params, x, num_experts=e, top_k=k, capacity=1)
    assert float(jnp.abs(full - tiny).max()) > 1e-6
