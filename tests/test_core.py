"""EdgeRAG core behaviour: index equivalence, selective storage (Alg. 1),
online updates (§5.4), and the Table 4 ablation orderings."""
import numpy as np
import pytest

from repro.core import (EdgeCostModel, EdgeRAGIndex, FlatIndex, IVFIndex,
                        kmeans)
from repro.data import generate_dataset


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=1200, dim=48, n_topics=40,
                            n_queries=120, seed=3)


@pytest.fixture(scope="module")
def stack(ds):
    cost = EdgeCostModel()
    flat = FlatIndex(48, cost)
    flat.add(ds.embeddings, ds.chunk_ids)
    ivf = IVFIndex(48, cost)
    ivf.build(ds.embeddings, ds.chunk_ids, nlist=40, seed=1)
    er = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, cost, slo_s=0.3,
                      cache_bytes=1 << 20)
    er.build(ds.chunk_ids, ds.texts, nlist=40, embeddings=ds.embeddings,
             seed=1)
    return flat, ivf, er


def test_kmeans_assigns_nearest_centroid(ds):
    cents, assign = kmeans(ds.embeddings, 16, iters=5, seed=0)
    x = ds.embeddings / np.linalg.norm(ds.embeddings, axis=1, keepdims=True)
    sims = x @ cents.T
    np.testing.assert_array_equal(assign, sims.argmax(1))
    np.testing.assert_allclose(np.linalg.norm(cents, axis=1), 1.0, atol=1e-5)


def test_edgerag_results_identical_to_ivf(stack, ds):
    """§6.3.1: EdgeRAG retrieval ≡ two-level IVF retrieval (same clustering)."""
    _, ivf, er = stack
    for qi in range(40):
        i_ids, i_vals, _ = ivf.search(ds.query_embs[qi], 10, 5)
        e_ids, e_vals, _ = er.search(ds.query_embs[qi], 10, 5)
        assert set(i_ids[0].tolist()) == set(e_ids[0].tolist())
        np.testing.assert_allclose(np.sort(i_vals[0]), np.sort(e_vals[0]),
                                   atol=1e-4)


def test_recall_improves_with_nprobe(stack, ds):
    flat, ivf, _ = stack
    recs = []
    for nprobe in (1, 4, 16, 40):
        hits = 0
        for qi in range(40):
            f_ids, _, _ = flat.search(ds.query_embs[qi], 10)
            i_ids, _, _ = ivf.search(ds.query_embs[qi], 10, nprobe)
            hits += len(set(f_ids[0].tolist()) & set(i_ids[0].tolist()))
        recs.append(hits / (40 * 10))
    assert recs[-1] > 0.999       # probing everything == exhaustive
    assert recs == sorted(recs)   # monotone in nprobe


def test_selective_storage_invariant(ds):
    """Alg. 1: exactly the clusters whose regeneration exceeds the SLO are
    stored; pruned memory stays tiny."""
    cost = EdgeCostModel()
    er = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, cost, slo_s=0.15)
    er.build(ds.chunk_ids, ds.texts, nlist=40, embeddings=ds.embeddings)
    for cid, cl in enumerate(er.clusters):
        expected = cl.gen_latency_est > er.slo_s
        assert cl.stored == expected
        assert (cid in er.storage) == expected
    # pruning: resident memory is centroids + (empty) cache only
    assert er.memory_bytes() <= er.centroids.nbytes + 1
    full = ds.embeddings.nbytes
    assert er.memory_bytes() < 0.1 * full


def test_store_heavy_false_never_stores(ds):
    er = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.01, store_heavy=False, cache_bytes=0)
    er.build(ds.chunk_ids, ds.texts, nlist=40, embeddings=ds.embeddings)
    assert er.storage_bytes() == 0
    ids, _, lat = er.search(ds.query_embs[0], 5, 3)
    assert lat.n_generated == lat.n_clusters_probed  # everything regenerated
    assert lat.n_cache_hits == 0


def test_cache_reduces_regeneration(ds):
    cost = EdgeCostModel()
    er = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, cost, slo_s=10.0,
                      cache_bytes=4 << 20)
    er.build(ds.chunk_ids, ds.texts, nlist=40, embeddings=ds.embeddings)
    gen_calls = []
    for qi in range(80):
        _, _, lat = er.search(ds.query_embs[qi], 10, 4)
        gen_calls.append(lat.n_generated)
    # Zipf reuse: later queries mostly hit the cache
    assert sum(gen_calls[40:]) < sum(gen_calls[:40])
    assert er.cache.hit_rate > 0.3


def test_insert_then_retrievable(ds):
    er = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.5, cache_bytes=1 << 20)
    er.build(ds.chunk_ids, ds.texts, nlist=40, embeddings=ds.embeddings)
    new_id = 777_777
    emb = ds.embeddings[5] + 0.01 * np.random.default_rng(0).standard_normal(48)
    emb = (emb / np.linalg.norm(emb)).astype(np.float32)
    ds.add_chunk(new_id, f"doc-{new_id} fresh chunk", emb)
    cid = er.insert(new_id, ds.get_chunks([new_id])[0])
    assert cid >= 0
    ids, _, _ = er.search(emb, 5, 6)
    assert new_id in ids[0].tolist()
    # removal really removes
    er.remove(new_id)
    ids, _, _ = er.search(emb, 5, 40)
    assert new_id not in ids[0].tolist()
    assert er.ntotal == ds.n


def test_split_keeps_all_chunks_retrievable(ds):
    er = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.5, split_max_chars=200)
    er.build(ds.chunk_ids, ds.texts, nlist=40, embeddings=ds.embeddings)
    n0, total0 = er.nlist, er.ntotal
    # trigger a split by inserting into some cluster
    new_id = 888_888
    emb = ds.embeddings[0].copy()
    ds.add_chunk(new_id, f"doc-{new_id} " + "pad " * 64, emb)
    er.insert(new_id, ds.get_chunks([new_id])[0])
    assert er.nlist > n0
    assert er.ntotal == total0 + 1


def test_merge_preserves_total(ds):
    er = EdgeRAGIndex(48, ds.embedder, ds.get_chunks, EdgeCostModel(),
                      slo_s=0.5, merge_min_size=3)
    er.build(ds.chunk_ids, ds.texts, nlist=40, embeddings=ds.embeddings)
    small_cid, small = min(((i, c) for i, c in enumerate(er.clusters)
                            if c.active and c.size > 1),
                           key=lambda t: t[1].size)
    victim = int(small.ids[0])
    survivors = [int(i) for i in small.ids[1:]]
    total0 = er.ntotal
    er.remove(victim)
    assert er.ntotal == total0 - 1
    if not er.clusters[small_cid].active:      # merged away
        # survivors live in some other active cluster
        all_ids = np.concatenate([c.ids for c in er.clusters if c.active])
        for s in survivors:
            assert s in all_ids


def test_latency_accounting_consistency(stack, ds):
    _, _, er = stack
    _, _, lat = er.search(ds.query_embs[0], 10, 5,
                          query_chars=int(ds.query_chars[0]))
    d = lat.as_dict()
    parts = (d["embed_query_s"] + d["centroid_search_s"] + d["l2_generate_s"]
             + d["l2_storage_load_s"] + d["l2_dequant_s"]
             + d["l2_cache_hit_s"] + d["l2_mem_load_s"] + d["l2_search_s"]
             + d["l2_slab_pack_s"] + d["l2_fused_dequant_s"]
             + d["l2_stall_s"] + d["l2_retry_backoff_s"])
    assert abs(parts - d["retrieval_s"]) < 1e-12
    assert d["l2_slab_pack_s"] > 0          # slab engine packed this batch
    # the fault-model fields stay zero on the fault-free path
    assert d["l2_stall_s"] == 0 and d["l2_retry_backoff_s"] == 0
    assert (lat.retries, lat.degraded_clusters, lat.stale_served) == (0, 0, 0)
    assert lat.n_clusters_probed == 5
    assert (lat.n_generated + lat.n_storage_loads + lat.n_cache_hits
            == lat.n_clusters_probed)
