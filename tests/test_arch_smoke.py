"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers-ish, d_model<=512, <=4 experts) runs one forward
AND one train step on CPU — output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.cache import init_cache
from repro.train.train_step import make_train_step, train_state_init

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32, with_labels=True):
    batch = {}
    if cfg.embedding_inputs:
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.02
    else:
        batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    if cfg.use_mrope:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, b, s))
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jax.random.normal(KEY, (b, 8, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = configs.get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.num_layers <= max(
        2, len(cfg.block_pattern))
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, _, aux = M.forward(params, cfg, batch, mode="train")
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    assert not np.isnan(float(aux))


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = configs.get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    state = train_state_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3))
    batch = make_batch(cfg)
    state1, _ = step(state, batch)         # step 0: lr still in warmup (=0)
    state2, metrics = step(state1, batch)
    assert not np.isnan(float(metrics["loss"]))
    assert not np.isnan(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state2.step) == 2
    # params actually moved
    delta = sum(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(state1.params), jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_prefill_decode_consistency(arch):
    """prefill(S) + decode(1) logits == full forward logits."""
    cfg = configs.get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    B, S = 2, 16
    full_batch = make_batch(cfg, B, S + 1, with_labels=False)
    full_logits, _, _ = M.forward(params, cfg, full_batch, mode="train",
                                  remat=False)
    caches = init_cache(cfg, B, S + 4)
    pre_batch = {k: (v[:, :S] if k != "positions" else v[..., :S])
                 for k, v in full_batch.items()}
    last, caches = M.prefill(params, cfg, pre_batch, caches)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, S - 1]),
                               atol=3e-4)
    nxt = (full_batch["embeds"][:, S:S + 1] if cfg.embedding_inputs
           else full_batch["tokens"][:, S:S + 1])
    dl, _ = M.decode_step(params, cfg, nxt, caches, S)
    np.testing.assert_allclose(np.asarray(dl),
                               np.asarray(full_logits[:, S]), atol=3e-4)


@pytest.mark.parametrize("arch", ["gemma3-12b", "yi-9b", "rwkv6-1.6b",
                                  "zamba2-2.7b"])
def test_window_mode_decode_runs(arch):
    """long-context serving mode: ring-buffer caches accept decode steps."""
    cfg = configs.get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    B = 1
    caches = init_cache(cfg, B, 256, window_mode=True)
    tok = jnp.zeros((B, 1), jnp.int32)
    for step_i in [0, 1, 2]:
        logits, caches = M.decode_step(params, cfg, tok, caches, step_i,
                                       window_mode=True)
        assert logits.shape == (B, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits)).any()


def test_paper_models_smoke():
    for name in configs.PAPER_MODELS:
        cfg = configs.get_config(name).reduced()
        params = M.init_params(cfg, KEY)
        logits, _, _ = M.forward(params, cfg, make_batch(cfg), mode="train")
        assert not np.isnan(np.asarray(logits)).any()


def test_encoder_embeddings_unit_norm():
    cfg = configs.get_config("gte-base-en-v1.5").reduced()
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (3, 24), 0, cfg.vocab_size)
    emb = M.encode(params, cfg, {"tokens": toks})
    assert emb.shape == (3, cfg.d_model)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(emb), axis=1),
                               1.0, atol=1e-5)


def test_shared_attn_params_counted_once():
    cfg = configs.get_config("zamba2-2.7b")
    params_analytic = cfg.param_count()
    red = cfg.reduced()
    params = M.init_params(red, KEY)
    assert "shared" in params
    # the shared block appears once in the tree (not stacked over repeats)
    assert params["shared"]["wq"].ndim == 2
    assert params_analytic < 6.0e9  # sanity: near the 2.7B + margins
