"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ivf_topk.kernel import topk_ip_pallas
from repro.kernels.ivf_topk.ops import topk_ip
from repro.kernels.ivf_topk.ref import topk_ip_ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# ivf_topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,q,k", [
    (1000, 768, 3, 10), (512, 128, 1, 5), (77, 256, 2, 8),
    (2048, 64, 4, 32), (130, 768, 1, 100),
])
def test_ivf_topk_matches_ref(n, d, q, k):
    embs = _rand((n, d))
    qs = _rand((q, d))
    pv, pi = topk_ip_pallas(embs, qs, min(k, n), interpret=True)
    rv, ri = topk_ip_ref(embs, qs, min(k, n))
    np.testing.assert_allclose(np.asarray(pv), np.asarray(rv), atol=2e-4)
    assert (np.asarray(pi) == np.asarray(ri)).all()


@pytest.mark.parametrize("q,block_q", [(1, 8), (3, 2), (16, 8), (9, 4)])
def test_ivf_topk_query_blocking(q, block_q):
    """Multi-query tiling: padded and exact query blocks match the ref."""
    embs = _rand((257, 64))
    qs = _rand((q, 64))
    pv, pi = topk_ip_pallas(embs, qs, 11, block_n=64, block_q=block_q,
                            interpret=True)
    rv, ri = topk_ip_ref(embs, qs, 11)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(rv), atol=2e-4)
    assert (np.asarray(pi) == np.asarray(ri)).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ivf_topk_dtypes(dtype):
    embs = _rand((300, 128), dtype)
    qs = _rand((2, 128), dtype)
    pv, pi = topk_ip_pallas(embs.astype(jnp.float32),
                            qs.astype(jnp.float32), 7, interpret=True)
    rv, ri = topk_ip_ref(embs, qs, 7)
    # scores computed in f32 in both paths
    np.testing.assert_allclose(np.asarray(pv), np.asarray(rv),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-4)


def test_topk_op_pads_when_k_exceeds_n():
    vals, idx = topk_ip(_rand((5, 32)), _rand((1, 32)), 10)
    assert vals.shape == (1, 10) and idx.shape == (1, 10)
    assert (np.asarray(idx)[0, 5:] == -1).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kh,sq,skv,d,causal,win", [
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 4, 4, 256, 256, 32, True, 64),
    (2, 2, 1, 128, 256, 64, False, 0),
    (1, 8, 2, 64, 64, 128, True, 0),
    (1, 2, 2, 192, 192, 64, True, 100),
])
def test_flash_attention_matches_ref(b, h, kh, sq, skv, d, causal, win):
    q, k, v = _rand((b, h, sq, d)), _rand((b, kh, skv, d)), _rand((b, kh, skv, d))
    o1 = flash_attention_pallas(q, k, v, causal=causal, window=win,
                                bq=64, bk=64, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_flash_attention_bf16():
    q, k, v = (_rand((1, 2, 128, 64), jnp.bfloat16) for _ in range(3))
    o1 = flash_attention_pallas(q, k, v, bq=64, bk=64, interpret=True)
    o2 = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kh,smax,d,clen,win", [
    (2, 4, 2, 512, 64, 300, 0),
    (1, 8, 8, 256, 32, 256, 64),
    (3, 4, 1, 512, 128, 17, 0),
    (1, 2, 2, 1024, 64, 1024, 0),
])
def test_decode_attention_matches_ref(b, h, kh, smax, d, clen, win):
    q = _rand((b, h, d))
    kc, vc = _rand((b, smax, kh, d)), _rand((b, smax, kh, d))
    o1 = decode_attention_pallas(q, kc, vc, clen, window=win, bk=128,
                                 interpret=True)
    o2 = decode_attention_ref(q, kc, vc, clen, window=win)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_decode_attention_ring_semantics():
    """circular cache: cache_len >= smax validates everything."""
    q = _rand((1, 4, 64))
    kc, vc = _rand((1, 128, 4, 64)), _rand((1, 128, 4, 64))
    o1 = decode_attention_pallas(q, kc, vc, 10_000, bk=64, interpret=True)
    o2 = decode_attention_ref(q, kc, vc, 10_000)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
