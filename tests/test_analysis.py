"""analysis/: HLO collective parser + roofline arithmetic."""
import numpy as np

from repro.analysis.hlo import collective_bytes, collective_sites
from repro.analysis.roofline import model_flops_estimate, roofline
from repro import configs
from repro.configs.shapes import INPUT_SHAPES

HLO = """
HloModule jit_step
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %p0), replica_groups=[2,8]<=[16], dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64]{0} reduce-scatter(f32[256]{0} %y), replica_groups=[4,4]<=[16], dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(bf16[32,32]{1,0} %z), source_target_pairs={{0,1}}
  %ata = f32[16,16]{1,0} all-to-all(f32[16,16]{1,0} %w), replica_groups=[2,8]<=[16]
  %agd = bf16[8,128]{1,0} all-gather-done(bf16[8,128] %h)
  // %comment = f32[999]{0} all-reduce(...)
"""


def test_collective_bytes_parses_ops_and_groups():
    total, by_op, counts = collective_bytes(HLO)
    # all-gather: 8*128*2 bytes * (8-1)/8
    assert abs(by_op["all-gather"] - 8 * 128 * 2 * 7 / 8) < 1e-6
    # all-reduce: 256*4 * 2*(4-1)/4
    assert abs(by_op["all-reduce"] - 256 * 4 * 2 * 3 / 4) < 1e-6
    # reduce-scatter: result 64*4 * (4-1)
    assert abs(by_op["reduce-scatter"] - 64 * 4 * 3) < 1e-6
    # collective-permute: raw bytes
    assert abs(by_op["collective-permute"] - 32 * 32 * 2) < 1e-6
    # all-to-all: 16*16*4 * 7/8
    assert abs(by_op["all-to-all"] - 16 * 16 * 4 * 7 / 8) < 1e-6
    assert counts["all-gather"] == 1          # -done not double counted
    assert sum(counts.values()) == 5
    assert abs(total - sum(by_op.values())) < 1e-9


def test_collective_sites_attribution():
    hlo = ('%x = f32[1024]{0} all-reduce(f32[1024]{0} %a), '
           'replica_groups={{0,1}}, metadata={op_name="jit(f)/foo/dot"}')
    sites = collective_sites(hlo)
    assert sites[0][1] == "all-reduce"
    assert sites[0][2] == "jit(f)/foo/dot"
    assert sites[0][0] == 4096


def test_roofline_terms_and_dominance():
    rep = roofline(arch="x", shape="train_4k", mesh_name="16x16", chips=256,
                   hlo_flops=197e12, hlo_bytes=819e9, collective_bytes=25e9,
                   collective_by_op={}, model_flops=1e16)
    assert abs(rep.compute_s - 1.0) < 1e-9
    assert abs(rep.memory_s - 1.0) < 1e-9
    assert abs(rep.collective_s - 0.5) < 1e-9
    assert rep.dominant in ("compute", "memory")
    assert rep.step_time_s == 1.0
    assert 0 < rep.mfu < 1


def test_model_flops_scales_with_shape():
    cfg = configs.get_config("yi-9b")
    f_train = model_flops_estimate(cfg, INPUT_SHAPES["train_4k"])
    f_prefill = model_flops_estimate(cfg, INPUT_SHAPES["prefill_32k"])
    f_decode = model_flops_estimate(cfg, INPUT_SHAPES["decode_32k"])
    assert f_train > f_prefill > f_decode > 0
    # decode processes B tokens; 2*N_active*B is a lower bound
    assert f_decode >= 2 * cfg.active_param_count() * 128


def test_moe_active_flops_smaller_than_total():
    cfg = configs.get_config("olmoe-1b-7b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
