"""Tiered cluster-resolution pipeline: ResolutionPlan structure, plan-driven
search parity with sequential search, precomputed-plan execution, coalesced
regeneration groups, the engine's answer wrapper + prefetch overlap, and the
sharded scoring route."""
import jax
import numpy as np
import pytest

from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.resolver import TIER_CACHE, TIER_REGEN, TIER_STORAGE
from repro.data import generate_dataset
from repro.serving.engine import RAGEngine

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def ds():
    return generate_dataset(n_records=900, dim=32, n_topics=30,
                            n_queries=48, seed=7)


def _fresh(ds, **kw):
    kw.setdefault("slo_s", 0.15)
    er = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, EdgeCostModel(), **kw)
    er.build(ds.chunk_ids, ds.texts, nlist=30, embeddings=ds.embeddings,
             seed=1)
    return er


def test_plan_driven_batch_matches_sequential_search(ds):
    """Acceptance: ResolutionPlan-driven search_batch equals the sequential
    per-query search on ids AND scores (fp32 tier)."""
    seq = _fresh(ds, cache_bytes=1 << 20)
    bat = _fresh(ds, cache_bytes=1 << 20)
    nq = 20
    s_ids, s_vals = [], []
    for qi in range(nq):
        ids, vals, _ = seq.search(ds.query_embs[qi], 10, 5)
        s_ids.append(ids[0])
        s_vals.append(vals[0])
    b_ids, b_vals, _ = bat.search_batch(ds.query_embs[:nq], 10, 5)
    assert np.array_equal(np.stack(s_ids), b_ids)
    assert np.array_equal(np.stack(s_vals), b_vals)


def test_plan_structure(ds):
    """Tier assignment: stored clusters -> storage; unknown -> regen on the
    first batch, then cache on the second.  Owner is the lowest-index
    query; every probed cluster is planned exactly once."""
    er = _fresh(ds, cache_bytes=8 << 20)
    plan = er.plan_batch(ds.query_embs[:12], 5)
    assert plan.n_unique == len(plan.tier) == len(plan.owner)
    assert set(plan.tier) == {c for p in plan.probed_per_q for c in p}
    for cid, t in plan.tier.items():
        stored = er.clusters[cid].stored
        assert t == (TIER_STORAGE if stored else TIER_REGEN)
        assert plan.owner[cid] == min(
            qi for qi, p in enumerate(plan.probed_per_q) if cid in p)
    assert set(plan.storage_clusters) == {
        c for c, t in plan.tier.items() if t == TIER_STORAGE}
    # all regens coalesce into ONE group by default
    assert len(plan.regen_groups) <= 1
    assert set(plan.regen_clusters) == {
        c for c, t in plan.tier.items() if t == TIER_REGEN}
    # execute the plan, then re-plan: regenerated clusters now hit the cache
    er.search_batch(ds.query_embs[:12], 10, 5, plan=plan)
    plan2 = er.plan_batch(ds.query_embs[:12], 5)
    for cid in plan.regen_clusters:
        assert plan2.tier[cid] == TIER_CACHE


def test_precomputed_plan_matches_inline(ds):
    """search_batch(plan=plan_batch(...)) is byte-for-byte the inline path
    (ids, scores, every LatencyBreakdown field except wall time)."""
    a = _fresh(ds, cache_bytes=1 << 20)
    b = _fresh(ds, cache_bytes=1 << 20)
    nq = 16
    ids_a, vals_a, lats_a = a.search_batch(ds.query_embs[:nq], 10, 5)
    plan = b.plan_batch(ds.query_embs[:nq], 5)
    ids_b, vals_b, lats_b = b.search_batch(ds.query_embs[:nq], 10, 5,
                                           plan=plan)
    assert np.array_equal(ids_a, ids_b)
    assert np.array_equal(vals_a, vals_b)
    for la, lb in zip(lats_a, lats_b):
        da, db = la.as_dict(), lb.as_dict()
        for key in da:
            if key != "wall_s":
                assert da[key] == db[key], key


def test_regen_group_budget(ds):
    """max_group_chars splits the coalesced regeneration into multiple
    embed_fn calls without changing results."""
    a = _fresh(ds, store_heavy=False, cache_bytes=0)
    b = _fresh(ds, store_heavy=False, cache_bytes=0)
    b.resolver.max_group_chars = 1          # one call per cluster
    nq = 8
    calls0 = ds.embedder.calls
    ids_a, vals_a, _ = a.search_batch(ds.query_embs[:nq], 10, 5)
    one_call = ds.embedder.calls - calls0
    assert one_call == 1
    calls0 = ds.embedder.calls
    ids_b, vals_b, lats = b.search_batch(ds.query_embs[:nq], 10, 5)
    assert ds.embedder.calls - calls0 == sum(l.n_generated for l in lats)
    assert np.array_equal(ids_a, ids_b)
    assert np.array_equal(vals_a, vals_b)


def test_answer_is_thin_wrapper_over_answer_batch(ds):
    """RAGEngine.answer == answer_batch with a batch of one."""
    ea = RAGEngine(_fresh(ds, cache_bytes=1 << 20), None, k=5, nprobe=4)
    eb = RAGEngine(_fresh(ds, cache_bytes=1 << 20), None, k=5, nprobe=4)
    for qi in range(5):
        q = f"query number {qi}"
        ra = ea.answer(q, ds.query_embs[qi], ds.get_chunks)
        rb = eb.answer_batch([q], ds.query_embs[qi][None], ds.get_chunks)[0]
        assert ra.chunk_ids == rb.chunk_ids
        assert ra.context == rb.context
        assert ra.ttft_edge_s == rb.ttft_edge_s
        assert ra.prefill_edge_s == rb.prefill_edge_s
        da, db = ra.retrieval.as_dict(), rb.retrieval.as_dict()
        for key in da:
            if key != "wall_s":
                assert da[key] == db[key], key


def test_prefetch_overlaps_storage_io(ds):
    """answer_batch(prefetch=True): identical retrieval, smaller edge TTFT —
    the plan's storage loads run under the rest of retrieval."""
    base = RAGEngine(_fresh(ds, slo_s=0.05, cache_bytes=0), None,
                     k=5, nprobe=4)
    pre = RAGEngine(_fresh(ds, slo_s=0.05, cache_bytes=0), None,
                    k=5, nprobe=4)
    queries = [f"query {i}" for i in range(8)]
    r0 = base.answer_batch(queries, ds.query_embs[:8], ds.get_chunks)
    r1 = pre.answer_batch(queries, ds.query_embs[:8], ds.get_chunks,
                          prefetch=True)
    assert any(r.retrieval.n_storage_loads > 0 for r in r0)
    saved_total = 0.0
    for a, b in zip(r0, r1):
        assert a.chunk_ids == b.chunk_ids
        assert a.context == b.context
        assert b.prefetch_saved_s >= 0.0
        assert b.ttft_edge_s == pytest.approx(
            a.ttft_edge_s - b.prefetch_saved_s)
        saved_total += b.prefetch_saved_s
    assert saved_total > 0.0


def test_prefetched_plan_survives_storage_delete(ds):
    """A storage key deleted between prefetch and execute falls back to
    regeneration — even though the stale payload was already prefetched."""
    ref = _fresh(ds, slo_s=0.05, cache_bytes=0)
    er = _fresh(ds, slo_s=0.05, cache_bytes=0)
    plan = er.plan_batch(ds.query_embs[:6], 5, prefetch_storage=True)
    assert plan.storage_clusters and plan.prefetched
    for cid in plan.storage_clusters:
        er.storage.delete(cid)
    ids, vals, lats = er.search_batch(ds.query_embs[:6], 10, 5, plan=plan)
    r_ids, r_vals, _ = ref.search_batch(ds.query_embs[:6], 10, 5)
    assert np.array_equal(ids, r_ids)
    assert np.array_equal(vals, r_vals)
    assert sum(l.n_storage_loads for l in lats) == 0
    assert sum(l.n_generated for l in lats) >= len(plan.storage_clusters)
    # self-heal: the vanished storage copies were re-persisted, so the next
    # batch loads instead of regenerating forever
    assert all(cid in er.storage for cid in plan.storage_clusters)
    _, _, lats2 = er.search_batch(ds.query_embs[:6], 10, 5)
    assert sum(l.n_storage_loads for l in lats2) == len(plan.storage_clusters)
    assert sum(l.n_generated for l in lats2) == len(plan.regen_clusters)


def test_stale_cached_plan_payload_falls_back(ds):
    """A cluster mutated between plan and execute invalidates the plan's
    cached payload (size guard) — the cluster regenerates instead of
    scoring a misaligned id map."""
    er = _fresh(ds, store_heavy=False, cache_bytes=8 << 20)
    er.search_batch(ds.query_embs[:6], 10, 5)       # populate the cache
    plan = er.plan_batch(ds.query_embs[:6], 5)
    assert plan.cached
    victim = next(iter(plan.cached))
    new_id = 900_001
    text = "fresh chunk " * 30
    ds.add_chunk(new_id, text, ds.embeddings[0])
    cl = er.clusters[victim]                        # mutate cluster directly
    cl.ids = np.append(cl.ids, np.int64(new_id))
    cl.char_count += len(text)
    er._chunk_chars[new_id] = len(text)
    er._chunk_cluster[new_id] = victim
    ids, vals, lats = er.search_batch(ds.query_embs[:6], 10, 5, plan=plan)
    fresh = _fresh(ds, store_heavy=False, cache_bytes=0)
    fresh.clusters[victim].ids = er.clusters[victim].ids.copy()
    f_ids, f_vals, _ = fresh.search_batch(ds.query_embs[:6], 10, 5)
    assert np.array_equal(ids, f_ids)
    assert np.array_equal(vals, f_vals)
    assert sum(l.n_generated for l in lats) >= 1    # victim regenerated
    # the stale entry was invalidated and replaced, not left to recur
    cached_now = er.cache.access(victim)
    assert cached_now is not None
    assert len(cached_now) == er.clusters[victim].size


def test_sharded_scoring_route_single_device(ds):
    """search_batch(mesh=...) routes scoring through sharded_slab_topk and
    matches the unsharded ids (1-device mesh; the 8-device equivalence runs
    in test_sharded_retrieval.py's subprocess)."""
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    a = _fresh(ds, cache_bytes=1 << 20)
    b = _fresh(ds, cache_bytes=1 << 20)
    ids_a, _, _ = a.search_batch(ds.query_embs[:8], 10, 5)
    ids_b, _, lats = b.search_batch(ds.query_embs[:8], 10, 5, mesh=mesh)
    assert np.array_equal(ids_a, ids_b)
    assert all(l.l2_search_s > 0 for l in lats if l.n_clusters_probed)
