"""Numerical equivalence of the explicit shard_map collectives (§Perf
implementations) against the single-device reference blocks.

Runs in a SUBPROCESS with 8 forced host devices (the pytest process itself
stays on 1 CPU device), mesh (data=2, model=4).
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.distributed import (DistConfig, decode_attention_sharded,
                                      moe_block_ep)
from repro.models.attention import attend_decode
from repro.models.moe import init_moe, moe_block
from repro.models.cache import KVCache

mesh = jax.make_mesh((2, 4), ("data", "model"))
dist = DistConfig(mesh=mesh, data_axes=("data",), moe_impl="ep",
                  decode_attn_impl="sharded")
rng = np.random.default_rng(0)
r = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)

# ---- decode attention: non-ring and ring ----
B, S, H, KH, D = 4, 64, 8, 2, 16
for circular, cache_len in [(False, 37), (True, 200), (False, 63)]:
    q = r(B, 1, H, D)
    kc, vc = r(B, S, KH, D), r(B, S, KH, D)
    kn, vn = r(B, 1, KH, D), r(B, 1, KH, D)
    with mesh:
        out, nk, nv = jax.jit(lambda *a: decode_attention_sharded(
            dist, *a, circular=circular))(q, kc, vc, kn, vn, cache_len)
    # reference: insert then attend
    ref_cache = KVCache(kc, vc).insert(kn, vn, cache_len, circular=circular)
    ref = attend_decode(q, ref_cache.k, ref_cache.v, cache_len + 1,
                        circular=circular)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-4, (circular, cache_len, err)
    cerr = float(jnp.abs(nk - ref_cache.k).max())
    assert cerr == 0.0, (circular, cache_len, cerr)
print("decode_attention_sharded OK")

# ---- MoE EP vs dense-capacity reference ----
d, ff, E, k = 32, 64, 8, 2
params = init_moe(jax.random.PRNGKey(0), d, ff, E)
x = r(2, 8, d)
with mesh:
    out_ep, aux_ep = jax.jit(lambda p, xx: moe_block_ep(
        dist, p, xx, num_experts=E, top_k=k, capacity=16))(params, x)
out_ref, aux_ref = moe_block(params, x, num_experts=E, top_k=k, capacity=16)
err = float(jnp.abs(out_ep - out_ref).max())
assert err < 1e-4, err
assert abs(float(aux_ep) - float(aux_ref)) < 1e-5
print("moe_block_ep OK")

# ---- TP-experts (expert count NOT divisible by the model axis) ----
from repro.models.distributed import moe_block_tp
E2 = 6                                   # 6 % 4 != 0
params2 = init_moe(jax.random.PRNGKey(2), d, ff, E2)
with mesh:
    out_tp, aux_tp = jax.jit(lambda p, xx: moe_block_tp(
        dist, p, xx, num_experts=E2, top_k=k, capacity=16))(params2, x)
ref_tp, refaux_tp = moe_block(params2, x, num_experts=E2, top_k=k, capacity=16)
assert float(jnp.abs(out_tp - ref_tp).max()) < 1e-4
assert abs(float(aux_tp) - float(refaux_tp)) < 1e-5
print("moe_block_tp OK")
'''


def test_shard_map_blocks_match_reference():
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "decode_attention_sharded OK" in res.stdout
    assert "moe_block_ep OK" in res.stdout
    assert "moe_block_tp OK" in res.stdout
