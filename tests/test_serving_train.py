"""Serving engine, scheduler, edge simulator, and training-loop behaviour."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset
from repro.models import model as M
from repro.serving.engine import GeneratorModel, RAGEngine
from repro.serving.scheduler import RequestScheduler
from repro.serving.simulator import EdgeSimulator
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule

pytestmark = pytest.mark.slow
from repro.train.train_step import make_train_step, train_state_init


# ---------------------------------------------------------------------------
# engine e2e
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def rag_setup():
    ds = generate_dataset(n_records=600, dim=32, n_topics=24, n_queries=40,
                          seed=5)
    cost = EdgeCostModel()
    index = EdgeRAGIndex(32, ds.embedder, ds.get_chunks, cost, slo_s=0.3,
                         cache_bytes=1 << 20)
    index.build(ds.chunk_ids, ds.texts, nlist=24, embeddings=ds.embeddings)
    return ds, index, cost


def test_engine_answers_with_context(rag_setup):
    ds, index, cost = rag_setup
    gen = GeneratorModel(configs.get_config("sheared-llama-2.7b")
                         .reduced(num_layers=2, d_model=128), max_prompt=32)
    engine = RAGEngine(index, gen, cost_model=cost, k=5, nprobe=4,
                       max_new_tokens=4)
    resp = engine.answer("what is a vector index", ds.query_embs[0],
                         ds.get_chunks)
    assert len(resp.chunk_ids) == 5
    assert len(resp.context) == 5
    assert len(resp.output_tokens) == 4
    assert resp.ttft_edge_s > 0
    assert resp.ttft_edge_s == pytest.approx(
        resp.retrieval.retrieval_s + resp.prefill_edge_s)


def test_scheduler_slo_accounting():
    sched = RequestScheduler()
    for i in range(10):
        sched.submit(arrival_s=i * 0.1, slo_s=0.5)
    done = sched.run(lambda req: 0.3)          # service 0.3s, arrivals 0.1s
    assert len(done) == 10
    # queue builds: later requests wait and miss SLO
    assert done[0].slo_met
    assert not done[-1].slo_met
    assert 0 < sched.slo_hit_rate() < 1


# ---------------------------------------------------------------------------
# edge simulator reproduces the paper's orderings
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dataset", ["fever", "nq"])
def test_sim_large_datasets_edgerag_beats_ivf(dataset):
    sim = EdgeSimulator(dataset, n_queries=200, seed=0)
    ivf = sim.run("ivf")
    er = sim.run("edgerag")
    assert er.mean_ttft_s < ivf.mean_ttft_s          # the paper's headline
    assert er.resident_bytes < 0.1 * ivf.resident_bytes   # pruning
    # flat thrashes catastrophically out of memory
    flat = sim.run("flat")
    assert flat.mean_ttft_s > ivf.mean_ttft_s


def test_sim_small_dataset_penalty_is_bounded():
    """scidocs/fiqa fit in memory: online generation must not win, but the
    cached EdgeRAG stays within ~2x of in-memory IVF (Fig. 13)."""
    sim = EdgeSimulator("fiqa", n_queries=200, seed=0)
    ivf = sim.run("ivf")
    er = sim.run("edgerag")
    gen = sim.run("ivf_gen")
    assert er.mean_ttft_s <= gen.mean_ttft_s + 1e-9  # caching only helps
    assert er.mean_ttft_s < 2.0 * ivf.mean_ttft_s


def test_sim_cache_improves_over_gen_load():
    sim = EdgeSimulator("fever", n_queries=300, seed=1)
    load = sim.run("ivf_gen_load")
    er = sim.run("edgerag")
    assert er.mean_ttft_s <= load.mean_ttft_s + 1e-9
    assert er.cache_hit_rate > 0.5                   # Table 2 reuse=2.41


# ---------------------------------------------------------------------------
# train substrate
# ---------------------------------------------------------------------------
def test_train_overfits_tiny_batch():
    cfg = configs.get_config("stablelm-1.6b").reduced(num_layers=2,
                                                      d_model=128)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = train_state_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, total_steps=60))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(2, 33))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    losses = []
    for _ in range(40):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::8]


def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    st_ = adamw_init(params)
    new, st2, gnorm = adamw_update(grads, st_, params, lr=0.1,
                                   weight_decay=0.0)
    assert float(gnorm) == pytest.approx(2.0)
    assert (np.asarray(new["w"]) < 1.0).all()
    assert int(st2.count) == 1


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, peak_lr=1.0, warmup=10,
                                 total=100)) == pytest.approx(1.0, abs=1e-2)
    end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-2)     # floor


def test_checkpoint_roundtrip():
    cfg = configs.get_config("olmoe-1b-7b").reduced(num_layers=1,
                                                    d_model=64)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, params)
        loaded = load_checkpoint(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
