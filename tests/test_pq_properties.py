"""Property suite for the PQ codec + memmap storage tier (core/pq.py,
core/storage.py ``codec="pq"`` / ``mode="memmap"``).

Every property runs twice: once over a deterministic seed grid (always), and
once hypothesis-fuzzed (when hypothesis is installed, same pattern as
test_fault_properties.py).  Checked invariants:

  * encode→decode reconstruction error is bounded: EXACT (zero) when every
    training row can own a centroid (n <= 256), and never worse than the
    one-centroid-per-subspace baseline otherwise;
  * the roundtrip preserves row count and original dim, for dims divisible
    and NOT divisible by ``m`` (zero-padded tail subspace);
  * LUT scoring is the same linear functional as decode-then-dot;
  * ``payload_rows`` / ``get_many_raw`` honor the pq payload contract;
  * memmap put→get→delete→clear leaves no file, no leaked bytes in
    ``total_bytes()``, and no dangling file handles.
"""
import os

import numpy as np
import pytest

from repro.core.pq import (pq_decode, pq_encode, pq_luts, quantization_error,
                           subspace_split, train_pq)
from repro.core.storage import StorageBackend

pytestmark = pytest.mark.fast

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=40, deadline=None)

# (n, dim, m): dims both divisible and non-divisible by m, n spanning the
# exact-reconstruction regime (n <= 256) and the lossy one
GRID = [(2, 8, 4), (30, 15, 4), (40, 16, 16), (200, 33, 8),
        (300, 16, 8), (500, 24, 24)]


def _emb(n, d, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


# ---------------------------------------------------------------- properties
def check_roundtrip_shape_and_error(n, d, m, seed):
    x = _emb(n, d, seed)
    cb = train_pq(x, m=m, iters=6, seed=seed)
    codes = pq_encode(cb, x)
    rec = pq_decode(cb, codes)
    assert codes.shape == (n, cb.m) and codes.dtype == np.uint8
    assert rec.shape == (n, d) and rec.dtype == np.float32
    err = quantization_error(cb, x)
    assert np.all(np.isfinite(err)) and np.all(err >= 0)
    if n <= 256:
        # every training row can own a centroid: exact reconstruction
        assert float(err.max()) <= 1e-6
    else:
        # never worse than quantizing each subspace to its single mean
        sub = subspace_split(x, cb)
        k1 = float(np.sum((sub - sub.mean(0, keepdims=True)) ** 2)) / n
        assert float(err.mean()) <= k1 + 1e-6


def check_lut_matches_decode_dot(n, d, m, seed):
    x = _emb(n, d, seed)
    q = _emb(3, d, seed + 1)
    cb = train_pq(x, m=m, iters=6, seed=seed)
    codes = pq_encode(cb, x)
    luts = pq_luts(cb, q)
    assert luts.shape == (3, cb.m, 256)
    s_lut = np.stack([luts[i, np.arange(cb.m), codes].sum(axis=1)
                      for i in range(3)])
    s_dec = q @ pq_decode(cb, codes).T
    scale = max(1.0, float(np.abs(s_dec).max()))
    assert np.abs(s_lut - s_dec).max() <= 1e-4 * scale


def check_payload_contract(n, d, m, seed):
    s = StorageBackend("memory", codec="pq", pq_m=m)
    x = _emb(n, d, seed)
    s.put(7, x)
    raw = s.get_many_raw([7])[0]
    assert s.payload_rows(raw) == n
    assert set(raw) >= {"codes", "cbv"}
    assert raw["codes"].shape == (n, s.pq.m) and raw["codes"].dtype == np.uint8
    assert int(np.asarray(raw["cbv"]).reshape(-1)[0]) == s.pq.version
    # the raw codes decode to the same rows get() returns
    assert np.array_equal(s.get(7), pq_decode(s.pq, raw["codes"]))


def check_memmap_lifecycle(tmpdir, n, d, m, seed):
    s = StorageBackend("memmap", root=str(tmpdir), codec="pq", pq_m=m)
    x = _emb(n, d, seed)
    nbytes = s.put(3, x)
    assert s.total_bytes() == nbytes == s.stored_bytes(3)
    raw = s.get_many_raw([3])[0]
    assert isinstance(raw["codes"], np.memmap)       # disk-native: no copy
    before = len(os.listdir("/proc/self/fd"))
    for _ in range(8):                               # handle-leak probe
        got = s.get_many_raw([3])[0]["codes"]
        assert got.shape == (n, s.pq.m)
        del got
    assert len(os.listdir("/proc/self/fd")) <= before + 1
    s.delete(3)
    assert 3 not in s and s.total_bytes() == 0
    s.put(4, x)
    s.clear()
    assert s.total_bytes() == 0
    left = [f for f in os.listdir(str(tmpdir)) if f.endswith(".npz")
            and not f.startswith("pq_codebook")]
    assert left == []


# ------------------------------------------------- deterministic grid (always)
@pytest.mark.parametrize("n,d,m", GRID)
def test_roundtrip_shape_and_error(n, d, m):
    check_roundtrip_shape_and_error(n, d, m, seed=n + d + m)


@pytest.mark.parametrize("n,d,m", GRID)
def test_lut_matches_decode_dot(n, d, m):
    check_lut_matches_decode_dot(n, d, m, seed=n + d + m)


@pytest.mark.parametrize("n,d,m", [(5, 8, 4), (30, 15, 4), (64, 33, 8)])
def test_payload_contract(n, d, m):
    check_payload_contract(n, d, m, seed=n + d + m)


@pytest.mark.parametrize("n,d,m", [(5, 8, 4), (30, 15, 4), (64, 33, 8)])
def test_memmap_lifecycle(tmp_path, n, d, m):
    check_memmap_lifecycle(tmp_path, n, d, m, seed=n + d + m)


# ------------------------------------------------------ hypothesis fuzz layer
if HAVE_HYPOTHESIS:
    @settings(**SETTINGS)
    @given(n=st.integers(2, 300), d=st.sampled_from([8, 15, 16, 33]),
           m=st.sampled_from([4, 8, 16]), seed=st.integers(0, 10_000))
    def test_roundtrip_shape_and_error_fuzz(n, d, m, seed):
        check_roundtrip_shape_and_error(n, d, m, seed)

    @settings(**SETTINGS)
    @given(n=st.integers(2, 120), d=st.sampled_from([8, 15, 33]),
           m=st.sampled_from([4, 8]), seed=st.integers(0, 10_000))
    def test_lut_matches_decode_dot_fuzz(n, d, m, seed):
        check_lut_matches_decode_dot(n, d, m, seed)

    @settings(**SETTINGS)
    @given(n=st.integers(2, 64), d=st.sampled_from([8, 15, 33]),
           m=st.sampled_from([4, 8]), seed=st.integers(0, 10_000))
    def test_payload_contract_fuzz(n, d, m, seed):
        check_payload_contract(n, d, m, seed)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 64), d=st.sampled_from([8, 15, 33]),
           m=st.sampled_from([4, 8]), seed=st.integers(0, 10_000))
    def test_memmap_lifecycle_fuzz(n, d, m, seed, tmp_path_factory=None):
        import tempfile
        with tempfile.TemporaryDirectory() as td:
            check_memmap_lifecycle(td, n, d, m, seed)
