"""Continuous batching == sequential decoding, token for token — plus the
slot-admission edge cases (full pool refusal, free-on-finish reuse,
zero-live-slot ticks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.cache import init_cache
from repro.serving.batching import ContinuousBatcher

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(3)


def sequential_generate(cfg, params, prompt, max_new, max_len=96):
    toks = jnp.asarray([prompt], jnp.int32)
    caches = init_cache(cfg, 1, max_len)
    logits, caches = M.prefill(params, cfg, {"tokens": toks}, caches)
    out = []
    cache_len = len(prompt)
    tok = int(jnp.argmax(logits[0]))
    for _ in range(max_new):
        out.append(tok)
        logits, caches = M.decode_step(
            params, cfg, jnp.asarray([[tok]], jnp.int32), caches, cache_len)
        tok = int(jnp.argmax(logits[0]))
        cache_len += 1
    return out


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-1.6b",
                                  "olmoe-1b-7b"])
def test_batched_equals_sequential(arch):
    cfg = configs.get_config(arch).reduced(num_layers=2, d_model=128)
    params = M.init_params(cfg, KEY)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=L).tolist()
               for L in (9, 14, 5, 11, 7)]
    budgets = [6, 4, 8, 5, 7]

    batcher = ContinuousBatcher(cfg, params, num_slots=3, max_len=96)
    reqs = [{"id": i, "prompt_tokens": p, "max_new_tokens": b}
            for i, (p, b) in enumerate(zip(prompts, budgets))]
    outs = batcher.run(reqs)
    assert set(outs) == set(range(5))

    for i, (p, b) in enumerate(zip(prompts, budgets)):
        ref = sequential_generate(cfg, params, p, b)
        assert outs[i] == ref, (arch, i, outs[i], ref)


def test_slots_reused():
    cfg = configs.get_config("stablelm-1.6b").reduced(num_layers=1,
                                                      d_model=64)
    params = M.init_params(cfg, KEY)
    batcher = ContinuousBatcher(cfg, params, num_slots=2, max_len=64)
    reqs = [{"id": i, "prompt_tokens": [3, 4, 5], "max_new_tokens": 3}
            for i in range(6)]
    outs = batcher.run(reqs)
    assert len(outs) == 6                      # 6 requests through 2 slots
    assert all(len(v) == 3 for v in outs.values())


def _tiny_batcher(num_slots=2):
    cfg = configs.get_config("stablelm-1.6b").reduced(num_layers=1,
                                                      d_model=64)
    params = M.init_params(cfg, KEY)
    return ContinuousBatcher(cfg, params, num_slots=num_slots, max_len=64)


def test_admit_returns_none_when_all_slots_busy():
    b = _tiny_batcher(num_slots=2)
    assert b.admit(0, [3, 4, 5], 4) is not None
    assert b.admit(1, [6, 7], 4) is not None
    # pool exhausted: admission is refused, nothing is clobbered
    assert b.admit(2, [8, 9], 4) is None
    assert sorted(s.request_id for s in b.slots) == [0, 1]
    assert 2 not in b.completed


def test_slot_freed_on_finish_then_readmitted():
    b = _tiny_batcher(num_slots=1)
    slot0 = b.admit(0, [3, 4, 5], 2)
    assert slot0 == 0 and b.admit(1, [6, 7], 2) is None
    b.tick()
    b.tick()                                   # budget of 2 reached
    assert 0 in b.completed and len(b.completed[0]) == 2
    assert b.slots[0].free                     # freed immediately
    # the freed slot is reusable and per-slot state was reset, not leaked
    slot1 = b.admit(1, [6, 7], 2)
    assert slot1 == 0
    assert b.slots[0].tokens_out == []
    assert int(b.lens[0]) == 2                 # fresh prefix, not 3+2


def test_tick_with_zero_live_slots_is_a_noop():
    b = _tiny_batcher(num_slots=2)
    lens_before = b.lens.copy()
    assert b.tick() == 0                       # no active slots: no decode
    assert np.array_equal(b.lens, lens_before)
    assert b.completed == {}
