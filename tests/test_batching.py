"""Continuous batching == sequential decoding, token for token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.cache import init_cache
from repro.serving.batching import ContinuousBatcher

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(3)


def sequential_generate(cfg, params, prompt, max_new, max_len=96):
    toks = jnp.asarray([prompt], jnp.int32)
    caches = init_cache(cfg, 1, max_len)
    logits, caches = M.prefill(params, cfg, {"tokens": toks}, caches)
    out = []
    cache_len = len(prompt)
    tok = int(jnp.argmax(logits[0]))
    for _ in range(max_new):
        out.append(tok)
        logits, caches = M.decode_step(
            params, cfg, jnp.asarray([[tok]], jnp.int32), caches, cache_len)
        tok = int(jnp.argmax(logits[0]))
        cache_len += 1
    return out


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-1.6b",
                                  "olmoe-1b-7b"])
def test_batched_equals_sequential(arch):
    cfg = configs.get_config(arch).reduced(num_layers=2, d_model=128)
    params = M.init_params(cfg, KEY)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=L).tolist()
               for L in (9, 14, 5, 11, 7)]
    budgets = [6, 4, 8, 5, 7]

    batcher = ContinuousBatcher(cfg, params, num_slots=3, max_len=96)
    reqs = [{"id": i, "prompt_tokens": p, "max_new_tokens": b}
            for i, (p, b) in enumerate(zip(prompts, budgets))]
    outs = batcher.run(reqs)
    assert set(outs) == set(range(5))

    for i, (p, b) in enumerate(zip(prompts, budgets)):
        ref = sequential_generate(cfg, params, p, b)
        assert outs[i] == ref, (arch, i, outs[i], ref)


def test_slots_reused():
    cfg = configs.get_config("stablelm-1.6b").reduced(num_layers=1,
                                                      d_model=64)
    params = M.init_params(cfg, KEY)
    batcher = ContinuousBatcher(cfg, params, num_slots=2, max_len=64)
    reqs = [{"id": i, "prompt_tokens": [3, 4, 5], "max_new_tokens": 3}
            for i in range(6)]
    outs = batcher.run(reqs)
    assert len(outs) == 6                      # 6 requests through 2 slots
    assert all(len(v) == 3 for v in outs.values())
