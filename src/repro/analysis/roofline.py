"""Three-term roofline from the dry-run's compiled artifact (TPU v5e).

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / ICI link bw   (per chip)

FLOPs/bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
module (already per-device); collective bytes from the HLO parser.  The
dominant term is the bottleneck the §Perf loop iterates on.  MODEL_FLOPS =
6·N·D (dense) or 6·N_active·D uses the config's analytic param count; the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs × chips) flags remat and
redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch.mesh import (V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_BF16_FLOPS)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-chip terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    # raw inputs
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_by_op: Dict[str, float]
    model_flops: float
    useful_compute_ratio: float
    bytes_per_chip_peak: Optional[float] = None   # memory_analysis if avail.

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound (no overlap assumption: max of terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops / self.chips / self.step_time_s
                / V5E_PEAK_BF16_FLOPS)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "dominant": self.dominant,
            "hlo_gflops_per_chip": self.hlo_flops_per_chip / 1e9,
            "hlo_gbytes_per_chip": self.hlo_bytes_per_chip / 1e9,
            "coll_mbytes_per_chip": self.collective_bytes_per_chip / 1e6,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_compute_ratio,
            "bound_step_ms": self.step_time_s * 1e3,
            "mfu_at_bound": self.mfu,
        }


def roofline(*, arch: str, shape: str, mesh_name: str, chips: int,
             hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             collective_by_op: Dict[str, float], model_flops: float,
             peak_flops: float = V5E_PEAK_BF16_FLOPS,
             hbm_bw: float = V5E_HBM_BW, ici_bw: float = V5E_ICI_BW,
             bytes_peak: Optional[float] = None) -> RooflineReport:
    """hlo_flops / hlo_bytes / collective_bytes are PER-CHIP quantities."""
    compute_s = hlo_flops / peak_flops
    memory_s = hlo_bytes / hbm_bw
    collective_s = collective_bytes / ici_bw
    useful = model_flops / max(hlo_flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops_per_chip=hlo_flops, hlo_bytes_per_chip=hlo_bytes,
        collective_bytes_per_chip=collective_bytes,
        collective_by_op=collective_by_op, model_flops=model_flops,
        useful_compute_ratio=useful, bytes_per_chip_peak=bytes_peak)


def model_flops_estimate(cfg, shape) -> float:
    """6·N·D for training; 2·N·D for inference forward (per step).

    N = active params (MoE counts routed experts only).  D = tokens
    processed by the step: B·S for train/prefill, B for one decode step.
    Attention FLOPs (the O(S²) term) are added explicitly.
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn_mult = 3.0  # fwd + bwd
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
    # attention score+AV FLOPs: 4 · B · Sq · ctx_avg · (H·Dh) per layer
    from repro.configs.shapes import LONG_CONTEXT_WINDOW
    attn_flops = 0.0
    for kind in cfg.block_pattern:
        if kind not in ("attn", "swa", "shared_attn", "moe", "swa_moe"):
            continue
        if shape.kind == "decode":
            sq = 1
            ctx = shape.seq_len
            if shape.sliding_window_mode:
                ctx = min(ctx, LONG_CONTEXT_WINDOW)
            if kind in ("swa", "swa_moe") and cfg.sliding_window:
                ctx = min(ctx, cfg.sliding_window)
        else:
            sq = shape.seq_len
            if kind in ("swa", "swa_moe") and cfg.sliding_window:
                ctx = min(cfg.sliding_window, shape.seq_len)
            else:
                ctx = shape.seq_len / 2.0          # causal average
        attn_flops += (4.0 * shape.global_batch * sq * ctx * cfg.q_dim
                       * cfg.depth_repeat)
    return base + attn_mult * attn_flops
