"""Collective-traffic extraction from compiled (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` has no collective term, so we parse the
optimized HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op contributes its ring-algorithm byte
count, per participating device:

    all-gather          (g-1)/g × result_bytes
    all-reduce        2 (g-1)/g × result_bytes
    reduce-scatter      (g-1)   × result_bytes      (result is the shard)
    all-to-all          (g-1)/g × result_bytes
    collective-permute            result_bytes

where g = replica-group size parsed from the op attributes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = bf16[8,128]{1,0} all-gather(...)` — also tuple results
_OP_RE = re.compile(
    r"=\s*(?P<rtype>\(?[\w\[\],{} ]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>\w+)\[(?P<dims>[\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(?P<groups>[^}]*(?:\},\{[^}]*)*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(?P<ng>\d+),(?P<gs>\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group("gs"))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group("groups").split("},{")[0]
        return max(1, first.count(",") + 1)
    return default


def collective_bytes(hlo_text: str, default_group: int = 1
                     ) -> Tuple[float, Dict[str, float], Dict[str, int]]:
    """Returns (total_bytes_per_device, bytes_by_op, count_by_op)."""
    by_op: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("//"):
            continue
        m = _OP_RE.search(stripped)
        if not m:
            continue
        if "-done" in stripped.split("=", 1)[-1][:80]:
            continue  # async done ops re-reference the start's buffers
        op = m.group("op")
        nbytes = _shape_bytes(m.group("rtype"))
        g = _group_size(stripped, default_group)
        if op == "all-gather":
            moved = nbytes * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            moved = 2 * nbytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            moved = nbytes * (g - 1)
        elif op == "all-to-all":
            moved = nbytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            moved = nbytes
        by_op[op] += moved
        counts[op] += 1
    return float(sum(by_op.values())), dict(by_op), dict(counts)


_META_RE = re.compile(r'op_name="([^"]+)"')


def collective_sites(hlo_text: str, top: int = 12):
    """Attribute collective bytes to source op_names (metadata).  Returns
    [(bytes, op_kind, op_name)] sorted desc — the §Perf evidence trail."""
    sites = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        if "-done" in stripped.split("=", 1)[-1][:80]:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("rtype"))
        meta = _META_RE.search(stripped)
        name = meta.group(1) if meta else "?"
        sites.append((nbytes, op, name))
    sites.sort(reverse=True)
    return sites[:top]
