from repro.analysis.hlo import collective_bytes  # noqa
from repro.analysis.roofline import RooflineReport, roofline  # noqa
