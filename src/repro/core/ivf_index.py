"""Two-level Inverted File (IVF) index — the paper's latency baseline
(Table 4 row 2) and the substrate EdgeRAG modifies.

Level 1: cluster centroids, always resident.  Level 2: per-cluster chunk
embeddings, resident in memory for the baseline.  Retrieval probes the
``nprobe`` nearest centroids and scans their clusters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.costs import EdgeCostModel, LatencyBreakdown, WallTimer
from repro.core.kmeans import kmeans
from repro.kernels.ivf_topk.ops import topk_ip


@dataclasses.dataclass
class Cluster:
    ids: np.ndarray                       # (n,) chunk ids
    embeddings: Optional[np.ndarray]      # (n, d) or None when pruned

    @property
    def size(self) -> int:
        return len(self.ids)


class IVFIndex:
    def __init__(self, dim: int, cost_model: Optional[EdgeCostModel] = None):
        self.dim = dim
        self.cost = cost_model or EdgeCostModel()
        self.centroids: Optional[np.ndarray] = None          # (nlist, d)
        self.clusters: List[Cluster] = []

    # ------------------------------------------------------------------
    def build(self, embeddings: np.ndarray, ids: np.ndarray,
              nlist: int, kmeans_iters: int = 20, seed: int = 0):
        embeddings = np.ascontiguousarray(embeddings, np.float32)
        ids = np.asarray(ids, np.int64)
        self.centroids, assign = kmeans(embeddings, nlist,
                                        iters=kmeans_iters, seed=seed)
        self.clusters = []
        for c in range(self.centroids.shape[0]):
            sel = np.where(assign == c)[0]
            self.clusters.append(
                Cluster(ids=ids[sel],
                        embeddings=np.ascontiguousarray(embeddings[sel])))
        return assign

    @property
    def nlist(self) -> int:
        return 0 if self.centroids is None else len(self.centroids)

    @property
    def ntotal(self) -> int:
        return sum(c.size for c in self.clusters)

    def memory_bytes(self) -> int:
        n = self.centroids.nbytes if self.centroids is not None else 0
        for c in self.clusters:
            if c.embeddings is not None:
                n += c.embeddings.nbytes
        return n

    # ------------------------------------------------------------------
    def probe(self, query: np.ndarray, nprobe: int) -> np.ndarray:
        """(Q, d) -> (Q, nprobe) centroid indices."""
        query = np.atleast_2d(np.asarray(query, np.float32))
        _, idx = topk_ip(self.centroids, query, min(nprobe, self.nlist))
        return np.asarray(idx)

    def search(self, query: np.ndarray, k: int, nprobe: int
               ) -> Tuple[np.ndarray, np.ndarray, LatencyBreakdown]:
        """Single query (d,) or (1, d)."""
        query = np.atleast_2d(np.asarray(query, np.float32))
        assert query.shape[0] == 1, "IVF search is per-query"
        lat = LatencyBreakdown()
        with WallTimer() as t:
            probed = self.probe(query, nprobe)[0]
            lat.n_clusters_probed = len(probed)
            cand_embs, cand_ids, scanned = [], [], 0
            for c in probed:
                cl = self.clusters[int(c)]
                if cl.size == 0 or cl.embeddings is None:
                    continue
                cand_embs.append(cl.embeddings)
                cand_ids.append(cl.ids)
                scanned += cl.size
            if not cand_embs:
                empty = np.full((1, k), -1, np.int64)
                return empty, np.full((1, k), -np.inf, np.float32), lat
            embs = np.concatenate(cand_embs)
            idmap = np.concatenate(cand_ids)
            vals, idx = topk_ip(embs, query, k)
            vals, idx = np.asarray(vals), np.asarray(idx)
        lat.wall_s = t.elapsed
        lat.centroid_search_s = (
            self.cost.mem_load_latency(self.centroids.nbytes)
            + self.cost.search_latency(self.nlist, self.dim))
        # level-2: touched cluster embeddings load from "memory"; the
        # RESIDENT SET is the whole in-memory index (this is what thrashes)
        lat.l2_mem_load_s = self.cost.mem_load_latency(
            embs.nbytes, resident_bytes=self.memory_bytes())
        lat.l2_search_s = self.cost.search_latency(scanned, self.dim)
        ids = np.where(idx >= 0, idmap[np.clip(idx, 0, len(idmap) - 1)], -1)
        return ids, vals, lat
