"""Crash-consistent index durability: snapshot + write-ahead log.

EdgeRAG's premise is an *online-indexed* edge deployment, yet everything
the index builds online — centroids, cluster membership, generation
stamps, tombstones, the Alg. 3 threshold, which clusters hold storage
blobs — lives in process memory: a power loss (routine on edge devices)
forces the worst-case recovery, a full corpus re-embed.  This module makes
index STATE durable next to the embedding blobs ``StorageBackend``
already persists, so recovery replays metadata and reuses the on-disk
embeddings instead of re-embedding.

THE THREE PIECES

:class:`WriteAheadLog` — an append-only log of CRC-framed records.  Every
finished index mutation (insert / remove / update / split / merge /
restore / drop / retrain_pq — plus the resolver's Alg. 1 self-heal
re-persist) emits ONE record carrying the *absolute post-op state* of
every cluster the op touched.  Frame format::

    file   := magic "EDGEWAL1" , frame*
    frame  := header , body
    header := <u32 body_len> <u32 crc32(body)>      (little-endian)
    body   := canonical JSON (sorted keys; ndarrays as
              {"__nd__": [dtype, shape, base64(raw bytes)]} — float32
              centroids round-trip bit-exactly)

Torn-tail detection: reading stops at the first bad frame (short header,
implausible length, CRC mismatch) and :meth:`~WriteAheadLog.records`
reports the valid prefix; the open-for-recovery path physically truncates
the file there.  A single bit flip anywhere in a frame fails its CRC and
truncates the log at that frame.  Each append is charged
``EdgeCostModel.wal_fsync_latency`` modeled edge seconds (surfaced as the
``LatencyBreakdown.wal_fsync_s`` field on the retrieval path, and folded
into maintenance ``edge_s`` on the drain path).

:class:`IndexSnapshot` — atomic (tmp + ``os.replace``) serialization of
the FULL index state into ``snapshot_<lsn>.npz`` next to the storage
root, self-validated by the same payload CRC the blob store uses.
Snapshots are taken incrementally via the ``OP_CHECKPOINT`` maintenance
kind (core/maintenance.py): after ``checkpoint_every`` WAL records a
checkpoint op is enqueued and rides idle gaps / pipeline S2-S3 bubbles
exactly like split / merge — a checkpoint bumps NO generation stamp, so
in-flight plans never go stale behind one.  After a snapshot lands, the
WAL is compacted (records at or below the snapshot LSN dropped).

:func:`recover` — newest valid snapshot + idempotent WAL-suffix replay
(records carry monotonically increasing LSNs; replay skips anything at or
below the applied LSN, so replaying twice equals replaying once), then a
reconciliation pass of the storage blobs against the recovered manifest:

  * a blob for a cluster the manifest doesn't claim → ORPHAN GC (a put
    that landed before its WAL record did; deleting it lands the index
    exactly on the pre-op state);
  * a manifest-claimed blob that is missing or whose stored CRC disagrees
    with the manifest's recorded CRC → SELF-HEAL regen (the one place
    recovery re-embeds — a single cluster, not the corpus).

THE ATOMICITY CONTRACT.  With a :class:`~repro.core.faults.CrashInjector`
cutting the process at any durability write boundary
(:data:`~repro.core.faults.CRASH_POINTS`), recovery always lands
bit-identical to the pre-op or the post-op index — never a torn hybrid.
The mechanism: blobs are written before their WAL record, so a lost
record orphans (GC → pre-op) and a torn record truncates (→ pre-op),
while a landed record pins the exact post-op state including each stored
blob's CRC (mismatch → heal → post-op content).  The property tests
(tests/test_durability_properties.py) fuzz this over random mutation
sequences × every crashpoint × every codec.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import re
import struct
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.costs import EdgeCostModel, WallTimer
from repro.core.faults import CrashInjector
from repro.core.maintenance import OP_MERGE, OP_RESTORE, OP_SPLIT

WAL_MAGIC = b"EDGEWAL1"
_WAL_HEADER = struct.Struct("<II")
_SNAPSHOT_FILE = re.compile(r"^snapshot_(\d+)\.npz$")
_META_KEY = "meta_json"
_CRC_KEY = "crc"


class RecoveryError(Exception):
    """No recoverable durable state under the given root."""


# ---------------------------------------------------------------------------
# record codec: canonical JSON with ndarray members
# ---------------------------------------------------------------------------
def _enc(obj):
    if isinstance(obj, np.ndarray):
        a = np.ascontiguousarray(obj)
        return {"__nd__": [a.dtype.str, list(a.shape),
                           base64.b64encode(a.tobytes()).decode("ascii")]}
    if isinstance(obj, dict):
        return {k: _enc(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_enc(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


def _dec(obj):
    if isinstance(obj, dict):
        if "__nd__" in obj:
            dtype, shape, data = obj["__nd__"]
            a = np.frombuffer(base64.b64decode(data), np.dtype(dtype))
            return a.reshape(shape).copy()
        return {k: _dec(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_dec(v) for v in obj]
    return obj


def pack_record(record: Dict) -> bytes:
    """Canonical (sorted-key) JSON bytes of one WAL record."""
    return json.dumps(_enc(record), sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def unpack_record(body: bytes) -> Dict:
    return _dec(json.loads(body.decode("utf-8")))


# ---------------------------------------------------------------------------
# write-ahead log
# ---------------------------------------------------------------------------
class WriteAheadLog:
    """Append-only CRC-framed byte log (see module docstring for the frame
    format).  This layer is pure bytes; :class:`Durability` owns record
    semantics (LSNs, compaction policy)."""

    def __init__(self, path: str):
        self.path = path
        self.records_appended = 0        # frames appended by THIS handle
        self.bytes_appended = 0

    # -- writing -----------------------------------------------------------
    def append(self, body: bytes,
               crash: Optional[CrashInjector] = None) -> int:
        """Append one frame (+ fsync); returns bytes written.  Crash
        boundaries: ``wal_pre_append`` (nothing lands), ``wal_torn_append``
        (a seeded prefix of the frame lands — recovery must truncate),
        ``wal_post_append`` (the frame is durable)."""
        if crash is not None:
            crash.hit("wal_pre_append")
        frame = _WAL_HEADER.pack(len(body), zlib.crc32(body)) + body
        fresh = not os.path.exists(self.path)
        if crash is not None and crash.take("wal_torn_append"):
            torn = frame[:crash.torn_length(len(frame))]
            with open(self.path, "ab") as f:
                if fresh:
                    f.write(WAL_MAGIC)
                f.write(torn)
                f.flush()
                os.fsync(f.fileno())
            crash.die("wal_torn_append")
        with open(self.path, "ab") as f:
            if fresh:
                f.write(WAL_MAGIC)
            f.write(frame)
            f.flush()
            os.fsync(f.fileno())
        if crash is not None:
            crash.hit("wal_post_append")
        self.records_appended += 1
        self.bytes_appended += len(frame)
        return len(frame)

    # -- reading -----------------------------------------------------------
    def frames(self) -> Tuple[List[bytes], int, bool]:
        """Every valid frame body in order, stopping at the first bad one.
        Returns ``(bodies, valid_end_offset, torn)`` — ``torn`` is True iff
        trailing bytes past the valid prefix exist (short/bad header, body
        overrunning the file, or CRC mismatch)."""
        if not os.path.exists(self.path):
            return [], 0, False
        with open(self.path, "rb") as f:
            data = f.read()
        if data[:len(WAL_MAGIC)] != WAL_MAGIC:
            return [], 0, len(data) > 0
        bodies: List[bytes] = []
        off = len(WAL_MAGIC)
        while off < len(data):
            if off + _WAL_HEADER.size > len(data):
                return bodies, off, True
            length, crc = _WAL_HEADER.unpack_from(data, off)
            start = off + _WAL_HEADER.size
            if start + length > len(data):
                return bodies, off, True
            body = data[start:start + length]
            if zlib.crc32(body) != crc:
                return bodies, off, True
            bodies.append(body)
            off = start + length
        return bodies, off, False

    def records(self) -> Tuple[List[Dict], int, bool]:
        """Decoded records of the valid frame prefix.  A frame whose CRC
        passes but whose body does not parse (cannot happen without a
        matching-CRC corruption, i.e. a software bug) also truncates."""
        bodies, off, torn = self.frames()
        out: List[Dict] = []
        end = len(WAL_MAGIC)
        for body in bodies:
            try:
                out.append(unpack_record(body))
            except Exception:
                return out, end, True
            end += _WAL_HEADER.size + len(body)
        return out, off, torn

    def truncate_torn_tail(self) -> int:
        """Physically cut the file back to its valid prefix; returns the
        number of torn bytes dropped."""
        if not os.path.exists(self.path):
            return 0
        _, valid_end, torn = self.frames()
        size = os.path.getsize(self.path)
        if not torn or size <= valid_end:
            return 0
        with open(self.path, "r+b") as f:
            f.truncate(valid_end)
        return size - valid_end

    def rewrite(self, bodies: Sequence[bytes]):
        """Atomic compaction: a fresh log holding only ``bodies``."""
        tmp = self.path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                f.write(WAL_MAGIC)
                for body in bodies:
                    f.write(_WAL_HEADER.pack(len(body), zlib.crc32(body)))
                    f.write(body)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.remove(tmp)
            raise

    def nbytes(self) -> int:
        return os.path.getsize(self.path) if os.path.exists(self.path) else 0


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------
class IndexSnapshot:
    """Atomic full-state serialization of an ``EdgeRAGIndex``.

    Payload members: a JSON meta string (lsn, dim, codec, pq version, the
    Alg. 3 threshold state), the centroid matrix, concatenated per-cluster
    chunk ids + per-chunk char counts with offsets, the per-cluster scalar
    columns (char_count, gen_latency_est, flags, generation stamps), and
    the blob-CRC manifest column (-1 = no stored blob).  A trailing
    ``crc`` member self-validates the file — recovery walks snapshots
    newest-first and uses the first one that verifies."""

    @staticmethod
    def capture(index, manifest: Dict[int, int],
                lsn: int) -> Dict[str, np.ndarray]:
        cls = index.clusters
        n = len(cls)
        ids_concat = (np.concatenate([c.ids for c in cls])
                      if n else np.zeros((0,), np.int64)).astype(np.int64)
        offsets = np.zeros((n + 1,), np.int64)
        for i, c in enumerate(cls):
            offsets[i + 1] = offsets[i] + c.size
        chars_concat = np.array(
            [index._chunk_chars.get(int(i), 0) for i in ids_concat],
            np.int64)
        thr = index.threshold
        meta = {
            "lsn": int(lsn),
            "dim": int(index.dim),
            "codec": index.storage.codec,
            "pq_version": (None if index.storage.pq is None
                           else int(index.storage.pq.version)),
            "threshold": {
                "threshold": float(thr.threshold),
                "step_s": float(thr.step_s),
                "alpha": float(thr.alpha),
                "moving_avg_latency": float(thr.moving_avg_latency),
                "initialized": bool(thr._initialized),
            },
        }
        payload = {
            _META_KEY: np.frombuffer(
                json.dumps(meta, sort_keys=True).encode("utf-8"), np.uint8),
            "centroids": (np.ascontiguousarray(index.centroids, np.float32)
                          if index.centroids is not None
                          else np.zeros((0, index.dim), np.float32)),
            "ids_concat": ids_concat,
            "offsets": offsets,
            "chars_concat": chars_concat,
            "char_count": np.array([c.char_count for c in cls], np.int64),
            "gen_latency_est": np.array([c.gen_latency_est for c in cls],
                                        np.float64),
            "stored": np.array([c.stored for c in cls], np.uint8),
            "active": np.array([c.active for c in cls], np.uint8),
            "generation": np.array([c.generation for c in cls], np.int64),
            "content_generation": np.array(
                [c.content_generation for c in cls], np.int64),
            "stored_generation": np.array(
                [c.stored_generation for c in cls], np.int64),
            "blob_crc": np.array(
                [manifest.get(cid, -1) for cid in range(n)], np.int64),
        }
        return payload

    @staticmethod
    def apply(index, payload: Dict[str, np.ndarray]
              ) -> Tuple[int, Dict[int, int]]:
        """Overwrite ``index``'s state from a verified snapshot payload.
        Returns ``(applied_lsn, blob-CRC manifest)``."""
        from repro.core.cache_policy import MinLatencyThresholdController
        from repro.core.edgerag import EdgeCluster
        meta = json.loads(bytes(payload[_META_KEY]).decode("utf-8"))
        assert int(meta["dim"]) == index.dim, \
            f"snapshot dim {meta['dim']} != index dim {index.dim}"
        tm = meta["threshold"]
        thr = MinLatencyThresholdController(tm["step_s"], tm["alpha"])
        thr.threshold = tm["threshold"]
        thr.moving_avg_latency = tm["moving_avg_latency"]
        thr._initialized = tm["initialized"]
        index.threshold = thr
        index.centroids = np.ascontiguousarray(payload["centroids"],
                                               np.float32)
        offsets = payload["offsets"]
        n = len(offsets) - 1
        index.clusters = []
        index._chunk_cluster = {}
        index._chunk_chars = {}
        manifest: Dict[int, int] = {}
        for cid in range(n):
            lo, hi = int(offsets[cid]), int(offsets[cid + 1])
            ids = payload["ids_concat"][lo:hi].astype(np.int64)
            cl = EdgeCluster(
                ids=ids,
                char_count=int(payload["char_count"][cid]),
                gen_latency_est=float(payload["gen_latency_est"][cid]),
                stored=bool(payload["stored"][cid]),
                active=bool(payload["active"][cid]),
                generation=int(payload["generation"][cid]),
                content_generation=int(payload["content_generation"][cid]),
                stored_generation=int(payload["stored_generation"][cid]))
            index.clusters.append(cl)
            for i, ch in zip(ids, payload["chars_concat"][lo:hi]):
                index._chunk_cluster[int(i)] = cid
                index._chunk_chars[int(i)] = int(ch)
            crc = int(payload["blob_crc"][cid])
            if crc >= 0:
                manifest[cid] = crc
        return int(meta["lsn"]), manifest

    # -- files -------------------------------------------------------------
    @staticmethod
    def path(dirpath: str, lsn: int) -> str:
        return os.path.join(dirpath, f"snapshot_{lsn}.npz")

    @staticmethod
    def write(dirpath: str, lsn: int, payload: Dict[str, np.ndarray],
              crash: Optional[CrashInjector] = None) -> str:
        """Atomic tmp + ``os.replace`` with the four snapshot crash
        boundaries.  A crash before the rename leaves (at most) a torn tmp
        that recovery ignores and a later ``StorageBackend.clear`` sweeps;
        a crash after the rename leaves a fully valid snapshot."""
        from repro.core.storage import payload_checksum
        stored = dict(payload)
        stored[_CRC_KEY] = np.array([payload_checksum(payload)], np.uint32)
        path = IndexSnapshot.path(dirpath, lsn)
        tmp = path + ".tmp"
        if crash is not None:
            crash.hit("snap_pre_tmp")
        if crash is not None and crash.take("snap_torn_tmp"):
            import io
            buf = io.BytesIO()
            np.savez(buf, **stored)
            blob = buf.getvalue()
            with open(tmp, "wb") as f:
                f.write(blob[:crash.torn_length(len(blob))])
                f.flush()
                os.fsync(f.fileno())
            crash.die("snap_torn_tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **stored)
            f.flush()
            os.fsync(f.fileno())
        if crash is not None:
            crash.hit("snap_pre_rename")
        os.replace(tmp, path)
        if crash is not None:
            crash.hit("snap_post_rename")
        return path

    @staticmethod
    def lsns(dirpath: str) -> List[int]:
        if not os.path.isdir(dirpath):
            return []
        out = [int(m.group(1)) for m in
               (_SNAPSHOT_FILE.match(f) for f in os.listdir(dirpath)) if m]
        return sorted(out)

    @staticmethod
    def load_valid(dirpath: str, lsn: int
                   ) -> Optional[Dict[str, np.ndarray]]:
        """The snapshot's payload iff its container parses and its CRC
        verifies; None otherwise."""
        from repro.core.storage import payload_checksum
        try:
            with np.load(IndexSnapshot.path(dirpath, lsn)) as z:
                stored = {name: z[name] for name in z.files}
        except Exception:
            return None
        crc = stored.pop(_CRC_KEY, None)
        if crc is None:
            return None
        if payload_checksum(stored) != int(np.asarray(crc).reshape(-1)[0]):
            return None
        return stored

    @staticmethod
    def newest_valid(dirpath: str
                     ) -> Optional[Tuple[int, Dict[str, np.ndarray]]]:
        for lsn in reversed(IndexSnapshot.lsns(dirpath)):
            payload = IndexSnapshot.load_valid(dirpath, lsn)
            if payload is not None:
                return lsn, payload
        return None

    @staticmethod
    def prune(dirpath: str, keep: int):
        """Drop all but the newest ``keep`` snapshots (older ones are
        recovery fallbacks for a torn newest — keep ≥ 1)."""
        lsns = IndexSnapshot.lsns(dirpath)
        for lsn in lsns[:-keep] if keep else lsns:
            try:
                os.remove(IndexSnapshot.path(dirpath, lsn))
            except OSError:
                pass


# ---------------------------------------------------------------------------
# the durability handle
# ---------------------------------------------------------------------------
class Durability:
    """Per-index durability handle: owns one WAL + snapshot directory.

    ``root`` is the storage root the blobs live under; durable state goes
    in ``<root>/durability/`` (``<root>/durability/tenant_<t>/`` for a
    tenant of a shared backend — per-tenant WALs under the shared root).
    Attach with :meth:`EdgeRAGIndex.attach_durability`; every finished
    mutation then emits one WAL record, and after ``checkpoint_every``
    records a snapshot is taken — inline in sync-maintenance mode, or as
    an ``OP_CHECKPOINT`` op that rides the deferred queue into idle gaps
    and pipeline bubbles.  ``crash`` injects simulated process death at
    the write boundaries (tests / benchmarks only)."""

    def __init__(self, root: str, *, tenant: Optional[str] = None,
                 cost_model: Optional[EdgeCostModel] = None,
                 checkpoint_every: int = 64, keep_snapshots: int = 2,
                 crash: Optional[CrashInjector] = None):
        assert checkpoint_every >= 1, checkpoint_every
        assert keep_snapshots >= 1, keep_snapshots
        self.root = root
        self.tenant = tenant
        self.dir = os.path.join(root, "durability",
                                *([f"tenant_{tenant}"] if tenant else []))
        os.makedirs(self.dir, exist_ok=True)
        self.wal = WriteAheadLog(os.path.join(self.dir, "wal.log"))
        self.cost = cost_model or EdgeCostModel()
        self.checkpoint_every = checkpoint_every
        self.keep_snapshots = keep_snapshots
        self.crash = crash
        self.next_lsn = 1       # LSN 0 = "no records": a baseline snapshot
        # taken before any record carries lsn 0 and replay skips lsn <= 0
        self.records_since_snapshot = 0
        # blob manifest: cid -> payload CRC the durable state expects for
        # that cluster's stored blob (recovery reconciles against it)
        self.manifest: Dict[int, int] = {}
        # counters (serving/metrics.py collectors)
        self.records_total = 0
        self.snapshots_total = 0
        self.compactions_total = 0
        self.fsync_edge_s_total = 0.0
        self.last_recovery_s: Optional[float] = None

    # -- record capture ----------------------------------------------------
    def _capture_cluster(self, index, cid: int) -> Dict:
        cl = index.clusters[cid]
        entry = {
            "cid": int(cid),
            "ids": np.asarray(cl.ids, np.int64),
            "chars": np.array([index._chunk_chars.get(int(i), 0)
                               for i in cl.ids], np.int64),
            "char_count": int(cl.char_count),
            "gen_latency_est": float(cl.gen_latency_est),
            "stored": bool(cl.stored),
            "active": bool(cl.active),
            "generation": int(cl.generation),
            "content_generation": int(cl.content_generation),
            "stored_generation": int(cl.stored_generation),
            "centroid": np.ascontiguousarray(index.centroids[cid],
                                             np.float32),
            "blob_crc": None,
        }
        if cl.stored:
            try:
                entry["blob_crc"] = int(index.storage.payload_crc(cid))
            except KeyError:
                entry["blob_crc"] = None
        return entry

    def log_mutation(self, index, op: str, cids: Sequence[int],
                     gone: Sequence[int]) -> float:
        """Append one record with the absolute post-op state of the
        touched clusters; returns modeled fsync edge seconds.  Updates the
        blob manifest and arms a checkpoint when the record budget is
        spent."""
        record = {
            "lsn": self.next_lsn,
            "op": op,
            "nlist": len(index.clusters),
            "gone": [int(i) for i in gone],
            "pq_version": (None if index.storage.pq is None
                           else int(index.storage.pq.version)),
            "clusters": [self._capture_cluster(index, cid) for cid in cids],
        }
        n = self.wal.append(pack_record(record), crash=self.crash)
        # the append landed: only now may the in-memory bookkeeping move
        self.next_lsn += 1
        self.records_total += 1
        self.records_since_snapshot += 1
        for entry in record["clusters"]:
            if entry["stored"] and entry["blob_crc"] is not None:
                self.manifest[entry["cid"]] = entry["blob_crc"]
            else:
                self.manifest.pop(entry["cid"], None)
        fsync_s = self.cost.wal_fsync_latency(n)
        self.fsync_edge_s_total += fsync_s
        if self.should_checkpoint():
            from repro.core.maintenance import CHECKPOINT_CID, OP_CHECKPOINT
            if index.maintenance_mode == "sync":
                self.checkpoint(index)
            else:
                index.maintenance.enqueue(OP_CHECKPOINT, CHECKPOINT_CID)
        return fsync_s

    def should_checkpoint(self) -> bool:
        return self.records_since_snapshot >= self.checkpoint_every

    @property
    def dirty_records(self) -> int:
        return self.records_since_snapshot

    # -- checkpoint --------------------------------------------------------
    def checkpoint_cost_s(self, index) -> float:
        """Drain-time estimate of one checkpoint: the serialized state
        streamed through one fsync'd write (+ the rename barrier)."""
        n_ids = sum(c.size for c in index.clusters)
        nbytes = (0 if index.centroids is None else index.centroids.nbytes)
        nbytes += n_ids * 16 + len(index.clusters) * 64 + 512
        return self.cost.wal_fsync_latency(nbytes) + self.cost.storage_seek_s

    def checkpoint(self, index) -> float:
        """Serialize the full index state to ``snapshot_<lsn>.npz``
        (atomic), then COMPACT the WAL — records at or below the snapshot
        LSN are dead weight (replay skips them by LSN anyway).  Returns
        modeled edge seconds."""
        snap_lsn = self.next_lsn - 1
        payload = IndexSnapshot.capture(index, self.manifest, snap_lsn)
        nbytes = sum(a.nbytes for a in payload.values())
        IndexSnapshot.write(self.dir, snap_lsn, payload, crash=self.crash)
        self.snapshots_total += 1
        keep = [pack_record(rec) for rec in self.wal.records()[0]
                if int(rec["lsn"]) > snap_lsn]
        self.wal.rewrite(keep)
        self.compactions_total += 1
        self.records_since_snapshot = len(keep)
        IndexSnapshot.prune(self.dir, self.keep_snapshots)
        edge_s = (self.cost.wal_fsync_latency(nbytes)
                  + self.cost.storage_seek_s)
        self.fsync_edge_s_total += edge_s
        return edge_s

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "wal_records_total": self.records_total,
            "wal_bytes": self.wal.nbytes(),
            "wal_records_since_snapshot": self.records_since_snapshot,
            "snapshots_total": self.snapshots_total,
            "wal_compactions_total": self.compactions_total,
            "fsync_edge_s_total": self.fsync_edge_s_total,
            "last_recovery_s": self.last_recovery_s,
        }


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RecoveryReport:
    """What one :func:`recover` did and what it cost (modeled edge
    seconds + real wall seconds)."""
    tenant: Optional[str] = None
    snapshot_lsn: int = -1
    replayed_records: int = 0
    torn_bytes: int = 0          # bytes cut off the WAL's torn tail
    orphans_gc: int = 0          # blobs the manifest didn't claim, deleted
    healed: int = 0              # manifest-claimed blobs regenerated
    requeued_ops: int = 0        # split/merge hygiene re-derived post-replay
    edge_s: float = 0.0
    wall_s: float = 0.0

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _replay_record(index, rec: Dict, manifest: Dict[int, int]):
    """Apply one WAL record: absolute post-op cluster states, chunk-map
    updates, blob-manifest updates.  Caller enforces LSN monotonicity."""
    from repro.core.edgerag import EdgeCluster
    nlist = int(rec["nlist"])
    while len(index.clusters) < nlist:      # split appended new slots
        index.clusters.append(EdgeCluster(
            ids=np.zeros((0,), np.int64), char_count=0,
            gen_latency_est=0.0, active=False))
    if index.centroids is None:
        index.centroids = np.zeros((0, index.dim), np.float32)
    if len(index.centroids) < nlist:
        pad = np.tile(-np.ones((1, index.dim), np.float32)
                      / np.sqrt(index.dim),
                      (nlist - len(index.centroids), 1))
        index.centroids = np.concatenate([index.centroids, pad])
    for entry in rec["clusters"]:
        cid = int(entry["cid"])
        ids = np.asarray(entry["ids"], np.int64)
        index.clusters[cid] = EdgeCluster(
            ids=ids,
            char_count=int(entry["char_count"]),
            gen_latency_est=float(entry["gen_latency_est"]),
            stored=bool(entry["stored"]),
            active=bool(entry["active"]),
            generation=int(entry["generation"]),
            content_generation=int(entry["content_generation"]),
            stored_generation=int(entry["stored_generation"]))
        index.centroids[cid] = np.asarray(entry["centroid"], np.float32)
        for i, ch in zip(ids, np.asarray(entry["chars"], np.int64)):
            index._chunk_cluster[int(i)] = cid
            index._chunk_chars[int(i)] = int(ch)
        if entry["stored"] and entry.get("blob_crc") is not None:
            manifest[cid] = int(entry["blob_crc"])
        else:
            manifest.pop(cid, None)
    for i in rec.get("gone", []):
        index._chunk_cluster.pop(int(i), None)
        index._chunk_chars.pop(int(i), None)


def recover_index(index, dur: Durability, *,
                  report: Optional[RecoveryReport] = None) -> RecoveryReport:
    """Recover a constructed-but-unbuilt index in place from ``dur``'s
    directory: newest valid snapshot, idempotent WAL-suffix replay, then
    blob reconciliation (orphan GC + missing/mismatched-blob self-heal —
    the only re-embedding recovery ever does) and split/merge hygiene
    re-derivation for the deferred queue the crash threw away.  Attaches
    ``dur`` to the index and finishes with a fresh checkpoint."""
    rep = report or RecoveryReport(tenant=dur.tenant)
    with WallTimer() as t:
        rep.torn_bytes = dur.wal.truncate_torn_tail()
        found = IndexSnapshot.newest_valid(dur.dir)
        if found is None:
            raise RecoveryError(
                f"no valid snapshot under {dur.dir!r} — nothing durable to "
                f"recover (build with a Durability handle attached first)")
        snap_lsn, payload = found
        applied, manifest = IndexSnapshot.apply(index, payload)
        rep.snapshot_lsn = snap_lsn
        rep.edge_s += dur.cost.storage_load_latency(
            os.path.getsize(IndexSnapshot.path(dur.dir, snap_lsn)))
        records, _, _ = dur.wal.records()
        rep.edge_s += dur.cost.storage_load_latency(dur.wal.nbytes())
        for rec in records:
            if int(rec["lsn"]) <= applied:
                continue            # idempotent replay: at-most-once by LSN
            _replay_record(index, rec, manifest)
            applied = int(rec["lsn"])
            rep.replayed_records += 1
        dur.next_lsn = applied + 1
        dur.manifest = manifest
        dur.records_since_snapshot = sum(
            1 for rec in records if int(rec["lsn"]) > snap_lsn)
        index.attach_durability(dur, checkpoint=False)
        # ---- blob reconciliation against the recovered manifest ----
        present = set(index.storage.keys())
        claimed = set()
        for cid, cl in enumerate(index.clusters):
            if not (cl.active and cl.stored):
                continue
            claimed.add(cid)
            ok = False
            if cid in present:
                rep.edge_s += dur.cost.storage_seek_s   # CRC-member peek
                try:
                    ok = (index.storage.payload_crc(cid)
                          == manifest.get(cid))
                except KeyError:
                    ok = False
            if not ok:
                # missing or replaced mid-op before its record landed:
                # self-heal — regenerate THIS cluster and re-persist
                rep.edge_s += dur.cost.embed_latency(cl.char_count)
                rep.edge_s += dur.cost.wal_fsync_latency(
                    cl.size * index.dim * 4)
                index._restore_cluster(cid)
                index._wal_commit("recover_heal")
                rep.healed += 1
        for cid in sorted(present - claimed):
            # a blob nothing durable claims: a put that landed before its
            # WAL record (or a dropped cluster's leftover) — GC it so the
            # recovered index is exactly the durable state, never a hybrid
            index.storage.delete(cid)
            rep.orphans_gc += 1
            rep.edge_s += dur.cost.storage_seek_s
        # ---- re-derive the maintenance the crash threw away ----
        for cid, cl in enumerate(index.clusters):
            if not cl.active or cl.size == 0:
                continue
            if cl.char_count > index.split_max_chars and cl.size >= 2:
                index.maintenance.enqueue(OP_SPLIT, cid)
                rep.requeued_ops += 1
            elif 0 < cl.size < index.merge_min_size:
                index.maintenance.enqueue(OP_MERGE, cid)
                rep.requeued_ops += 1
            elif (index.store_heavy and cl.gen_latency_est > index.slo_s
                    and not cl.storage_fresh):
                index.maintenance.enqueue(OP_RESTORE, cid)
                rep.requeued_ops += 1
        rep.edge_s += dur.checkpoint(index)
    rep.wall_s = t.elapsed
    dur.last_recovery_s = rep.wall_s
    return rep


def recover(root: str, embed_fn, get_chunks,
            cost_model: Optional[EdgeCostModel] = None, *,
            storage_mode: str = "disk", tenant: Optional[str] = None,
            checkpoint_every: int = 64,
            crash: Optional[CrashInjector] = None,
            **index_kwargs):
    """Recover a single-tenant :class:`~repro.core.edgerag.EdgeRAGIndex`
    from ``root`` (the storage root the crashed index wrote blobs and
    durable state under).  The codec and dimensionality come from the
    snapshot itself.  Returns ``(index, RecoveryReport)``.

    The crashed process must actually be dead (or its backend object
    garbage-collected): the recovered backend becomes the root's writer.
    """
    from repro.core.edgerag import EdgeRAGIndex
    dur = Durability(root, tenant=tenant, cost_model=cost_model,
                     checkpoint_every=checkpoint_every, crash=crash)
    found = IndexSnapshot.newest_valid(dur.dir)
    if found is None:
        raise RecoveryError(
            f"no valid snapshot under {dur.dir!r} — nothing durable to "
            f"recover (build with a Durability handle attached first)")
    meta = json.loads(bytes(found[1][_META_KEY]).decode("utf-8"))
    index = EdgeRAGIndex(
        int(meta["dim"]), embed_fn, get_chunks, cost_model,
        storage_mode=storage_mode, storage_codec=meta["codec"],
        storage_root=root, **index_kwargs)
    report = recover_index(index, dur)
    return index, report


def recover_router(root: str, tenant_specs: Dict[str, Tuple],
                   cost_model: Optional[EdgeCostModel] = None, *,
                   storage_mode: str = "disk", checkpoint_every: int = 64,
                   router_kwargs: Optional[Dict] = None,
                   tenant_kwargs: Optional[Dict] = None):
    """Recover EVERY tenant of a crashed multi-tenant deployment from the
    shared ``root``.  ``tenant_specs`` maps tenant id ->
    ``(embed_fn, get_chunks)``; tenants are discovered from their
    per-tenant durability directories (``<root>/durability/tenant_<t>/``)
    and each one must have a spec.  Returns ``(TenantRouter,
    {tenant: RecoveryReport})``."""
    from repro.core.tenant import TenantRouter
    base = os.path.join(root, "durability")
    discovered = sorted(
        m.group(1) for m in
        (re.match(r"^tenant_([A-Za-z0-9._-]+)$", e)
         for e in (os.listdir(base) if os.path.isdir(base) else []))
        if m)
    if not discovered:
        raise RecoveryError(f"no per-tenant durable state under {base!r}")
    missing = [t for t in discovered if t not in tenant_specs]
    assert not missing, f"no (embed_fn, get_chunks) spec for {missing}"
    # the shared backend's codec / dim come from the first tenant snapshot
    meta = None
    for t in discovered:
        found = IndexSnapshot.newest_valid(os.path.join(base, f"tenant_{t}"))
        if found is not None:
            meta = json.loads(bytes(found[1][_META_KEY]).decode("utf-8"))
            break
    if meta is None:
        raise RecoveryError(f"no valid tenant snapshot under {base!r}")
    router = TenantRouter(int(meta["dim"]), cost_model,
                          storage_mode=storage_mode,
                          storage_codec=meta["codec"], storage_root=root,
                          **(router_kwargs or {}))
    reports: Dict[str, RecoveryReport] = {}
    for t in discovered:
        embed_fn, get_chunks = tenant_specs[t]
        ix = router.create_tenant(t, embed_fn, get_chunks,
                                  **(tenant_kwargs or {}))
        dur = Durability(root, tenant=t, cost_model=cost_model,
                         checkpoint_every=checkpoint_every)
        reports[t] = recover_index(ix, dur)
    return router, reports
