"""Edge cost model + latency accounting.

This container is CPU-only, so the paper's *absolute* numbers (Jetson Orin
Nano: 8 GB shared DRAM, SD-card storage, iGPU embedding model) are reproduced
through a calibrated cost model; the *algorithms* (what gets stored, cached,
evicted, regenerated) always run for real.  Every retrieval returns a
:class:`LatencyBreakdown` carrying both the simulated edge seconds and the
measured wall seconds of the real computation.

Calibration (paper §3.2, Fig. 4): generating embeddings for clusters smaller
than ~24 000 chars (~8 000 tokens) beats loading them from storage.  With the
gte-base throughput below (~60 k chars/s on the Orin iGPU), the 24 k-char
cluster generates in ~0.40 s; the same cluster's embeddings (~80 chunks ×
3 072 B) must therefore take ~0.40 s to load, giving the effective scattered-
read bandwidth of ~0.6 MB/s (4 KiB random reads on a UHS-I SD card under
memory pressure — the paper's "thrashing" regime).  Sequential DRAM loads are
modeled at LPDDR5 speeds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

BYTES_PER_EMBEDDING_F32 = 768 * 4


@dataclasses.dataclass
class EdgeCostModel:
    # embedding generation (gte-base-en-v1.5 on the Orin iGPU)
    embed_chars_per_sec: float = 60_000.0
    embed_fixed_s: float = 0.008
    # SD-card storage: SEQUENTIAL reads (EdgeRAG's contiguously-stored heavy
    # clusters) vs RANDOM 4K reads (page-in thrashing of a scattered index —
    # this is the regime behind Fig. 4's ~24 kchar gen-vs-load break-even)
    storage_seq_bw_bytes_per_sec: float = 80e6
    storage_rand_bw_bytes_per_sec: float = 0.6e6
    storage_seek_s: float = 0.005
    # in-memory index access
    dram_bw_bytes_per_sec: float = 34e9          # LPDDR5-4250 x4
    # memory budget: the generation model + runtime stay resident, so the
    # INDEX has device_memory - model_reserved to work with before thrashing
    device_memory_bytes: float = 8 * 1024**3
    model_reserved_bytes: float = 6.0e9          # 5.4 GB LLM bf16 + runtime
    # vector math throughput for similarity search (CPU+GPU)
    search_flops_per_sec: float = 2.0e11
    # int8/fp16 storage codecs dequantize on load (widen + scale per value)
    dequant_values_per_sec: float = 2.0e9
    # fused in-kernel dequant (packed-slab scoring): the widen rides the
    # score matmul's data stream and the int8 per-row scale is applied to
    # the (Q, N) score block, not the (N, D) slab — far cheaper per value
    # than a standalone decode pass that materializes an fp32 copy
    fused_dequant_values_per_sec: float = 8.0e9
    # PQ slab scoring charges LUT build + code gather INSTEAD of dequant:
    # a row's score is m table lookups + adds (random access, no SIMD
    # stream), well below the fused-dequant rate
    pq_lookup_values_per_sec: float = 4.0e9
    # LLM prefill (Sheared-LLaMA-2.7B on Orin): tokens/s
    prefill_tokens_per_sec: float = 400.0
    # autoregressive decode: one forward pass per tick, memory-bandwidth
    # bound, so a continuous-batching tick advances EVERY live slot at
    # roughly the single-stream rate — batch decode time is per-token,
    # not per-(token, slot)
    decode_tokens_per_sec: float = 20.0

    def embed_latency(self, n_chars: int) -> float:
        return self.embed_fixed_s + n_chars / self.embed_chars_per_sec

    @property
    def index_memory_budget(self) -> float:
        return self.device_memory_bytes - self.model_reserved_bytes

    def storage_load_latency(self, n_bytes: int) -> float:
        """Sequential read of a contiguously-stored cluster."""
        return self.storage_seek_s + n_bytes / self.storage_seq_bw_bytes_per_sec

    def mem_load_latency(self, n_bytes: int, resident_bytes: float = 0.0) -> float:
        """DRAM access; degrades to random-read thrashing when the resident
        index exceeds its memory budget (Fig. 3's regime)."""
        if resident_bytes > self.index_memory_budget:
            over = ((resident_bytes - self.index_memory_budget)
                    / resident_bytes)
            # fraction `over` of accesses page-fault as scattered 4K reads
            return (n_bytes * (1 - over) / self.dram_bw_bytes_per_sec
                    + n_bytes * over / self.storage_rand_bw_bytes_per_sec)
        return n_bytes / self.dram_bw_bytes_per_sec

    def search_latency(self, n_vectors: int, dim: int) -> float:
        return 2.0 * n_vectors * dim / self.search_flops_per_sec

    def dequant_latency(self, n_values: int) -> float:
        """Decode cost of a quantized storage codec (zero work for fp32)."""
        return n_values / self.dequant_values_per_sec

    def fused_dequant_latency(self, n_values: int) -> float:
        """In-kernel decode of a quantized slab segment, charged once per
        slab (per unique cluster) — never per probing query."""
        return n_values / self.fused_dequant_values_per_sec

    def pq_lut_latency(self, dim: int, n_centroids: int = 256) -> float:
        """Building ONE query's ADC tables: every subspace dots the query
        slice against its 256 centroids — together one (256, dim) matmul,
        2·256·dim flops.  Charged per query per batch (the tables are
        reused across every PQ row the query scores)."""
        return 2.0 * n_centroids * dim / self.search_flops_per_sec

    def pq_gather_latency(self, n_lookups: int) -> float:
        """In-kernel gather+accumulate over PQ codes, owner-charged once
        per slab cluster (rows × m lookups) — replaces the dequant charge
        other codecs pay."""
        return n_lookups / self.pq_lookup_values_per_sec

    def slab_pack_latency(self, n_bytes: int) -> float:
        """Copying one resolved cluster's compact payload into the batch
        slab: a DRAM read + write.  Replaces the old per-query concat,
        which re-copied every shared cluster once per probing query."""
        return 2.0 * n_bytes / self.dram_bw_bytes_per_sec

    def wal_fsync_latency(self, n_bytes: int) -> float:
        """Appending + fsyncing one WAL frame (or snapshot payload): a
        flash write barrier (same order as a seek on SD-class media) plus
        the frame streamed at sequential bandwidth.  Charged per durable
        mutation when a ``Durability`` handle is attached
        (core/durability.py)."""
        return self.storage_seek_s + n_bytes / self.storage_seq_bw_bytes_per_sec

    def prefill_latency(self, n_tokens: int) -> float:
        return n_tokens / self.prefill_tokens_per_sec

    def decode_latency(self, n_tokens: int) -> float:
        """Decode ticks for ``n_tokens`` output tokens (whole batch: each
        tick advances every live slot, see ``decode_tokens_per_sec``)."""
        return n_tokens / self.decode_tokens_per_sec


@dataclasses.dataclass
class LatencyBreakdown:
    """Per-query accounting (simulated edge seconds + real wall seconds)."""
    embed_query_s: float = 0.0
    centroid_search_s: float = 0.0
    l2_generate_s: float = 0.0
    l2_storage_load_s: float = 0.0
    l2_dequant_s: float = 0.0   # codec decode — compute, not storage I/O
    l2_cache_hit_s: float = 0.0
    l2_mem_load_s: float = 0.0
    l2_search_s: float = 0.0
    # packed-slab scoring engine (owner-charged, once per unique cluster):
    l2_slab_pack_s: float = 0.0         # compact payload copy into the slab
    l2_fused_dequant_s: float = 0.0     # in-kernel fp16/int8 decode
    # PQ tier (charged INSTEAD of dequant for pq segments):
    l2_pq_lut_s: float = 0.0            # per-query ADC table build
    l2_pq_gather_s: float = 0.0         # in-kernel code gather+accumulate
    # failure model (core/faults.py) — zero on the fault-free path:
    l2_stall_s: float = 0.0             # injected storage stall tail (I/O)
    l2_retry_backoff_s: float = 0.0     # modeled retry exponential backoff
    # durability (core/durability.py) — the WAL record a retrieval-path
    # Alg. 1 self-heal re-persist emits; zero unless a handle is attached:
    wal_fsync_s: float = 0.0
    wall_s: float = 0.0
    n_clusters_probed: int = 0
    n_generated: int = 0
    n_storage_loads: int = 0
    n_cache_hits: int = 0
    n_shared_hits: int = 0      # batched search: cluster resolved by a peer
    chars_embedded: int = 0
    # degradation ladder accounting (core/faults.py):
    retries: int = 0            # storage read attempts that were retried
    degraded_clusters: int = 0  # probes shed / regens skipped under deadline
    stale_served: int = 0       # stale payloads scored instead of regenerated

    # retrieval fields grouped by the serving pipeline stage that does the
    # work (serving/pipeline.py): S1 probe/plan, S2 storage fetch / regen,
    # S3 slab pack + score.  The three partitions are exhaustive —
    # ``retrieval_s`` is exactly their sum, asserted in tests.
    STAGE_FIELDS = {
        "plan": ("embed_query_s", "centroid_search_s"),
        "fetch": ("l2_generate_s", "l2_storage_load_s", "l2_dequant_s",
                  "l2_cache_hit_s", "l2_stall_s", "l2_retry_backoff_s",
                  "wal_fsync_s"),
        "score": ("l2_slab_pack_s", "l2_fused_dequant_s", "l2_pq_lut_s",
                  "l2_pq_gather_s", "l2_mem_load_s", "l2_search_s"),
    }

    def stage_s(self, stage: str) -> float:
        """Edge seconds this query spent in one pipeline stage."""
        return sum(getattr(self, f) for f in self.STAGE_FIELDS[stage])

    @property
    def retrieval_s(self) -> float:
        return (self.stage_s("plan") + self.stage_s("fetch")
                + self.stage_s("score"))

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d.pop("STAGE_FIELDS", None)
        return d | {"retrieval_s": self.retrieval_s}


class WallTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0
