"""K-means clustering for the IVF first level.

The paper builds its first-level index with FAISS k-means, 20 iterations
(§6.2).  This is our JAX replacement: k-means++ seeding + jit'd Lloyd
iterations.  Works on unit-normalized embeddings (spherical k-means is the
cosine-similarity analogue; we re-normalize centroids each iteration).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("block",))
def pairwise_neg_ip(x, c, block: int = 0):
    """Negative inner product 'distance' (unit vectors): lower = closer."""
    return -(x @ c.T)


@jax.jit
def _assign(x, centroids):
    d = pairwise_neg_ip(x, centroids)
    return jnp.argmin(d, axis=1), -jnp.min(d, axis=1)


@functools.partial(jax.jit, static_argnames=("k",))
def _update(x, assign, k: int):
    one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)          # (n, k)
    sums = one_hot.T @ x                                        # (k, d)
    counts = one_hot.sum(0)[:, None]
    cent = sums / jnp.maximum(counts, 1.0)
    norm = jnp.linalg.norm(cent, axis=1, keepdims=True)
    cent = cent / jnp.maximum(norm, 1e-9)
    return cent, counts[:, 0]


def kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator):
    """k-means++ seeding (host-side; O(n·k) total)."""
    n = x.shape[0]
    first = int(rng.integers(n))
    centroids = [x[first]]
    d2 = 2.0 - 2.0 * (x @ x[first])                             # unit vectors
    for _ in range(1, k):
        d2c = np.clip(d2, 1e-12, None)
        probs = d2c / d2c.sum()
        idx = int(rng.choice(n, p=probs))
        centroids.append(x[idx])
        d_new = 2.0 - 2.0 * (x @ x[idx])
        d2 = np.minimum(d2, d_new)
    return np.stack(centroids)


def kmeans(x: np.ndarray, k: int, iters: int = 20,
           seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (centroids (k, d) unit-norm, assignments (n,))."""
    x = np.asarray(x, np.float32)
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    xn = x / np.clip(norms, 1e-9, None)
    rng = np.random.default_rng(seed)
    k = min(k, x.shape[0])
    cent = kmeans_pp_init(xn, k, rng)
    xj = jnp.asarray(xn)
    cj = jnp.asarray(cent)
    for _ in range(iters):
        assign, _ = _assign(xj, cj)
        cj, counts = _update(xj, assign, k)
        # re-seed empty clusters to the farthest points (host-side, rare)
        empties = np.where(np.asarray(counts) == 0)[0]
        if len(empties):
            d = np.asarray(pairwise_neg_ip(xj, cj)).min(axis=1)
            far = np.argsort(-d)[:len(empties)]  # least-similar points
            c_host = np.array(cj)      # writable copy (asarray of a jax
            c_host[empties] = xn[far]  # array is a read-only view)
            cj = jnp.asarray(c_host)
    assign, _ = _assign(xj, cj)
    return np.array(cj), np.array(assign)  # writable host copies
