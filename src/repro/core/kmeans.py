"""K-means clustering for the IVF first level.

The paper builds its first-level index with FAISS k-means, 20 iterations
(§6.2).  This is our JAX replacement: k-means++ seeding + jit'd Lloyd
iterations.  Works on unit-normalized embeddings (spherical k-means is the
cosine-similarity analogue; we re-normalize centroids each iteration).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("block",))
def pairwise_neg_ip(x, c, block: int = 0):
    """Negative inner product 'distance' (unit vectors): lower = closer."""
    return -(x @ c.T)


@jax.jit
def _assign(x, centroids):
    d = pairwise_neg_ip(x, centroids)
    return jnp.argmin(d, axis=1), -jnp.min(d, axis=1)


@functools.partial(jax.jit, static_argnames=("k",))
def _update(x, assign, k: int):
    one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)          # (n, k)
    sums = one_hot.T @ x                                        # (k, d)
    counts = one_hot.sum(0)[:, None]
    cent = sums / jnp.maximum(counts, 1.0)
    norm = jnp.linalg.norm(cent, axis=1, keepdims=True)
    cent = cent / jnp.maximum(norm, 1e-9)
    return cent, counts[:, 0]


def kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator):
    """k-means++ seeding (host-side; O(n·k) total)."""
    n = x.shape[0]
    first = int(rng.integers(n))
    centroids = [x[first]]
    d2 = 2.0 - 2.0 * (x @ x[first])                             # unit vectors
    for _ in range(1, k):
        d2c = np.clip(d2, 1e-12, None)
        probs = d2c / d2c.sum()
        idx = int(rng.choice(n, p=probs))
        centroids.append(x[idx])
        d_new = 2.0 - 2.0 * (x @ x[idx])
        d2 = np.minimum(d2, d_new)
    return np.stack(centroids)


@jax.jit
def _assign_l2(x, centroids):
    # ||x - c||^2 = ||x||^2 - 2 x·c + ||c||^2 ; the x term is constant per
    # row so argmin only needs the last two.
    d = jnp.sum(centroids * centroids, axis=1)[None, :] - 2.0 * (x @ centroids.T)
    return jnp.argmin(d, axis=1)


@functools.partial(jax.jit, static_argnames=("k",))
def _update_l2(x, assign, k: int):
    one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)          # (n, k)
    sums = one_hot.T @ x                                        # (k, d)
    counts = one_hot.sum(0)[:, None]
    return sums / jnp.maximum(counts, 1.0), counts[:, 0]


def kmeans_euclidean(x: np.ndarray, k: int, iters: int = 20,
                     seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Plain (non-spherical) Lloyd k-means for PQ subspace codebooks.

    The IVF variant above assumes unit-normalized inputs; PQ subspaces are
    arbitrary low-dimensional slices where re-normalizing centroids would
    destroy the reconstruction, so centroids here are unconstrained means
    under squared-Euclidean distance.  Returns (centroids (k, d),
    assignments (n,))."""
    x = np.ascontiguousarray(x, np.float32)
    rng = np.random.default_rng(seed)
    k = min(k, x.shape[0])
    # k-means++ under true L2 (the unit-vector shortcut does not apply)
    n = x.shape[0]
    cent = [x[int(rng.integers(n))]]
    d2 = np.sum((x - cent[0]) ** 2, axis=1)
    for _ in range(1, k):
        d2c = np.clip(d2, 1e-12, None)
        idx = int(rng.choice(n, p=d2c / d2c.sum()))
        cent.append(x[idx])
        d2 = np.minimum(d2, np.sum((x - x[idx]) ** 2, axis=1))
    xj = jnp.asarray(x)
    cj = jnp.asarray(np.stack(cent))
    for _ in range(iters):
        assign = _assign_l2(xj, cj)
        cj, counts = _update_l2(xj, assign, k)
        empties = np.where(np.asarray(counts) == 0)[0]
        if len(empties):
            # re-seed empties to the points farthest from their centroid
            d = np.sum((x - np.asarray(cj)[np.asarray(assign)]) ** 2, axis=1)
            far = np.argsort(-d)[:len(empties)]
            c_host = np.array(cj)
            c_host[empties] = x[far]
            cj = jnp.asarray(c_host)
    assign = _assign_l2(xj, cj)
    return np.array(cj), np.array(assign)


def kmeans(x: np.ndarray, k: int, iters: int = 20,
           seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (centroids (k, d) unit-norm, assignments (n,))."""
    x = np.asarray(x, np.float32)
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    xn = x / np.clip(norms, 1e-9, None)
    rng = np.random.default_rng(seed)
    k = min(k, x.shape[0])
    cent = kmeans_pp_init(xn, k, rng)
    xj = jnp.asarray(xn)
    cj = jnp.asarray(cent)
    for _ in range(iters):
        assign, _ = _assign(xj, cj)
        cj, counts = _update(xj, assign, k)
        # re-seed empty clusters to the farthest points (host-side, rare)
        empties = np.where(np.asarray(counts) == 0)[0]
        if len(empties):
            d = np.asarray(pairwise_neg_ip(xj, cj)).min(axis=1)
            far = np.argsort(-d)[:len(empties)]  # least-similar points
            c_host = np.array(cj)      # writable copy (asarray of a jax
            c_host[empties] = xn[far]  # array is a read-only view)
            cj = jnp.asarray(c_host)
    assign, _ = _assign(xj, cj)
    return np.array(cj), np.array(assign)  # writable host copies
