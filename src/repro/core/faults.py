"""Deterministic fault injection + deadline-aware graceful degradation.

The whole retrieve path (StorageBackend -> ClusterResolver -> slab scoring
-> RAGEngine) used to assume I/O never fails and every request can afford
full-fidelity resolution.  The paper's premise is flash-backed edge storage,
where slow / torn / corrupt SD reads are the norm, not the exception; this
module gives the stack an explicit failure model and a degradation ladder.

FAULT TAXONOMY (:class:`FaultInjector`, seeded and deterministic given the
same configuration and call order):

  missing    the key transiently reads as absent (flaky directory entry)
  flip       one bit of the payload (any array, any byte) is flipped
  truncate   the payload loses its trailing row (a torn write surfacing
             on read)
  io         the read raises a transient ``IOError``
  stall      the read completes but its latency spikes — stall seconds are
             drawn from a configurable log-normal tail distribution and
             charged into the request's :class:`LatencyBreakdown`
             (``l2_stall_s``), riding the same edge-cost accounting as the
             modeled storage bandwidth

The injector perturbs a COPY of each payload: the underlying store is never
damaged by injection, so a retry can observe a clean read.  ``flip`` and
``truncate`` are caught by the per-key checksum ``StorageBackend`` verifies
on every load; ``missing`` / ``io`` surface as the corresponding read
failures.  ``StorageBackend`` retries failed reads with bounded exponential
backoff (modeled edge seconds, never a real sleep); a read that exhausts
its retries degrades to the regeneration fallback upstream instead of
raising, and a checksum failure that survives every retry quarantine-drops
the blob so the resolver's self-heal re-persists a fresh copy.

DEGRADATION LADDER (:class:`DegradationPolicy`): each request may carry a
deadline budget (modeled edge seconds).  Under pressure the resolver sheds
work in a defined order rather than blowing the deadline:

  1. shrink effective nprobe — trailing probed clusters (never below
     ``min_nprobe``) are dropped while the estimated resolution cost
     exceeds the remaining budget;
  2. skip regeneration of the largest unstored tail clusters — an owner
     whose queued regenerations cannot fit the remaining budget sheds the
     most expensive ones first (they resolve to zero rows);
  3. serve cached-but-stale payloads flagged stale — a payload whose
     generation moved since plan time (or a stale storage copy) is scored
     anyway when regeneration would blow the deadline and the row count
     still aligns, instead of being regenerated.

Every shed step is recorded: ``LatencyBreakdown.degraded_clusters`` counts
rung-1/rung-2 sheds, ``stale_served`` counts rung-3 serves, ``retries``
counts storage read retries; :class:`~repro.serving.engine.RAGResponse`
surfaces them plus an ``outcome`` ("ok" / "degraded" / "missed").

With no injector attached and no deadlines passed, every code path in this
module is bypassed and fp32 results stay bit-identical to the fault-free
pipeline (the Table-4 parity tests run unmodified).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("missing", "flip", "truncate", "io")

# Durability write boundaries where a :class:`CrashInjector` can cut the
# process (core/durability.py calls ``crash.hit(point)`` at each one).
# WAL append:
#   wal_pre_append    before any frame byte reaches the log (op lost whole)
#   wal_torn_append   mid-append — a seeded prefix of the frame is written,
#                     then the "power" goes: the torn tail recovery must
#                     truncate
#   wal_post_append   frame fully written + fsynced
# Snapshot (atomic tmp + ``os.replace``):
#   snap_pre_tmp      before the tmp file is opened
#   snap_torn_tmp     mid-tmp-write — a truncated tmp is left behind
#   snap_pre_rename   tmp complete, rename not issued
#   snap_post_rename  snapshot durable (crash before WAL compaction)
CRASH_POINTS = ("wal_pre_append", "wal_torn_append", "wal_post_append",
                "snap_pre_tmp", "snap_torn_tmp", "snap_pre_rename",
                "snap_post_rename")


class InjectedFault(Exception):
    """Base of the injector-raised read failures."""


class InjectedMissing(InjectedFault):
    """The key transiently reads as absent."""


class TransientIOError(InjectedFault, IOError):
    """The read raised a transient I/O error."""


class CorruptPayloadError(Exception):
    """Checksum mismatch (real torn/bit-rotted blob or injected corruption)
    — or an unreadable .npz container."""


class SimulatedCrash(BaseException):
    """Raised by :class:`CrashInjector` at a durability write boundary.

    Deliberately a ``BaseException``: a crash is not an error the write
    path may catch and clean up after — torn tmp files and half-written
    frames must stay on disk exactly as a power loss would leave them, so
    recovery code (not writer cleanup) is what gets exercised."""

    def __init__(self, point: str):
        super().__init__(point)
        self.point = point


class CrashInjector:
    """Seeded process-death injection at durability write boundaries.

    Crashes on the ``at``-th time execution reaches crashpoint ``point``
    (one of :data:`CRASH_POINTS`); every other boundary passes through
    untouched.  For the torn-write points (``wal_torn_append`` /
    ``snap_torn_tmp``) the writer asks :meth:`torn_length` how many bytes
    of the frame / tmp payload to emit before dying — drawn from the
    injector's seeded generator, so the same (point, at, seed) triple
    reproduces the identical torn file."""

    def __init__(self, point: str, at: int = 1, seed: int = 0):
        assert point in CRASH_POINTS, point
        assert at >= 1, at
        self.point = point
        self.at = int(at)
        self.rng = np.random.default_rng(seed)
        self.hits: Dict[str, int] = {p: 0 for p in CRASH_POINTS}
        self.crashed = False

    def hit(self, point: str) -> None:
        """Register reaching one boundary; raises :class:`SimulatedCrash`
        when this is the configured occurrence."""
        assert point in CRASH_POINTS, point
        self.hits[point] += 1
        if (not self.crashed and point == self.point
                and self.hits[point] == self.at):
            self.die(point)

    def take(self, point: str) -> bool:
        """Register reaching a TWO-PHASE (torn-write) boundary; True iff
        this occurrence is the configured crash.  The writer then emits
        its :meth:`torn_length` partial bytes and calls :meth:`die` — the
        crash must land *after* the torn prefix hits disk, so this cannot
        raise the way :meth:`hit` does."""
        assert point in CRASH_POINTS, point
        self.hits[point] += 1
        return (not self.crashed and point == self.point
                and self.hits[point] == self.at)

    def die(self, point: str) -> None:
        self.crashed = True
        raise SimulatedCrash(point)

    def torn_length(self, n_bytes: int) -> int:
        """How many of a frame's ``n_bytes`` land before the torn crash:
        uniform over [1, n_bytes) — never zero (that's the pre-append
        point) and never complete (that's post-append)."""
        if n_bytes <= 1:
            return 0
        return int(self.rng.integers(1, n_bytes))


@dataclasses.dataclass
class IOOutcome:
    """What one keyed read cost and how it ended (one per requested key)."""
    key: int
    ok: bool = True
    retries: int = 0             # failed attempts that were retried
    stall_s: float = 0.0         # injected stall seconds (edge)
    backoff_s: float = 0.0       # modeled retry backoff seconds (edge)
    error: Optional[str] = None  # terminal: "missing" | "corrupt" | "io"


class FaultInjector:
    """Seeded fault source wrapped around ``StorageBackend`` reads.

    ``fault_rate`` is the per-read-attempt probability of one injected
    fault, split across ``kind_weights`` (default: uniform over
    missing / flip / truncate / io).  ``stall_rate`` independently spikes a
    read's latency by ``stall_scale_s * lognormal(0, stall_sigma)`` modeled
    seconds.  All draws come from one ``numpy`` generator seeded at
    construction: the same configuration replayed over the same read
    sequence injects the identical faults.
    """

    def __init__(self, seed: int = 0, fault_rate: float = 0.0,
                 kind_weights: Optional[Dict[str, float]] = None,
                 stall_rate: float = 0.0, stall_scale_s: float = 0.05,
                 stall_sigma: float = 1.0):
        weights = dict(kind_weights or {k: 1.0 for k in FAULT_KINDS})
        assert all(k in FAULT_KINDS for k in weights), weights
        total = sum(weights.values())
        self.kinds = sorted(weights)
        self.probs = np.array([weights[k] / total for k in self.kinds])
        self.fault_rate = float(fault_rate)
        self.stall_rate = float(stall_rate)
        self.stall_scale_s = float(stall_scale_s)
        self.stall_sigma = float(stall_sigma)
        self.rng = np.random.default_rng(seed)
        self.injected: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self.stalls = 0
        self.stall_s_total = 0.0

    @property
    def injected_total(self) -> int:
        """Injected read FAULTS (stalls excluded: a stalled read still
        returns good data, it just pays for it)."""
        return sum(self.injected.values())

    def stats(self) -> Dict[str, object]:
        return {"injected": dict(self.injected),
                "injected_total": self.injected_total,
                "stalls": self.stalls,
                "stall_s_total": self.stall_s_total}

    # ------------------------------------------------------------------
    def perturb(self, key: int, payload: Dict[str, np.ndarray],
                outcome: Optional[IOOutcome] = None
                ) -> Dict[str, np.ndarray]:
        """One read attempt over ``payload``: maybe stall, maybe inject one
        fault.  Returns the payload (possibly a corrupted COPY — the stored
        arrays are never touched) or raises the injected failure."""
        if self.stall_rate and self.rng.random() < self.stall_rate:
            s = self.stall_scale_s * float(
                self.rng.lognormal(0.0, self.stall_sigma))
            self.stalls += 1
            self.stall_s_total += s
            if outcome is not None:
                outcome.stall_s += s
        if self.fault_rate and self.rng.random() < self.fault_rate:
            kind = self.kinds[int(self.rng.choice(len(self.kinds),
                                                  p=self.probs))]
            self.injected[kind] += 1
            if kind == "missing":
                raise InjectedMissing(key)
            if kind == "io":
                raise TransientIOError(key)
            return self._corrupt(payload, kind)
        return payload

    def _corrupt(self, payload: Dict[str, np.ndarray], kind: str
                 ) -> Dict[str, np.ndarray]:
        out = dict(payload)
        if kind == "truncate":
            # drop the trailing row of the widest array (a torn write);
            # degenerate payloads fall through to a bit flip
            name = max(payload, key=lambda n: payload[n].nbytes)
            a = payload[name]
            if a.ndim >= 1 and len(a) >= 1:
                out[name] = np.array(a[:-1], copy=True)
                return out
        name = max(payload, key=lambda n: payload[n].nbytes)
        b = np.array(payload[name], copy=True)
        flat = b.reshape(-1).view(np.uint8)
        if flat.size == 0:                  # nothing to flip: read as absent
            raise InjectedMissing("empty payload")
        i = int(self.rng.integers(flat.size))
        flat[i] ^= np.uint8(1 << int(self.rng.integers(8)))
        out[name] = b
        return out


@dataclasses.dataclass
class DegradationPolicy:
    """Deadline-pressure shedding knobs (see module docstring for the
    ladder).  ``prefill_reserve_frac`` is the fraction of a TTFT deadline
    the serving engine reserves for prefill when deriving the retrieval
    budget it hands to ``search_batch``."""
    min_nprobe: int = 2          # rung 1 never shrinks the probe set below
    shed_probes: bool = True     # rung 1: shrink effective nprobe
    shed_regen: bool = True      # rung 2: skip largest unaffordable regens
    serve_stale: bool = True     # rung 3: score stale payloads, flagged
    prefill_reserve_frac: float = 0.3

    # ------------------------------------------------------------------
    def resolve_estimate(self, index, cid: int) -> float:
        """Cheap plan-time estimate of resolving one cluster (edge s)."""
        cl = index.clusters[cid]
        if cl.storage_fresh and cid in index.storage:
            try:
                nbytes = index.storage.stored_bytes(cid)
            except KeyError:
                nbytes = cl.size * index.dim * 4
            return index.cost.storage_load_latency(nbytes)
        if cid in index.cache:       # peek only — no Alg. 2 counter bump
            return index.cost.mem_load_latency(cl.size * index.dim * 4)
        return cl.gen_latency_est

    def trim_probes(self, index,
                    probed_per_q: Sequence[Sequence[int]],
                    deadlines: Sequence[Optional[float]],
                    base_s: Sequence[float]
                    ) -> Tuple[List[List[int]], List[int]]:
        """Rung 1: per query, walk the probe list in probe order and drop
        trailing clusters (never below ``min_nprobe``) while the estimated
        cumulative resolution cost exceeds the remaining deadline budget.
        ``base_s`` is each query's already-committed edge seconds (query
        embed + centroid search).  Returns (trimmed lists, shed counts)."""
        trimmed: List[List[int]] = []
        shed: List[int] = []
        for qi, probed in enumerate(probed_per_q):
            deadline = deadlines[qi]
            if deadline is None or not self.shed_probes:
                trimmed.append(list(probed))
                shed.append(0)
                continue
            budget = deadline - base_s[qi]
            keep: List[int] = []
            total = 0.0
            for pos, cid in enumerate(probed):
                est = self.resolve_estimate(index, cid)
                if pos < self.min_nprobe or total + est <= budget:
                    keep.append(cid)
                    total += est
            trimmed.append(keep)
            shed.append(len(probed) - len(keep))
        return trimmed, shed
