"""EdgeRAG core: the paper's contribution.

Index zoo (Table 4):
  FlatIndex              exhaustive baseline
  IVFIndex               two-level, all embeddings resident
  EdgeRAGIndex           pruned second level + selective storage + caching
                         (flags give the IVF+Gen / IVF+Gen+Load ablations)
"""
from repro.core.cache_policy import (CostAwareLFUCache,  # noqa
                                     MinLatencyThresholdController,
                                     TenantCacheView)
from repro.core.costs import EdgeCostModel, LatencyBreakdown  # noqa
from repro.core.durability import (Durability, IndexSnapshot,  # noqa
                                   RecoveryError, RecoveryReport,
                                   WriteAheadLog, recover, recover_index,
                                   recover_router)
from repro.core.edgerag import EdgeCluster, EdgeRAGIndex  # noqa
from repro.core.faults import (CRASH_POINTS, CorruptPayloadError,  # noqa
                               CrashInjector, DegradationPolicy,
                               FaultInjector, IOOutcome, SimulatedCrash)
from repro.core.flat_index import FlatIndex  # noqa
from repro.core.ivf_index import IVFIndex  # noqa
from repro.core.kmeans import kmeans  # noqa
from repro.core.maintenance import (OP_CHECKPOINT,  # noqa
                                    FairShareMaintenance, MaintenanceOp,
                                    MaintenanceReport, MaintenanceScheduler)
from repro.core.resolver import ClusterResolver, ResolutionPlan  # noqa
from repro.core.storage import StorageBackend, TenantStorageView  # noqa
from repro.core.tenant import (MultiTenantSearchState,  # noqa
                               TenantRouter)
