"""Multi-tenant index service: shared substrate behind a TenantRouter.

EdgeRAG's premise is many indexes sharing one memory-constrained device
(arXiv 2412.21023), and on a single device the win comes from multiplexing
every tenant's retrieval through ONE shared engine rather than siloed
per-index stacks (RAGDoll, arXiv 2504.15302).  This module turns the
repo's single-tenant singletons into shared services:

  storage      one :class:`~repro.core.storage.StorageBackend` holding
               every tenant's blobs under ``(tenant, cid)`` keys and one
               optional shared byte budget; each tenant's index sees an
               int-keyed :class:`~repro.core.storage.TenantStorageView`
  cache        one :class:`~repro.core.cache_policy.CostAwareLFUCache`
               (one DRAM budget, global cost-aware eviction, per-tenant
               accounting) behind per-tenant
               :class:`~repro.core.cache_policy.TenantCacheView`\\ s
  maintenance  per-tenant :class:`~repro.core.maintenance
               .MaintenanceScheduler`\\ s multiplexed by
               :class:`~repro.core.maintenance.FairShareMaintenance` —
               effective queue keys are ``(tenant, kind, cid)`` and idle
               windows drain round-robin across tenants
  scoring      one slab engine: a mixed-tenant batch resolves per tenant
               (S1 probe / S2 fetch are tenant-local by construction — the
               centroid tables are disjoint) but packs ALL tenants'
               resolved clusters into a single
               :class:`~repro.core.resolver.SlabLayout` and scores every
               query in ONE ragged ``slab_topk`` launch per storage
               representation.  Cluster identity is ``(tenant, cid)`` end
               to end through the merged :class:`ResolutionPlan`.

BIT-IDENTICALITY.  Fusing tenants into one slab cannot perturb any query's
results: the virt matrix masks every row outside the query's own probe
list, so per-(query, cluster) scores are independent of what else shares
the launch (the same argument that makes slab scoring match the per-query
concat loop, asserted in tests/test_slab_scoring.py).  A router with ONE
tenant replays a standalone :class:`EdgeRAGIndex` exactly — same kernel
calls, same cache/threshold mutations, same modeled charges — and a
standalone index is just the degenerate one-tenant router.

Serving integration: :class:`~repro.serving.engine.RAGEngine`,
:class:`~repro.serving.pipeline.StagedPipeline`, and
:class:`~repro.serving.scheduler.RequestScheduler` accept a router as
their ``index`` and thread a per-query ``tenants`` list through the stage
methods; per-tenant SLO-aware admission lives in
:class:`~repro.serving.scheduler.TokenBucketAdmission`.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache_policy import (CostAwareLFUCache,
                                     TenantCacheView)
from repro.core.costs import EdgeCostModel, LatencyBreakdown, WallTimer
from repro.core.edgerag import (BatchSearchState, EdgeRAGIndex,
                                slab_score_topk)
from repro.core.faults import DegradationPolicy
from repro.core.maintenance import FairShareMaintenance
from repro.core.resolver import ClusterResolver, ResolutionPlan, SlabPayload
from repro.core.storage import StorageBackend, TenantStorageView

_TENANT_ID_RE = re.compile(r"^[A-Za-z0-9._-]+$")

TenantKey = Tuple[str, int]     # cluster identity across the router


class _TenantClusters:
    """``(tenant, cid) -> EdgeCluster`` mapping facade.

    The shared :class:`~repro.core.resolver.ClusterResolver` methods the
    router reuses (``pack_slab``, ``stale_cids``) only ever index
    ``index.clusters[key]`` — this facade routes the composite key to the
    owning tenant's cluster table, so those methods work verbatim over a
    merged cross-tenant plan."""

    def __init__(self, router: "TenantRouter"):
        self._router = router

    def __getitem__(self, key: TenantKey):
        tenant, cid = key
        return self._router.tenants[tenant].clusters[cid]


@dataclasses.dataclass
class MultiTenantSearchState:
    """In-flight state of one mixed-tenant staged retrieval.

    Mirrors :class:`~repro.core.edgerag.BatchSearchState` where the
    serving layer is concerned (``plan`` / ``lats`` / ``missed`` /
    ``payloads`` / ``nq`` / ``shrink_deadlines`` / ``centroid_total_s``)
    but holds one per-tenant :class:`BatchSearchState` per tenant present
    in the batch plus the MERGED ``(tenant, cid)``-keyed plan the fused S3
    scores from.  ``lats[qi]`` is the SAME LatencyBreakdown object as the
    owning tenant state's local entry, so per-tenant stage charges land in
    the global view without copying."""
    queries: np.ndarray                      # (Q, d) f32, global batch order
    k: int
    plan: ResolutionPlan                     # merged, (tenant, cid) keys
    lats: List[LatencyBreakdown]             # global order, shared objects
    missed: List[bool]
    tenants: List[str]                       # per-query tenant id
    order: Dict[str, List[int]]              # tenant -> global qi list
    states: Dict[str, BatchSearchState]      # per-tenant staged states
    payloads: Optional[Dict[TenantKey, SlabPayload]] = None
    mesh: object = None
    shard_axis: str = "data"
    wall_accum_s: float = 0.0                # router-side (merge) wall time

    @property
    def nq(self) -> int:
        return self.queries.shape[0]

    @property
    def centroid_total_s(self) -> float:
        """S1 runs ONE centroid launch PER TENANT in the batch — the
        stage's edge occupancy is their sum, not one tenant's charge."""
        return sum(st.centroid_total_s for st in self.states.values())

    def shrink_deadlines(self, extra_wait_s: float):
        for st in self.states.values():
            st.shrink_deadlines(extra_wait_s)


class TenantRouter:
    """Routes per-tenant corpora onto one shared EdgeRAG substrate.

    ``create_tenant`` builds an :class:`EdgeRAGIndex` whose storage and
    cache are views into the router's shared backend / cache and whose
    maintenance scheduler joins the fair-share drain.  Mixed batches go
    through :meth:`search_batch` (or the staged ``search_begin`` /
    ``search_fetch`` / ``search_finish`` the serving pipeline calls) with
    a per-query ``tenants`` list; per-tenant probing and resolution feed
    ONE fused cross-tenant slab launch per storage representation.
    """

    def __init__(self, dim: int, cost_model: Optional[EdgeCostModel] = None,
                 *, slo_s: float = 1.0,
                 cache_bytes: Optional[int] = None,
                 storage_mode: str = "memory",
                 storage_codec: str = "fp32",
                 storage_root: Optional[str] = None,
                 storage_budget_bytes: Optional[int] = None):
        self.dim = dim
        self.cost = cost_model or EdgeCostModel()
        self.slo_s = slo_s
        if cache_bytes is None:
            cache_bytes = int(0.07 * self.cost.device_memory_bytes)  # §6.3.4
        self.cache = CostAwareLFUCache(cache_bytes)
        self.storage = StorageBackend(storage_mode, root=storage_root,
                                      codec=storage_codec,
                                      budget_bytes=storage_budget_bytes)
        self.maintenance = FairShareMaintenance()
        self.tenants: Dict[str, EdgeRAGIndex] = {}
        self.clusters = _TenantClusters(self)
        # pack_slab / stale_cids run against the router as if it were an
        # index: they only touch .dim / .cost / .clusters[key]
        self.resolver = ClusterResolver(self)
        self._durability_cfg: Optional[Dict] = None

    # ------------------------------------------------------------------
    # tenant lifecycle
    # ------------------------------------------------------------------
    def create_tenant(self, tenant_id: str,
                      embed_fn: Callable[[Sequence[str]], np.ndarray],
                      get_chunks: Callable[[Sequence[int]], List[str]],
                      *, slo_s: Optional[float] = None,
                      store_heavy: bool = True,
                      split_max_chars: int = 200_000,
                      merge_min_size: int = 2,
                      maintenance: str = "deferred",
                      maintenance_budget_s: Optional[float] = None
                      ) -> EdgeRAGIndex:
        """Register a tenant and return its index (call ``build`` on it).
        The index owns its first level (centroids, cluster table, Alg. 3
        threshold) and SHARES the router's storage / cache / maintenance
        substrate through tenant-scoped views."""
        tenant_id = str(tenant_id)
        assert _TENANT_ID_RE.match(tenant_id), \
            f"tenant id must match [A-Za-z0-9._-]+, got {tenant_id!r}"
        assert tenant_id not in self.tenants, \
            f"tenant {tenant_id!r} already exists"
        ix = EdgeRAGIndex(
            self.dim, embed_fn, get_chunks, self.cost,
            slo_s=self.slo_s if slo_s is None else slo_s,
            store_heavy=store_heavy,
            split_max_chars=split_max_chars,
            merge_min_size=merge_min_size,
            maintenance=maintenance,
            maintenance_budget_s=maintenance_budget_s,
            storage=TenantStorageView(self.storage, tenant_id),
            cache=TenantCacheView(self.cache, tenant_id))
        self.maintenance.register(tenant_id, ix.maintenance)
        self.tenants[tenant_id] = ix
        if self._durability_cfg is not None:
            self._attach_tenant_durability(tenant_id, checkpoint=False)
        return ix

    # ------------------------------------------------------------------
    # durability (core/durability.py)
    # ------------------------------------------------------------------
    def enable_durability(self, root: Optional[str] = None, *,
                          checkpoint_every: int = 64,
                          keep_snapshots: int = 2, checkpoint: bool = True):
        """Make every tenant's index state crash-consistent: one
        per-tenant namespaced WAL + snapshot directory
        (``<root>/durability/tenant_<t>/``) under the SHARED storage root,
        so one ``recover_router`` call restores the whole deployment.
        Applies to existing tenants now and auto-attaches to tenants
        created later.  ``root`` defaults to the shared backend's disk
        root (required for memory-mode storage).  Returns the per-tenant
        :class:`~repro.core.durability.Durability` handles."""
        root = root or self.storage.root
        assert root is not None, \
            "durability needs a filesystem root: disk-backed storage or root="
        self._durability_cfg = {"root": root,
                                "checkpoint_every": checkpoint_every,
                                "keep_snapshots": keep_snapshots}
        return {t: self._attach_tenant_durability(
                    t, checkpoint=checkpoint
                    and self.tenants[t].centroids is not None)
                for t in self.tenants}

    def _attach_tenant_durability(self, tenant_id: str, *,
                                  checkpoint: bool):
        from repro.core.durability import Durability
        cfg = self._durability_cfg
        dur = Durability(cfg["root"], tenant=tenant_id,
                         cost_model=self.cost,
                         checkpoint_every=cfg["checkpoint_every"],
                         keep_snapshots=cfg["keep_snapshots"])
        # an unbuilt tenant checkpoints at build() time instead
        self.tenants[tenant_id].attach_durability(dur,
                                                  checkpoint=checkpoint)
        return dur

    def tenant(self, tenant_id: str) -> EdgeRAGIndex:
        return self.tenants[tenant_id]

    def get_chunks(self, tenant_id: str, ids: Sequence[int]) -> List[str]:
        """Per-tenant chunk-text dispatch (the serving layer's S3 context
        assembly for mixed batches)."""
        return self.tenants[tenant_id].get_chunks(ids)

    # ------------------------------------------------------------------
    # memory accounting
    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        """Device-resident bytes: every tenant's first-level centroids plus
        the ONE shared cache (counted once — it is one resident set)."""
        n = sum(ix.centroids.nbytes for ix in self.tenants.values()
                if ix.centroids is not None)
        return n + self.cache.total_bytes()

    # ------------------------------------------------------------------
    # retrieval: per-tenant probe/resolve, fused cross-tenant scoring
    # ------------------------------------------------------------------
    def _normalize_tenants(self, tenants, nq: int) -> List[str]:
        if isinstance(tenants, str):
            tenants = [tenants] * nq
        tenants = [str(t) for t in tenants]
        assert len(tenants) == nq, \
            f"{len(tenants)} tenant ids for {nq} queries"
        for t in tenants:
            assert t in self.tenants, f"unknown tenant {t!r}"
        return tenants

    def search_begin(self, query_embs: np.ndarray, k: int, nprobe: int,
                     query_chars: Optional[Sequence[int]] = None,
                     *, tenants,
                     deadlines: Optional[Sequence[Optional[float]]] = None,
                     policy: Optional[DegradationPolicy] = None,
                     prefetch: bool = False,
                     mesh=None, shard_axis: str = "data"
                     ) -> MultiTenantSearchState:
        """S1 for a mixed batch: group queries by tenant (order within a
        tenant preserved), run each tenant's probe + plan (+ optional
        storage prefetch), and merge the per-tenant plans into ONE
        ``(tenant, cid)``-keyed :class:`ResolutionPlan` whose owner order
        follows the GLOBAL batch order — so a one-tenant batch packs the
        slab in exactly the standalone order."""
        queries = np.atleast_2d(np.asarray(query_embs, np.float32))
        nq = queries.shape[0]
        tenants = self._normalize_tenants(tenants, nq)
        order: Dict[str, List[int]] = {}
        for qi, t in enumerate(tenants):
            order.setdefault(t, []).append(qi)
        states: Dict[str, BatchSearchState] = {}
        for t, gqis in order.items():
            tix = self.tenants[t]
            sub = np.ascontiguousarray(queries[gqis])
            sub_chars = (None if query_chars is None
                         else [query_chars[i] for i in gqis])
            sub_dl = (None if deadlines is None
                      else [deadlines[i] for i in gqis])
            if prefetch:
                tplan = tix.plan_batch(sub, nprobe, prefetch_storage=True,
                                       deadlines=sub_dl, policy=policy,
                                       query_chars=sub_chars)
                states[t] = tix.search_begin(sub, k, nprobe, sub_chars,
                                             plan=tplan, mesh=mesh,
                                             shard_axis=shard_axis)
            else:
                states[t] = tix.search_begin(sub, k, nprobe, sub_chars,
                                             deadlines=sub_dl, policy=policy,
                                             mesh=mesh, shard_axis=shard_axis)
        with WallTimer() as timer:
            probed_per_q: List[List[TenantKey]] = [[] for _ in range(nq)]
            lats: List[Optional[LatencyBreakdown]] = [None] * nq
            for t, gqis in order.items():
                st = states[t]
                for lqi, gqi in enumerate(gqis):
                    probed_per_q[gqi] = [(t, cid) for cid
                                         in st.plan.probed_per_q[lqi]]
                    lats[gqi] = st.lats[lqi]
            # owner insertion order = global batch order, each query's
            # probes in probe order — the standalone owner order when one
            # tenant fills the batch
            owner: Dict[TenantKey, int] = {}
            for qi in range(nq):
                for key in probed_per_q[qi]:
                    owner.setdefault(key, qi)
            tier: Dict[TenantKey, str] = {}
            generations: Dict[TenantKey, int] = {}
            content_generations: Dict[TenantKey, int] = {}
            for t, st in states.items():
                for cid in st.plan.owner:
                    key = (t, cid)
                    tier[key] = st.plan.tier[cid]
                    generations[key] = st.plan.generations[cid]
                    content_generations[key] = \
                        st.plan.content_generations[cid]
            plan = ResolutionPlan(
                probed_per_q=probed_per_q, owner=owner, tier=tier,
                storage_clusters=[], cached={}, regen_groups=[],
                generations=generations,
                content_generations=content_generations)
        return MultiTenantSearchState(
            queries=queries, k=k, plan=plan, lats=lats,
            missed=[False] * nq, tenants=tenants, order=order,
            states=states, mesh=mesh, shard_axis=shard_axis,
            wall_accum_s=timer.elapsed)

    def search_fetch(self, state: MultiTenantSearchState
                     ) -> MultiTenantSearchState:
        """S2: each tenant resolves its own sub-plan (tenant-scoped
        storage / cache / coalesced regeneration — embed calls never mix
        tenants' texts); payloads merge under ``(tenant, cid)`` keys."""
        payloads: Dict[TenantKey, SlabPayload] = {}
        for t, st in state.states.items():
            self.tenants[t].search_fetch(st)
            for lqi, gqi in enumerate(state.order[t]):
                if st.missed[lqi]:
                    state.missed[gqi] = True
            for cid, p in st.payloads.items():
                payloads[(t, cid)] = p
        state.payloads = payloads
        return state

    def search_finish(self, state: MultiTenantSearchState
                      ) -> Tuple[np.ndarray, np.ndarray,
                                 List[LatencyBreakdown]]:
        """S3: pack EVERY tenant's resolved clusters into one slab and
        score all queries in ONE ragged top-k launch per storage
        representation (the cross-tenant batching win: T tenants cost
        T probe launches but only one scoring launch).  Then each tenant's
        Alg. 3 threshold observes its own queries, scoped to its own
        cache entries."""
        assert state.payloads is not None, "search_fetch has not run"
        lats = state.lats
        nq = state.nq
        with WallTimer() as t:
            slab = self.resolver.pack_slab(state.plan, state.payloads, lats)
            owner = state.plan.owner
            resident = self.memory_bytes()
            for qi, probed in enumerate(state.plan.probed_per_q):
                for key in probed:
                    if owner[key] != qi:
                        lats[qi].l2_mem_load_s += self.cost.mem_load_latency(
                            slab.nbytes(key), resident_bytes=resident)
                        lats[qi].n_shared_hits += 1
            out_ids, out_vals, n_valid = slab_score_topk(
                slab, state.queries, state.k, state.plan.probed_per_q,
                mesh=state.mesh, shard_axis=state.shard_axis)
            # same LUT-build charge as EdgeRAGIndex.search_finish: a pq
            # segment means every query's ADC tables were built this batch
            has_pq = any(seg.kind == "pq" and seg.rows
                         for seg in slab.segments)
            for qi in range(nq):
                if has_pq:
                    lats[qi].l2_pq_lut_s += self.cost.pq_lut_latency(self.dim)
                if n_valid[qi]:
                    lats[qi].l2_search_s = self.cost.search_latency(
                        int(n_valid[qi]), self.dim)
        total_wall = (state.wall_accum_s + t.elapsed
                      + sum(st.wall_accum_s for st in state.states.values()))
        state.wall_accum_s = total_wall
        for lat in lats:
            lat.wall_s = total_wall / nq
        # Alg. 3: per query in global batch order, each against ITS
        # tenant's controller and cache scope (one tenant's affordable
        # misses must not evict another tenant's entries)
        for qi in range(nq):
            if not state.plan.probed_per_q[qi]:
                continue
            tix = self.tenants[state.tenants[qi]]
            new_thr = tix.threshold.observe(state.missed[qi],
                                            lats[qi].retrieval_s)
            if state.missed[qi]:
                tix.cache.drop_below_threshold(new_thr)
        return out_ids, out_vals, lats

    def search_batch(self, query_embs: np.ndarray, k: int, nprobe: int,
                     query_chars: Optional[Sequence[int]] = None,
                     *, tenants,
                     deadlines: Optional[Sequence[Optional[float]]] = None,
                     policy: Optional[DegradationPolicy] = None,
                     mesh=None, shard_axis: str = "data"
                     ) -> Tuple[np.ndarray, np.ndarray,
                                List[LatencyBreakdown]]:
        """Mixed-tenant batched retrieval: the three staged calls
        back-to-back.  ``tenants`` is one tenant id per query (or a single
        id for the whole batch).  Per-query (ids, scores) are bit-identical
        to routing each tenant's queries through its index separately."""
        state = self.search_begin(query_embs, k, nprobe, query_chars,
                                  tenants=tenants, deadlines=deadlines,
                                  policy=policy, mesh=mesh,
                                  shard_axis=shard_axis)
        self.search_fetch(state)
        return self.search_finish(state)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "n_tenants": len(self.tenants),
            "tenants": {t: ix.stats() for t, ix in self.tenants.items()},
            "cache": {
                "capacity_bytes": self.cache.capacity_bytes,
                "total_bytes": self.cache.total_bytes(),
                "hit_rate": self.cache.hit_rate,
                "per_tenant": {t: dict(st) for t, st
                               in self.cache.per_tenant.items()},
            },
            "storage": {
                "total_bytes": self.storage.total_bytes(),
                "budget_bytes": self.storage.budget_bytes,
                "put_rejected": self.storage.io_stats["put_rejected"],
                "per_tenant": {t: self.storage.tenant_bytes(t)
                               for t in self.tenants},
            },
            "maintenance": self.maintenance.stats(),
            "memory_bytes": self.memory_bytes(),
        }
