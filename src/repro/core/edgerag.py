"""EdgeRAG index — the paper's contribution (§4, §5).

Improves the two-level IVF index for memory-constrained serving:

  1. PRUNE second-level embeddings (they are generated at indexing time for
     clustering, then discarded) and regenerate them online at retrieval.
  2. SELECTIVE INDEX STORAGE (Alg. 1): clusters whose regeneration latency
     would exceed the SLO get their embeddings precomputed and persisted to
     storage; loads bypass the long tail of online generation.
  3. ADAPTIVE COST-AWARE CACHING (Alg. 2 + 3): regenerated embeddings are
     cached under a cost-weighted LFU policy with an adaptive minimum-
     latency admission threshold.
  4. Online INSERT / REMOVE with cluster split / merge (§5.4), made
     concurrent-safe with precomputed plans through GENERATION STAMPS and
     optionally deferred through the MaintenanceScheduler (see below).

Retrieval (Fig. 9): probe centroids → per probed cluster resolve embeddings
via storage / cache / regeneration → fused top-k → chunk ids.

Table 4 ablations map to constructor flags:
  IVF+Embed.Gen.        store_heavy=False  cache_bytes=0
  IVF+Embed.Gen.+Load   store_heavy=True   cache_bytes=0
  EdgeRAG               store_heavy=True   cache_bytes>0
Retrieval results are bit-identical across the three (and to the in-memory
IVF baseline): the paper's §6.3.1 claim, asserted in tests.

BATCHED RETRIEVAL (:meth:`EdgeRAGIndex.search_batch`): the serving fast
path for concurrent queries.  One fused centroid top-k runs over the whole
batch, the probed clusters are union-deduped across queries, and each
unique cluster is resolved exactly once per batch (storage → cache →
regenerate).  All cache-miss regenerations are coalesced into a SINGLE
``embed_fn`` call over the concatenated cluster texts, then split back per
cluster.  Per-query results are assembled from the shared resolutions in
each query's own probed order, so (ids, scores) are bit-identical to
running per-query ``search`` sequentially.

Latency attribution for shared resolutions: each unique cluster has an
OWNER — the lowest-index query in the batch that probed it.  The owner's
:class:`LatencyBreakdown` is charged the full resolution cost
(storage load / cache hit / generation, exactly the single-query formula);
every other query that probed the same cluster records a *shared hit*
(``n_shared_hits``) charged only a DRAM re-read (``l2_mem_load_s``) since
the embeddings are already resident.  The cache is consulted at most once
per unique cluster per batch (one counter bump + decay per access, as in
Alg. 2), and the Alg. 3 threshold observes once per query in batch order;
a query counts as a miss iff it owns at least one regenerated cluster.
``wall_s`` is the batch wall time amortized uniformly over the queries.
Single-query ``search`` is a thin wrapper over a batch of one — the
degenerate case reproduces the seed semantics exactly.

TIERED RESOLUTION (core/resolver.py): retrieval runs an explicit
probe → PLAN → EXECUTE → score pipeline.  :meth:`EdgeRAGIndex.plan_batch`
(or ``search_batch`` internally) builds a
:class:`~repro.core.resolver.ResolutionPlan` — the batch's unique clusters,
each one's owner query and chosen tier (storage / cache / regen), and the
coalesced regeneration groups — and the shared
:class:`~repro.core.resolver.ClusterResolver` executes it: a batched
``get_many`` storage load under the configured codec (fp32 / fp16 / int8,
``storage_codec=``), cache lookups, one ``embed_fn`` call per regen group.
A precomputed plan can be handed back to ``search_batch(plan=...)`` so the
serving engine can prefetch the plan's storage loads before prompt
assembly.

PACKED-SLAB SCORING (kernels/slab_topk + resolver.SlabLayout): the
second-level scoring step packs the batch's unique resolved clusters
exactly ONCE into contiguous slabs (one per storage representation, with
per-cluster (offset, length) extents and a parallel chunk-id slab) and
scores ALL queries in one ragged multi-query kernel launch per slab —
per-(query, row) membership and the per-query virtual concat order ride
in an int32 ``virt`` matrix whose entries double as the top-k tie-break
key, so fp32 results are bit-identical to the old per-query
concat + top-k loop while shared clusters are copied once instead of once
per probing query.  fp16/int8 storage payloads are loaded UNDECODED
(``StorageBackend.get_many_raw``) and dequantized inside the kernel's
dot-product block (per-row scales on the score tile) — no fp32 copy of
quantized storage is ever materialized.  ``search_batch(..., mesh=...)``
shards the slab itself: ONE ``sharded_slab_topk`` launch per batch per
representation (core/sharded_retrieval.py) instead of one collective per
query; ids match the unsharded path.

PLAN-STALENESS CONTRACT (core/maintenance.py): every cluster carries a
monotonically increasing ``generation``, bumped by any mutation — insert,
remove, split, merge, restore, stored-copy drop.  A ``ResolutionPlan``
snapshots each planned cluster's generation, and ``execute`` regenerates
(never scores) any cluster whose generation moved between plan and
execution — including SAME-SIZE mutations the old row-count guard missed.
``stored_generation`` tracks which generation the storage copy reflects;
stale copies are bypassed and re-persisted.  A stale plan therefore always
degrades to regeneration over the clusters' *current* membership (or to
skipping clusters that were merged away), never to wrong ids.  Code that
mutates a cluster without going through insert / remove must bump
``generation`` itself.

Maintenance runs synchronously inside insert / remove by default
(``maintenance="sync"``, the seed behavior).  With
``maintenance="deferred"`` mutations only enqueue split / merge / restore
onto ``self.maintenance`` (a MaintenanceScheduler) and return fast; the
serving layer drains the queue between steps under an edge-cost budget.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache_policy import (CostAwareLFUCache,
                                     MinLatencyThresholdController)
from repro.core.costs import EdgeCostModel, LatencyBreakdown, WallTimer
from repro.core.faults import DegradationPolicy
from repro.core.kmeans import kmeans
from repro.core.maintenance import (OP_DROP_STORE, OP_MERGE, OP_RESTORE,
                                    OP_SPLIT, MaintenanceScheduler)
from repro.core.resolver import ClusterResolver, ResolutionPlan, SlabPayload
from repro.core.storage import StorageBackend
from repro.kernels.ivf_topk.ops import topk_ip
from repro.kernels.slab_topk.ops import NOT_PROBED, slab_topk


@dataclasses.dataclass
class EdgeCluster:
    ids: np.ndarray                 # (n,) chunk ids
    char_count: int                 # total chars across chunks
    gen_latency_est: float          # profiled regeneration latency (Alg. 1)
    stored: bool = False            # embeddings persisted to storage
    active: bool = True             # tombstone after merge
    generation: int = 0             # bumped on ANY mutation (plan staleness)
    content_generation: int = 0     # bumped only when membership/content
    # moves (insert / update / remove / split / merge) — storage-tier flips
    # (restore, drop) bump ``generation`` alone.  Fetched payloads stay
    # row-aligned across tier flips, so post-fetch staleness checks (the
    # pipeline's S3 replan gate) compare THIS stamp; fetch-time tier
    # decisions keep using ``generation`` (a dropped copy can't be loaded)
    stored_generation: int = -1     # generation the storage copy reflects

    @property
    def size(self) -> int:
        return len(self.ids)

    @property
    def storage_fresh(self) -> bool:
        """The stored copy (if any) reflects the current membership."""
        return self.stored and self.stored_generation == self.generation


@dataclasses.dataclass
class BatchSearchState:
    """In-flight state of a staged batched retrieval.

    :meth:`EdgeRAGIndex.search_batch` is split into three resumable stages
    so the serving pipeline (serving/pipeline.py) can interleave other
    work between them on the modeled clock:

      ``search_begin``   S1  probe + plan (+ per-query plan-time charges)
      ``search_fetch``   S2  raw payload resolution (storage / cache /
                             coalesced regeneration, fault retries/stalls)
      ``search_finish``  S3  slab pack + multi-query top-k scoring

    Calling the three back-to-back is exactly ``search_batch`` — same
    draws, same charges, bit-identical (ids, scores).
    """
    queries: np.ndarray                      # (Q, d) float32
    k: int
    plan: ResolutionPlan
    lats: List[LatencyBreakdown]
    missed: List[bool]
    payloads: Optional[Dict[int, SlabPayload]] = None
    mesh: object = None
    shard_axis: str = "data"
    wall_accum_s: float = 0.0                # summed stage wall times

    @property
    def nq(self) -> int:
        return self.queries.shape[0]

    @property
    def centroid_total_s(self) -> float:
        """Total centroid-search edge seconds of this batch's S1 — ONE
        fused launch for a plain batch (the multi-tenant state overrides
        with one launch per tenant)."""
        return self.lats[0].centroid_search_s if self.lats else 0.0

    def shrink_deadlines(self, extra_wait_s: float):
        """Tighten every remaining per-query deadline by queue seconds that
        accrued after S1 (the serving layer's queue-wait adjustment)."""
        plan = self.plan
        if extra_wait_s > 0.0 and plan.deadlines is not None:
            plan.deadlines = [None if d is None else max(0.0, d - extra_wait_s)
                              for d in plan.deadlines]


def slab_score_topk(slab, queries: np.ndarray, k: int,
                    probed_per_q: Sequence[Sequence],
                    *, mesh=None, shard_axis: str = "data"
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The S3 scoring core: ONE ragged multi-query top-k launch per slab
    segment (at most four: fp32/fp16/int8/pq), segments merged per query
    under the virt tie-break.  Shared verbatim by ``search_finish`` and the
    multi-tenant router's fused cross-tenant scoring — each (query, row)
    pair's result depends only on that query's member rows (the virt mask
    excludes everything else), so fusing several tenants' clusters into one
    slab cannot perturb any query's (ids, scores).  PQ segments build the
    batch's ADC tables ONCE here (``pq_luts``) and score codes by in-kernel
    gather+accumulate — the sharded route row-shards the codes and
    replicates the tables.  Returns ``(out_ids (Q,k), out_vals (Q,k),
    n_valid (Q,))``.
    """
    nq = queries.shape[0]
    out_ids = np.full((nq, k), -1, np.int64)
    out_vals = np.full((nq, k), -np.inf, np.float32)
    virts, n_valid, n_valid_seg = slab.query_layout(probed_per_q)
    lane = np.arange(k)[None, :]
    cand_vals, cand_virt, cand_ids = [], [], []
    for seg in slab.segments:
        if seg.rows == 0:
            continue
        virt = virts[seg.kind]
        luts = None
        if seg.kind == "pq":
            from repro.core.pq import pq_luts
            luts = pq_luts(seg.codebook, queries)     # (Q, m, 256), once
        if mesh is not None and seg.rows >= k:
            from repro.core.sharded_retrieval import sharded_slab_topk
            vals, rows = sharded_slab_topk(
                seg.emb, queries, virt, k, mesh,
                shard_axis, scales=seg.scales, luts=luts)
        else:
            vals, rows = slab_topk(seg.emb, queries, virt, k,
                                   scales=seg.scales, luts=luts)
        vals, rows = np.asarray(vals), np.asarray(rows)
        # mask the padding lanes BEFORE the id gather and insist
        # every remaining row is in-range — the old path's np.clip
        # silently mapped any out-of-range index to the last id
        valid = lane < n_valid_seg[seg.kind][:, None]    # (Q, k)
        assert ((rows[valid] >= 0)
                & (rows[valid] < seg.rows)).all(), \
            "slab top-k returned out-of-range rows"
        rows = np.where(valid, rows, 0)
        cand_ids.append(np.where(valid, seg.ids[rows], -1))
        cand_vals.append(np.where(valid, vals, -np.inf))
        cand_virt.append(np.where(
            valid, virt[np.arange(nq)[:, None], rows],
            np.int32(NOT_PROBED)))
    if len(cand_vals) == 1:            # one representation (fp32 path)
        out_vals[:, :] = cand_vals[0]
        out_ids[:, :] = cand_ids[0]
    elif cand_vals:                    # merge segments per query under
        cv = np.concatenate(cand_vals, axis=1)   # the same total
        ct = np.concatenate(cand_virt, axis=1)   # order the kernel
        ci = np.concatenate(cand_ids, axis=1)    # selected by
        order = np.lexsort((ct, -cv), axis=1)[:, :k]
        out_vals[:, :] = np.take_along_axis(cv, order, axis=1)
        out_ids[:, :] = np.take_along_axis(ci, order, axis=1)
    return out_ids, out_vals, n_valid


class EdgeRAGIndex:
    """Two-level pruned IVF with selective storage + adaptive caching."""

    def __init__(self, dim: int, embed_fn: Callable[[Sequence[str]], np.ndarray],
                 get_chunks: Callable[[Sequence[int]], List[str]],
                 cost_model: Optional[EdgeCostModel] = None,
                 *, slo_s: float = 1.0,
                 store_heavy: bool = True,
                 cache_bytes: Optional[int] = None,
                 storage_mode: str = "memory",
                 storage_codec: str = "fp32",
                 storage_root: Optional[str] = None,
                 split_max_chars: int = 200_000,
                 merge_min_size: int = 2,
                 maintenance: str = "sync",
                 maintenance_budget_s: Optional[float] = None,
                 storage=None, cache=None):
        assert maintenance in ("sync", "deferred"), maintenance
        self.dim = dim
        self.embed_fn = embed_fn
        self.get_chunks = get_chunks
        self.cost = cost_model or EdgeCostModel()
        self.slo_s = slo_s
        self.store_heavy = store_heavy
        # ``storage`` / ``cache`` inject SHARED substrates (a TenantRouter's
        # TenantStorageView / TenantCacheView); None keeps the historical
        # owned-singleton behavior bit-for-bit
        if cache is not None:
            self.cache = cache
        else:
            if cache_bytes is None:
                cache_bytes = int(0.07 * self.cost.device_memory_bytes)  # §6.3.4
            self.cache = CostAwareLFUCache(cache_bytes)
        self.threshold = MinLatencyThresholdController()
        self.storage = storage if storage is not None else StorageBackend(
            storage_mode, root=storage_root, codec=storage_codec)
        self.resolver = ClusterResolver(self)
        self.centroids: Optional[np.ndarray] = None
        self.clusters: List[EdgeCluster] = []
        self.split_max_chars = split_max_chars
        self.merge_min_size = merge_min_size
        self.maintenance_mode = maintenance
        self.maintenance = MaintenanceScheduler(
            self, budget_s_per_step=maintenance_budget_s)
        self._chunk_chars: Dict[int, int] = {}
        self._chunk_cluster: Dict[int, int] = {}   # chunk id -> cluster id
        # durability (core/durability.py): attached handle + the dirty set
        # the next _wal_commit() turns into ONE WAL record.  Mutation
        # helpers mark the clusters they touch; the PUBLIC op (insert /
        # update / remove / retrain_pq / a drained maintenance op / a
        # resolver self-heal) commits, so one op = one record whatever
        # cascade it triggered.
        self.durability = None
        self._dirty: set = set()
        self._gone: set = set()     # chunk ids deleted since last commit

    # ------------------------------------------------------------------
    # durability (core/durability.py)
    # ------------------------------------------------------------------
    def attach_durability(self, durability, *, checkpoint: bool = True):
        """Attach a :class:`~repro.core.durability.Durability` handle: every
        finished mutation now emits one WAL record, and snapshots ride the
        maintenance queue as ``OP_CHECKPOINT`` ops.  ``checkpoint=True``
        takes the baseline snapshot now (recovery needs one to exist)."""
        self.durability = durability
        self._dirty.clear()
        self._gone.clear()
        durability.manifest = {
            cid: self.storage.payload_crc(cid)
            for cid, cl in enumerate(self.clusters)
            if cl.stored and cid in self.storage}
        if checkpoint:
            durability.checkpoint(self)
        return durability

    def _wal_commit(self, op: str) -> float:
        """Commit the accumulated dirty set as ONE WAL record carrying the
        absolute post-op state of every touched cluster; returns modeled
        fsync edge seconds (0 with no handle attached).  Blobs are always
        written BEFORE this runs, so a crash between blob and record
        orphans the blob (recovery GCs it back to pre-op) rather than ever
        leaving a hybrid."""
        dirty, gone = self._dirty, self._gone
        if self.durability is None or not (dirty or gone):
            dirty.clear()
            gone.clear()
            return 0.0
        cids = sorted(c for c in dirty if c < len(self.clusters))
        removed = sorted(gone)
        dirty.clear()
        gone.clear()
        return self.durability.log_mutation(self, op, cids, removed)

    # ------------------------------------------------------------------
    # indexing (Fig. 8 + Alg. 1)
    # ------------------------------------------------------------------
    def build(self, chunk_ids: Sequence[int], texts: Sequence[str],
              nlist: int, kmeans_iters: int = 20, seed: int = 0,
              embeddings: Optional[np.ndarray] = None):
        """Index a corpus.  ``embeddings`` may be passed if already computed
        (the paper computes them once for clustering, then prunes)."""
        chunk_ids = np.asarray(chunk_ids, np.int64)
        if embeddings is None:
            embeddings = self.embed_fn(list(texts))
        embeddings = np.ascontiguousarray(embeddings, np.float32)
        # rebuild: drop every trace of the previous corpus — stored
        # clusters, cached embeddings, the adapted Alg. 3 threshold (learned
        # from the old latency distribution), and the char table
        self.storage.clear()
        self.maintenance.clear()        # queued ops describe the old corpus
        # owned cache: a new empty instance (identical to the old
        # re-construction); shared view: clears only this tenant's entries
        self.cache = self.cache.fresh()
        self.threshold = MinLatencyThresholdController(
            self.threshold.step_s, self.threshold.alpha)
        self._chunk_chars = {int(i): len(t)
                             for i, t in zip(chunk_ids, texts)}
        if self.storage.codec == "pq":
            # codebook lifecycle: TRAIN AT BUILD on the full corpus, before
            # any Alg. 1 put encodes against it (a rebuild retrains — the
            # version bump invalidates the cleared previous-corpus blobs).
            # On a SHARED backend (TenantStorageView) the codebook is a
            # physical-medium singleton: the first tenant build trains it,
            # later tenants reuse it (retraining would invalidate their
            # neighbors' blobs — that is retrain_pq's explicit job).
            shared = hasattr(self.storage, "backend")
            if not (shared and self.storage.pq is not None):
                self.storage.train_pq(embeddings, seed=seed)
        self.centroids, assign = kmeans(embeddings, nlist,
                                        iters=kmeans_iters, seed=seed)
        self.clusters = []
        self._chunk_cluster = {}
        for c in range(self.centroids.shape[0]):
            sel = np.where(assign == c)[0]
            chars = int(sum(len(texts[j]) for j in sel))
            cl = EdgeCluster(ids=chunk_ids[sel], char_count=chars,
                             gen_latency_est=self.cost.embed_latency(chars))
            for i in cl.ids:
                self._chunk_cluster[int(i)] = len(self.clusters)
            # ---- Algorithm 1: Selective Index Storage ----
            # (a shared-budget refusal — put returns 0 — leaves the
            # cluster on the regeneration path)
            if (self.store_heavy and cl.gen_latency_est > self.slo_s
                    and self.storage.put(len(self.clusters),
                                         embeddings[sel]) > 0):
                cl.stored = True                           # heavy tail persisted
                cl.stored_generation = cl.generation
            self.clusters.append(cl)
        # second-level embeddings are now PRUNED (not retained in memory)
        if self.durability is not None:
            # a rebuild obsoletes every prior record: re-baseline with a
            # fresh manifest + snapshot (compaction drops the old WAL)
            self.attach_durability(self.durability, checkpoint=True)
        return assign

    # ------------------------------------------------------------------
    # memory accounting
    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        n = self.centroids.nbytes if self.centroids is not None else 0
        return n + self.cache.total_bytes()

    def storage_bytes(self) -> int:
        return self.storage.total_bytes()

    @property
    def nlist(self) -> int:
        return 0 if self.centroids is None else len(self.centroids)

    @property
    def ntotal(self) -> int:
        return sum(c.size for c in self.clusters if c.active)

    # ------------------------------------------------------------------
    # retrieval (Fig. 9): probe → plan → execute → score
    # ------------------------------------------------------------------
    def _probe(self, queries: np.ndarray, nprobe: int) -> List[List[int]]:
        """ONE fused centroid top-k over the batch; per query, the probed
        active non-empty clusters in probe order.

        Tombstoned (merged-away) and emptied-out clusters keep a centroid
        in the first level, so the top-k over-requests by their count and
        truncates back to ``nprobe`` after filtering — otherwise every such
        centroid that outranks a live one silently shrinks the probe set
        below ``nprobe`` (recall loss on merge-heavy indexes).  With no
        dead clusters this is exactly a ``min(nprobe, nlist)`` top-k.
        """
        n_dead = sum(not c.active or c.size == 0 for c in self.clusters)
        _, probed_all = topk_ip(self.centroids, queries,
                                min(nprobe + n_dead, self.nlist))
        probed_all = np.asarray(probed_all)
        return [[int(c) for c in probed_all[qi]
                 if c >= 0 and self.clusters[int(c)].active
                 and self.clusters[int(c)].size > 0][:nprobe]
                for qi in range(queries.shape[0])]

    def _plan_with_deadlines(self, probed_per_q: List[List[int]],
                             deadlines: Optional[Sequence[Optional[float]]],
                             policy: Optional[DegradationPolicy],
                             query_chars: Optional[Sequence[int]]
                             ) -> ResolutionPlan:
        """Plan the probe lists, applying degradation rung 1 (shrink
        effective nprobe) first when deadline budgets are present.  The
        deadlines / policy / shed counts ride on the plan so execute-time
        rungs 2-3 and ``search_batch``'s accounting see them."""
        shed: Optional[List[int]] = None
        if deadlines is not None:
            nq = len(probed_per_q)
            assert len(deadlines) == nq, \
                f"{len(deadlines)} deadlines for {nq} queries"
            policy = policy or DegradationPolicy()
            centroid_s = (self.cost.mem_load_latency(self.centroids.nbytes)
                          + self.cost.search_latency(self.nlist, self.dim))
            base = [centroid_s
                    + (self.cost.embed_latency(int(query_chars[qi]))
                       if query_chars is not None and query_chars[qi]
                       else 0.0)
                    for qi in range(nq)]
            probed_per_q, shed = policy.trim_probes(self, probed_per_q,
                                                    deadlines, base)
        plan = self.resolver.plan(probed_per_q)
        if deadlines is not None:
            plan.deadlines = list(deadlines)
            plan.policy = policy
            plan.shed_probes = shed
        return plan

    def plan_batch(self, query_embs: np.ndarray, nprobe: int, *,
                   prefetch_storage: bool = False,
                   deadlines: Optional[Sequence[Optional[float]]] = None,
                   policy: Optional[DegradationPolicy] = None,
                   query_chars: Optional[Sequence[int]] = None
                   ) -> ResolutionPlan:
        """Probe + plan without executing — the serving engine uses this to
        issue the plan's storage loads before prompt assembly.  Hand the
        plan to ``search_batch(plan=...)`` to execute it (the plan-time
        cache lookups already happened; they are not repeated).

        ``deadlines``: optional per-query retrieval budgets (edge seconds,
        None entries = no deadline); the plan applies the degradation
        ladder's rung 1 (probe trimming, ``DegradationPolicy``) now and
        carries the budgets so execution can shed further."""
        queries = np.atleast_2d(np.asarray(query_embs, np.float32))
        plan = self._plan_with_deadlines(self._probe(queries, nprobe),
                                         deadlines, policy, query_chars)
        if prefetch_storage:
            self.resolver.prefetch(plan)
        return plan

    def search_batch(self, query_embs: np.ndarray, k: int, nprobe: int,
                     query_chars: Optional[Sequence[int]] = None,
                     *, plan: Optional[ResolutionPlan] = None,
                     deadlines: Optional[Sequence[Optional[float]]] = None,
                     policy: Optional[DegradationPolicy] = None,
                     mesh=None, shard_axis: str = "data"
                     ) -> Tuple[np.ndarray, np.ndarray,
                                List[LatencyBreakdown]]:
        """Batched retrieval fast path (see module docstring).

        ``query_embs`` (Q, d); returns (ids (Q, k), scores (Q, k), one
        :class:`LatencyBreakdown` per query).  Each unique probed cluster is
        resolved once for the whole batch through the tiered
        :class:`ClusterResolver` and all cache-miss regenerations coalesce
        into a single ``embed_fn`` call; per-query (ids, scores) are
        bit-identical to a sequential per-query ``search`` loop.

        ``plan``: a precomputed :class:`ResolutionPlan` from
        :meth:`plan_batch` (same queries / nprobe) — skips re-probing and
        re-planning.  ``deadlines`` / ``policy``: per-query retrieval
        budgets and degradation ladder knobs (core/faults.py); with a
        precomputed plan, pass the deadlines to :meth:`plan_batch` instead
        (they ride on the plan) — passing them here only attaches them if
        the plan carries none (rung 1 can no longer trim a fixed plan).
        ``mesh``: row-shard the batch slab over the mesh's ``shard_axis``
        and score through ``sharded_slab_topk`` — one collective per batch
        per representation.

        Internally this is the three staged steps ``search_begin`` (S1),
        ``search_fetch`` (S2), ``search_finish`` (S3) run back-to-back —
        the serving pipeline calls them individually to overlap the stages
        of different batches on the modeled clock.
        """
        state = self.search_begin(query_embs, k, nprobe, query_chars,
                                  plan=plan, deadlines=deadlines,
                                  policy=policy, mesh=mesh,
                                  shard_axis=shard_axis)
        self.search_fetch(state)
        return self.search_finish(state)

    def search_begin(self, query_embs: np.ndarray, k: int, nprobe: int,
                     query_chars: Optional[Sequence[int]] = None,
                     *, plan: Optional[ResolutionPlan] = None,
                     deadlines: Optional[Sequence[Optional[float]]] = None,
                     policy: Optional[DegradationPolicy] = None,
                     mesh=None, shard_axis: str = "data"
                     ) -> BatchSearchState:
        """Stage S1 of the staged retrieval: probe + plan.  Charges the
        query-embed and centroid-search edge costs and accounts plan-time
        probe sheds.  Returns the :class:`BatchSearchState` the later
        stages consume."""
        queries = np.atleast_2d(np.asarray(query_embs, np.float32))
        nq = queries.shape[0]
        lats = [LatencyBreakdown() for _ in range(nq)]
        with WallTimer() as t:
            if query_chars is not None:
                assert len(query_chars) == nq, \
                    f"query_chars has {len(query_chars)} entries for {nq} queries"
                for lat, qc in zip(lats, query_chars):
                    if qc:
                        lat.embed_query_s = self.cost.embed_latency(int(qc))
            # Step 1: probe (ONE fused centroid top-k) + plan the tiers
            if plan is None:
                plan = self._plan_with_deadlines(
                    self._probe(queries, nprobe), deadlines, policy,
                    query_chars)
            elif deadlines is not None and plan.deadlines is None:
                plan.deadlines = list(deadlines)
                plan.policy = policy
            probed_per_q = plan.probed_per_q
            assert len(probed_per_q) == nq, \
                f"plan covers {len(probed_per_q)} queries, got {nq}"
            centroid_s = (self.cost.mem_load_latency(self.centroids.nbytes)
                          + self.cost.search_latency(self.nlist, self.dim))
            for qi in range(nq):
                lats[qi].n_clusters_probed = len(probed_per_q[qi])
                lats[qi].centroid_search_s = centroid_s
            if plan.shed_probes:
                # rung-1 sheds happened at plan time, before these
                # LatencyBreakdowns existed — account for them now
                for qi, n_shed in enumerate(plan.shed_probes):
                    lats[qi].degraded_clusters += n_shed
        return BatchSearchState(queries=queries, k=k, plan=plan, lats=lats,
                                missed=[False] * nq, mesh=mesh,
                                shard_axis=shard_axis,
                                wall_accum_s=t.elapsed)

    def search_fetch(self, state: BatchSearchState) -> BatchSearchState:
        """Stage S2: resolve the plan's unique clusters to RAW payloads —
        batched raw-codec storage ``get_many_raw``, cache payloads, one
        coalesced regeneration per regen group (plus any fault retries /
        stalls / degradation sheds).  Owners are charged the single-query
        tier formulas."""
        with WallTimer() as t:
            state.payloads = self.resolver.execute(
                state.plan, state.lats, state.missed, raw=True)
        state.wall_accum_s += t.elapsed
        return state

    def search_finish(self, state: BatchSearchState
                      ) -> Tuple[np.ndarray, np.ndarray,
                                 List[LatencyBreakdown]]:
        """Stage S3: pack the resolved payloads into the batch slab and
        score — ONE ragged multi-query top-k launch per storage
        representation — then run the Alg. 3 threshold observations."""
        assert state.payloads is not None, "search_fetch has not run"
        queries, k, plan, lats, missed = (state.queries, state.k, state.plan,
                                          state.lats, state.missed)
        nq = state.nq
        probed_per_q = plan.probed_per_q
        with WallTimer() as t:
            # Pack every unique cluster exactly once into the batch slab;
            # owners are charged the pack copy (and fused dequant for
            # quantized payloads) once per slab.
            slab = self.resolver.pack_slab(plan, state.payloads, lats)
            # Non-owners re-read the already-resident embeddings from DRAM
            # (resident set is invariant here: nothing mutates the cache
            # between pack_slab() and scoring, so hoist the byte count)
            owner = plan.owner
            resident = self.memory_bytes()
            for qi, probed in enumerate(probed_per_q):
                for cid in probed:
                    if owner[cid] != qi:
                        lats[qi].l2_mem_load_s += self.cost.mem_load_latency(
                            slab.nbytes(cid), resident_bytes=resident)
                        lats[qi].n_shared_hits += 1
            # Step 6: packed-slab scoring — ONE ragged multi-query launch
            # per storage representation (slab_score_topk; per-query results
            # identical to the old per-query concat + top-k loop, bitwise on
            # the fp32 tier)
            out_ids, out_vals, n_valid = slab_score_topk(
                slab, queries, k, probed_per_q,
                mesh=state.mesh, shard_axis=state.shard_axis)
            # PQ segments: every query's ADC tables are built once per
            # batch (l2_pq_lut_s) — charged INSTEAD of any dequant
            has_pq = any(seg.kind == "pq" and seg.rows
                         for seg in slab.segments)
            for qi in range(nq):
                if has_pq:
                    lats[qi].l2_pq_lut_s += self.cost.pq_lut_latency(self.dim)
                if n_valid[qi]:
                    lats[qi].l2_search_s = self.cost.search_latency(
                        int(n_valid[qi]), self.dim)
        state.wall_accum_s += t.elapsed
        for lat in lats:                       # amortized batch wall time
            lat.wall_s = state.wall_accum_s / nq
        # ---- Algorithm 3: adapt the threshold, once per query in order
        # (queries that probed nothing did no level-2 work: no observation,
        # matching the single-query early-return) ----
        for qi in range(nq):
            if not probed_per_q[qi]:
                continue
            new_thr = self.threshold.observe(missed[qi], lats[qi].retrieval_s)
            if missed[qi]:
                self.cache.drop_below_threshold(new_thr)
        return out_ids, out_vals, lats

    def search(self, query_emb: np.ndarray, k: int, nprobe: int,
               query_chars: int = 0, *,
               deadline_s: Optional[float] = None,
               policy: Optional[DegradationPolicy] = None
               ) -> Tuple[np.ndarray, np.ndarray, LatencyBreakdown]:
        """Single query — the degenerate batch of one."""
        query = np.atleast_2d(np.asarray(query_emb, np.float32))
        assert query.shape[0] == 1
        ids, vals, lats = self.search_batch(
            query, k, nprobe,
            query_chars=[query_chars] if query_chars else None,
            deadlines=None if deadline_s is None else [deadline_s],
            policy=policy)
        return ids, vals, lats[0]

    # ------------------------------------------------------------------
    # online updates (§5.4)
    # ------------------------------------------------------------------
    def insert(self, chunk_id: int, text: str,
               embedding: Optional[np.ndarray] = None) -> int:
        """Insert one chunk; returns the cluster id it LANDED in (after any
        split moved it).  In deferred mode the heavy follow-up work
        (restore / split) is queued on ``self.maintenance`` instead of
        running inline."""
        if embedding is None:
            embedding = self.embed_fn([text])[0]
        embedding = np.asarray(embedding, np.float32)
        # assignment by the same un-normalized inner product that build's
        # spherical k-means and the retrieval probe use (centroids are
        # unit-norm, so ordering is scale-invariant): normalizing here
        # rounds differently than the probe's raw IP and can flip near-ties,
        # landing a chunk in a cluster its own embedding never probes.
        # Tombstoned clusters are excluded — their buried centroids can
        # outrank every live one (see _probe), and a chunk appended to an
        # inactive cluster would be silently unretrievable.
        active_idx = np.array([j for j, c in enumerate(self.clusters)
                               if c.active], np.int64)
        _, idx = topk_ip(self.centroids[active_idx], embedding[None], 1)
        cid = int(active_idx[int(np.asarray(idx)[0, 0])])
        cl = self.clusters[cid]
        cl.ids = np.append(cl.ids, np.int64(chunk_id))
        cl.char_count += len(text)
        cl.generation += 1
        cl.content_generation += 1
        self._chunk_chars[int(chunk_id)] = len(text)
        self._chunk_cluster[int(chunk_id)] = cid
        cl.gen_latency_est = self.cost.embed_latency(cl.char_count)
        self.cache.invalidate(cid)                      # stale embeddings
        if cl.char_count > self.split_max_chars:
            # a pending split supersedes a restore: the split re-persists
            # its parts per Alg. 1 itself, so restoring first would
            # regenerate + write a copy the split immediately deletes
            ops = [(OP_SPLIT, cid)]
        elif self.store_heavy and cl.gen_latency_est > self.slo_s:
            ops = [(OP_RESTORE, cid)]                   # regenerate + persist
        else:
            ops = []
        self._dirty.add(cid)
        self._dispatch_maintenance(ops)
        self._wal_commit("insert")
        # a synchronous split may have moved the chunk to the appended slot
        return self._chunk_cluster[int(chunk_id)]

    def update(self, chunk_id: int, text: str) -> Optional[int]:
        """Re-embed one chunk IN PLACE (§5.4 online update): same id, same
        cluster, same row count — only the content moved.  Returns the
        cluster id, or None for an unknown chunk.  The cluster's generation
        bumps, so cached embeddings are invalidated and any stored copy
        goes stale (a deferred restore refreshes it; until then the
        degradation ladder may serve the old copy FLAGGED as stale — unlike
        insert/remove churn it still row-aligns with the cluster)."""
        cid = self._chunk_cluster.get(int(chunk_id))
        if cid is None:
            return None
        cl = self.clusters[cid]
        cl.char_count += len(text) - self._chunk_chars.get(int(chunk_id), 0)
        self._chunk_chars[int(chunk_id)] = len(text)
        cl.generation += 1
        cl.content_generation += 1
        cl.gen_latency_est = self.cost.embed_latency(cl.char_count)
        self.cache.invalidate(cid)                      # stale embeddings
        if cl.char_count > self.split_max_chars:
            ops = [(OP_SPLIT, cid)]                     # supersedes restore
        elif self.store_heavy and cl.gen_latency_est > self.slo_s:
            ops = [(OP_RESTORE, cid)]                   # refresh stale copy
        elif cl.stored:
            ops = [(OP_DROP_STORE, cid)]                # became cheap
        else:
            ops = []
        self._dirty.add(cid)
        self._dispatch_maintenance(ops)
        self._wal_commit("update")
        return cid

    def remove(self, chunk_id: int) -> Optional[int]:
        # O(1) lookup through the chunk->cluster map (kept consistent by
        # build / insert / remove / split / merge)
        cid = self._chunk_cluster.get(int(chunk_id))
        if cid is None:
            return None
        cl = self.clusters[cid]
        pos = np.where(cl.ids == chunk_id)[0]
        if not cl.active or len(pos) == 0:      # defensive: stale map entry
            self._chunk_cluster.pop(int(chunk_id), None)
            return None
        cl.ids = np.delete(cl.ids, pos)
        cl.char_count -= self._chunk_chars.pop(int(chunk_id), 0)
        cl.generation += 1
        cl.content_generation += 1
        del self._chunk_cluster[int(chunk_id)]
        cl.gen_latency_est = self.cost.embed_latency(cl.char_count)
        self.cache.invalidate(cid)
        ops = []
        if cl.char_count > self.split_max_chars:
            # a cluster oversized since build (build never splits) heals on
            # first touch, keeping the split bound a true invariant for
            # every mutated cluster; the split supersedes any restore/drop
            # (it re-persists its parts per Alg. 1 itself)
            ops.append((OP_SPLIT, cid))
        elif cl.stored:
            if cl.gen_latency_est <= self.slo_s:
                # cheap again: drop the stored copy entirely (deferred mode
                # finally does this "async in the paper" work off-path)
                ops.append((OP_DROP_STORE, cid))
            else:
                ops.append((OP_RESTORE, cid))
        if 0 < cl.size < self.merge_min_size:
            ops.append((OP_MERGE, cid))
        self._dirty.add(cid)
        self._gone.add(int(chunk_id))
        self._dispatch_maintenance(ops)
        self._wal_commit("remove")
        return cid

    # ---- maintenance helpers (shared by sync mode and the scheduler) ----
    def _dispatch_maintenance(self, ops):
        """Run follow-up work inline (sync mode) or queue it (deferred).
        Sync split finishes the whole cascade now; the scheduler budgets
        split follow-ups across drains instead."""
        sync_apply = {OP_RESTORE: self._restore_cluster,
                      OP_DROP_STORE: self._drop_stored,
                      OP_SPLIT: self._split_cluster,
                      OP_MERGE: self._merge_cluster}
        for kind, cid in ops:
            if self.maintenance_mode == "sync":
                sync_apply[kind](cid)
            else:
                self.maintenance.enqueue(kind, cid)

    def _regen_embeddings(self, cid: int) -> np.ndarray:
        return self.resolver.regenerate([cid])[0]

    def _restore_cluster(self, cid: int):
        embs = self._regen_embeddings(cid)
        cl = self.clusters[cid]
        cl.generation += 1              # storage state is cluster state
        if self.storage.put(cid, embs) > 0:
            cl.stored = True
            cl.stored_generation = cl.generation
        else:                           # shared storage budget refused
            cl.stored = False
            cl.stored_generation = -1
        self._dirty.add(cid)

    def _drop_stored(self, cid: int):
        """The inverse of a restore: the cluster became cheap to regenerate,
        so its storage copy is dead weight."""
        cl = self.clusters[cid]
        cl.generation += 1
        self.storage.delete(cid)
        cl.stored = False
        cl.stored_generation = -1
        self._dirty.add(cid)

    def retrain_pq(self, embeddings: np.ndarray, *, seed: int = 0):
        """Drift retrain of the PQ codebook (lifecycle: train at build,
        RETRAIN ON DRIFT).  Bumps the codebook version — every stored blob
        is now stale (its ``cbv`` pins the old version) — then routes one
        restore per stored cluster through the maintenance path (applied
        inline under ``maintenance='sync'``, queued for bubble-drain under
        ``'deferred'``): regenerate at full precision, re-encode under the
        new codebook, re-persist.  A read racing an un-restored blob is
        safe: the stale payload quarantine-drops and falls back to
        regeneration (exact results, never old-codebook reconstructions).
        """
        assert self.storage.codec == "pq", "retrain_pq requires the pq codec"
        self.storage.train_pq(embeddings, seed=seed)
        for cid, cl in enumerate(self.clusters):
            if not (cl.active and cl.stored):
                continue
            cl.generation += 1
            cl.stored_generation = -1       # stale under the new codebook
            self._dirty.add(cid)
            if self.maintenance_mode == "sync":
                self._restore_cluster(cid)
            else:
                self.maintenance.enqueue(OP_RESTORE, cid)
        self._wal_commit("retrain_pq")

    def _reconcile_storage(self, cid: int):
        """Make the Alg. 1 invariant true for one cluster: (re)store it if
        regeneration is over-SLO and the copy is missing/stale, drop the
        copy if it became cheap.  The fallback when a split that superseded
        a restore turns out to be degenerate."""
        cl = self.clusters[cid]
        if not cl.active or cl.size == 0:
            if cl.stored:
                self._drop_stored(cid)
            return
        if self.store_heavy and cl.gen_latency_est > self.slo_s:
            if not (cl.storage_fresh and cid in self.storage):
                self._restore_cluster(cid)
        elif cl.stored:
            self._drop_stored(cid)

    def _split_cluster(self, cid: int):
        """Split an oversized cluster (k-means k=2 on regenerated
        embeddings), cascading until every produced part fits
        ``split_max_chars`` (or is a single un-splittable chunk)."""
        work = [cid]
        while work:
            c = work.pop()
            produced = self._split_once(c)
            if not produced:
                # degenerate split (duplicate embeddings): the cluster
                # stays oversized, but the storage reconciliation the
                # split superseded must still happen
                self._reconcile_storage(c)
                continue
            for slot in produced:
                cl = self.clusters[slot]
                if cl.char_count > self.split_max_chars and cl.size >= 2:
                    work.append(slot)

    def _split_once(self, cid: int) -> List[int]:
        """One split level: replace ``cid`` with part 0, append part 1.
        Returns the slots written (empty if the split was degenerate)."""
        cl = self.clusters[cid]
        embs = self._regen_embeddings(cid)
        if len(embs) < 2:
            return []
        cents, assign = kmeans(embs, 2, iters=10, seed=len(self.clusters))
        texts = self.get_chunks(cl.ids.tolist())
        parts = []
        for half in (0, 1):
            sel = np.where(assign == half)[0]
            chars = int(sum(len(texts[j]) for j in sel))
            parts.append((cl.ids[sel], chars, embs[sel]))
        if any(len(p[0]) == 0 for p in parts):
            return []
        # replace cid with part 0; append part 1
        self.storage.delete(cid)
        self.cache.invalidate(cid)
        self._dirty.add(cid)
        self._dirty.add(len(self.clusters))     # the appended part's slot
        slots = []
        next_gen = cl.generation + 1    # both parts outlive any plan of cid
        for slot, (ids, chars, sub) in zip(
                (cid, len(self.clusters)), parts):
            newcl = EdgeCluster(ids=ids, char_count=chars,
                                gen_latency_est=self.cost.embed_latency(chars),
                                generation=next_gen,
                                content_generation=cl.content_generation + 1)
            if (self.store_heavy and newcl.gen_latency_est > self.slo_s
                    and self.storage.put(slot, sub) > 0):
                newcl.stored = True
                newcl.stored_generation = newcl.generation
            if slot == cid:
                self.clusters[cid] = newcl
                self.centroids[cid] = cents[0]
            else:
                self.clusters.append(newcl)
                self.centroids = np.concatenate(
                    [self.centroids, cents[1:2]])
            for i in newcl.ids:
                self._chunk_cluster[int(i)] = slot
            slots.append(slot)
        return slots

    def _merge_target(self, cid: int) -> Optional[int]:
        """The nearest active neighbor an undersized cluster would merge
        into (None if no candidate) — shared by the merge itself and the
        scheduler's cost estimate."""
        if self.nlist < 2:
            return None
        mask = np.ones(self.nlist, bool)
        mask[cid] = False
        for j, other in enumerate(self.clusters):
            if not other.active:
                mask[j] = False
        if not mask.any():
            return None
        sims = self.centroids @ self.centroids[cid]
        sims[~mask] = -np.inf
        return int(np.argmax(sims))

    def _merge_cluster(self, cid: int):
        """Merge an undersized cluster into its nearest active neighbor."""
        cl = self.clusters[cid]
        tgt = self._merge_target(cid)
        if tgt is None or cl.size == 0:
            return
        other = self.clusters[tgt]
        self._dirty.add(cid)
        self._dirty.add(tgt)
        other.ids = np.concatenate([other.ids, cl.ids])
        other.char_count += cl.char_count
        other.generation += 1
        other.content_generation += 1
        for i in cl.ids:
            self._chunk_cluster[int(i)] = tgt
        other.gen_latency_est = self.cost.embed_latency(other.char_count)
        self.cache.invalidate(tgt)
        self.cache.invalidate(cid)
        self.storage.delete(cid)
        cl.stored = False               # the copy just deleted is gone
        cl.stored_generation = -1
        # absorbing the merged chunks may push the survivor over the split
        # bound; the dispatched split then supersedes the restore (it
        # re-persists its parts itself — restoring first would regenerate
        # and write a copy the split immediately deletes)
        will_split = (other.char_count > self.split_max_chars
                      and other.size >= 2)
        if not will_split and (other.stored
                               or (self.store_heavy
                                   and other.gen_latency_est > self.slo_s)):
            self._restore_cluster(tgt)
        cl.active = False
        cl.ids = np.zeros((0,), np.int64)
        cl.char_count = 0
        cl.generation += 1              # tombstoning invalidates plans too
        cl.content_generation += 1
        self.centroids[cid] = -np.ones(self.dim) / np.sqrt(self.dim)  # bury
        if will_split:
            self._dispatch_maintenance([(OP_SPLIT, tgt)])

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        active = [c for c in self.clusters if c.active]
        n_stored_rows = sum(c.size for c in active if c.stored)
        return {
            "nlist": self.nlist,
            "active_clusters": len(active),
            "ntotal": self.ntotal,
            "stored_clusters": sum(c.stored for c in active),
            "memory_bytes": self.memory_bytes(),
            "storage_bytes": self.storage_bytes(),
            "storage_codec": self.storage.codec,
            # fp32-equivalent footprint of the stored rows — the reduction
            # denominator for quantized codecs
            "storage_fp32_bytes": n_stored_rows * self.dim * 4,
            "cache_entries": len(self.cache),
            "cache_hit_rate": self.cache.hit_rate,
            "threshold_s": self.threshold.threshold,
            "maintenance_pending": len(self.maintenance),
            "maintenance_edge_s": self.maintenance.total_edge_s,
        }
