"""EdgeRAG index — the paper's contribution (§4, §5).

Improves the two-level IVF index for memory-constrained serving:

  1. PRUNE second-level embeddings (they are generated at indexing time for
     clustering, then discarded) and regenerate them online at retrieval.
  2. SELECTIVE INDEX STORAGE (Alg. 1): clusters whose regeneration latency
     would exceed the SLO get their embeddings precomputed and persisted to
     storage; loads bypass the long tail of online generation.
  3. ADAPTIVE COST-AWARE CACHING (Alg. 2 + 3): regenerated embeddings are
     cached under a cost-weighted LFU policy with an adaptive minimum-
     latency admission threshold.
  4. Online INSERT / REMOVE with cluster split / merge (§5.4).

Retrieval (Fig. 9): probe centroids → per probed cluster resolve embeddings
via storage / cache / regeneration → fused top-k → chunk ids.

Table 4 ablations map to constructor flags:
  IVF+Embed.Gen.        store_heavy=False  cache_bytes=0
  IVF+Embed.Gen.+Load   store_heavy=True   cache_bytes=0
  EdgeRAG               store_heavy=True   cache_bytes>0
Retrieval results are bit-identical across the three (and to the in-memory
IVF baseline): the paper's §6.3.1 claim, asserted in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache_policy import (CostAwareLFUCache,
                                     MinLatencyThresholdController)
from repro.core.costs import EdgeCostModel, LatencyBreakdown, WallTimer
from repro.core.kmeans import kmeans
from repro.core.storage import StorageBackend
from repro.kernels.ivf_topk.ops import topk_ip


@dataclasses.dataclass
class EdgeCluster:
    ids: np.ndarray                 # (n,) chunk ids
    char_count: int                 # total chars across chunks
    gen_latency_est: float          # profiled regeneration latency (Alg. 1)
    stored: bool = False            # embeddings persisted to storage
    active: bool = True             # tombstone after merge

    @property
    def size(self) -> int:
        return len(self.ids)


class EdgeRAGIndex:
    """Two-level pruned IVF with selective storage + adaptive caching."""

    def __init__(self, dim: int, embed_fn: Callable[[Sequence[str]], np.ndarray],
                 get_chunks: Callable[[Sequence[int]], List[str]],
                 cost_model: Optional[EdgeCostModel] = None,
                 *, slo_s: float = 1.0,
                 store_heavy: bool = True,
                 cache_bytes: Optional[int] = None,
                 storage_mode: str = "memory",
                 split_max_chars: int = 200_000,
                 merge_min_size: int = 2):
        self.dim = dim
        self.embed_fn = embed_fn
        self.get_chunks = get_chunks
        self.cost = cost_model or EdgeCostModel()
        self.slo_s = slo_s
        self.store_heavy = store_heavy
        if cache_bytes is None:
            cache_bytes = int(0.07 * self.cost.device_memory_bytes)  # §6.3.4
        self.cache = CostAwareLFUCache(cache_bytes)
        self.threshold = MinLatencyThresholdController()
        self.storage = StorageBackend(storage_mode)
        self.centroids: Optional[np.ndarray] = None
        self.clusters: List[EdgeCluster] = []
        self.split_max_chars = split_max_chars
        self.merge_min_size = merge_min_size
        self._chunk_chars: Dict[int, int] = {}

    # ------------------------------------------------------------------
    # indexing (Fig. 8 + Alg. 1)
    # ------------------------------------------------------------------
    def build(self, chunk_ids: Sequence[int], texts: Sequence[str],
              nlist: int, kmeans_iters: int = 20, seed: int = 0,
              embeddings: Optional[np.ndarray] = None):
        """Index a corpus.  ``embeddings`` may be passed if already computed
        (the paper computes them once for clustering, then prunes)."""
        chunk_ids = np.asarray(chunk_ids, np.int64)
        if embeddings is None:
            embeddings = self.embed_fn(list(texts))
        embeddings = np.ascontiguousarray(embeddings, np.float32)
        self._chunk_chars.update(
            {int(i): len(t) for i, t in zip(chunk_ids, texts)})
        self.centroids, assign = kmeans(embeddings, nlist,
                                        iters=kmeans_iters, seed=seed)
        self.clusters = []
        for c in range(self.centroids.shape[0]):
            sel = np.where(assign == c)[0]
            chars = int(sum(len(texts[j]) for j in sel))
            cl = EdgeCluster(ids=chunk_ids[sel], char_count=chars,
                             gen_latency_est=self.cost.embed_latency(chars))
            # ---- Algorithm 1: Selective Index Storage ----
            if self.store_heavy and cl.gen_latency_est > self.slo_s:
                self.storage.put(len(self.clusters),
                                 embeddings[sel])          # persist heavy tail
                cl.stored = True
            self.clusters.append(cl)
        # second-level embeddings are now PRUNED (not retained in memory)
        return assign

    # ------------------------------------------------------------------
    # memory accounting
    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        n = self.centroids.nbytes if self.centroids is not None else 0
        return n + self.cache.total_bytes()

    def storage_bytes(self) -> int:
        return self.storage.total_bytes()

    @property
    def nlist(self) -> int:
        return 0 if self.centroids is None else len(self.centroids)

    @property
    def ntotal(self) -> int:
        return sum(c.size for c in self.clusters if c.active)

    # ------------------------------------------------------------------
    # retrieval (Fig. 9)
    # ------------------------------------------------------------------
    def _resolve_cluster(self, cid: int, lat: LatencyBreakdown
                         ) -> Tuple[np.ndarray, bool]:
        """Returns (embeddings, cache_missed)."""
        cl = self.clusters[cid]
        # Step 2-3: precomputed? load from storage
        if cl.stored and cid in self.storage:
            embs = self.storage.get(cid)
            lat.l2_storage_load_s += self.cost.storage_load_latency(embs.nbytes)
            lat.n_storage_loads += 1
            return embs, False
        # Step 4: embedding cache
        cached = self.cache.access(cid)
        if cached is not None:
            lat.l2_cache_hit_s += self.cost.mem_load_latency(
                cached.nbytes, resident_bytes=self.memory_bytes())
            lat.n_cache_hits += 1
            return cached, False
        # Step 4b: regenerate in flight
        texts = self.get_chunks(cl.ids.tolist())
        chars = sum(len(t) for t in texts)
        embs = np.ascontiguousarray(self.embed_fn(texts), np.float32)
        gen_s = self.cost.embed_latency(chars)
        lat.l2_generate_s += gen_s
        lat.n_generated += 1
        lat.chars_embedded += chars
        cl.gen_latency_est = gen_s
        self.cache.insert(cid, embs, gen_s,
                          min_latency_threshold=self.threshold.threshold)
        return embs, True

    def search(self, query_emb: np.ndarray, k: int, nprobe: int,
               query_chars: int = 0
               ) -> Tuple[np.ndarray, np.ndarray, LatencyBreakdown]:
        query = np.atleast_2d(np.asarray(query_emb, np.float32))
        assert query.shape[0] == 1
        lat = LatencyBreakdown()
        with WallTimer() as t:
            if query_chars:
                lat.embed_query_s = self.cost.embed_latency(query_chars)
            # Step 1: first-level centroid search
            _, probed = topk_ip(self.centroids, query,
                                min(nprobe, self.nlist))
            probed = [int(c) for c in np.asarray(probed)[0]
                      if c >= 0 and self.clusters[int(c)].active
                      and self.clusters[int(c)].size > 0]
            lat.n_clusters_probed = len(probed)
            lat.centroid_search_s = (
                self.cost.mem_load_latency(self.centroids.nbytes)
                + self.cost.search_latency(self.nlist, self.dim))
            # Steps 2-5: resolve each probed cluster's embeddings
            cand_embs, cand_ids, missed = [], [], False
            for cid in probed:
                embs, miss = self._resolve_cluster(cid, lat)
                missed |= miss
                cand_embs.append(embs)
                cand_ids.append(self.clusters[cid].ids)
            if not cand_embs:
                return (np.full((1, k), -1, np.int64),
                        np.full((1, k), -np.inf, np.float32), lat)
            # Step 6: second-level fused top-k
            embs = np.concatenate(cand_embs)
            idmap = np.concatenate(cand_ids)
            vals, idx = topk_ip(embs, query, k)
            vals, idx = np.asarray(vals), np.asarray(idx)
            lat.l2_search_s = self.cost.search_latency(len(embs), self.dim)
        lat.wall_s = t.elapsed
        # ---- Algorithm 3: adapt the admission threshold ----
        new_thr = self.threshold.observe(missed, lat.retrieval_s)
        if missed:
            self.cache.drop_below_threshold(new_thr)
        ids = np.where(idx >= 0, idmap[np.clip(idx, 0, len(idmap) - 1)], -1)
        return ids, vals, lat

    # ------------------------------------------------------------------
    # online updates (§5.4)
    # ------------------------------------------------------------------
    def insert(self, chunk_id: int, text: str,
               embedding: Optional[np.ndarray] = None):
        if embedding is None:
            embedding = self.embed_fn([text])[0]
        embedding = np.asarray(embedding, np.float32)
        q = embedding[None] / max(np.linalg.norm(embedding), 1e-9)
        _, idx = topk_ip(self.centroids, q, 1)
        cid = int(np.asarray(idx)[0, 0])
        cl = self.clusters[cid]
        cl.ids = np.append(cl.ids, np.int64(chunk_id))
        cl.char_count += len(text)
        self._chunk_chars[int(chunk_id)] = len(text)
        cl.gen_latency_est = self.cost.embed_latency(cl.char_count)
        self.cache.invalidate(cid)                      # stale embeddings
        if self.store_heavy and cl.gen_latency_est > self.slo_s:
            self._restore_cluster(cid)                  # regenerate + persist
        if cl.char_count > self.split_max_chars:
            self._split_cluster(cid)
        return cid

    def remove(self, chunk_id: int) -> Optional[int]:
        for cid, cl in enumerate(self.clusters):
            if not cl.active:
                continue
            pos = np.where(cl.ids == chunk_id)[0]
            if len(pos) == 0:
                continue
            cl.ids = np.delete(cl.ids, pos)
            cl.char_count -= self._chunk_chars.pop(int(chunk_id), 0)
            cl.gen_latency_est = self.cost.embed_latency(cl.char_count)
            self.cache.invalidate(cid)
            if cl.stored:
                if cl.gen_latency_est <= self.slo_s:
                    # cheap again: drop the stored copy entirely (async in
                    # the paper; synchronous here)
                    self.storage.delete(cid)
                    cl.stored = False
                else:
                    self._restore_cluster(cid)
            if 0 < cl.size < self.merge_min_size:
                self._merge_cluster(cid)
            return cid
        return None

    # ---- maintenance helpers ----
    def _regen_embeddings(self, cid: int) -> np.ndarray:
        cl = self.clusters[cid]
        texts = self.get_chunks(cl.ids.tolist())
        return np.ascontiguousarray(self.embed_fn(texts), np.float32)

    def _restore_cluster(self, cid: int):
        embs = self._regen_embeddings(cid)
        self.storage.put(cid, embs)
        self.clusters[cid].stored = True

    def _split_cluster(self, cid: int):
        """Split an oversized cluster into two (k-means k=2 on regenerated
        embeddings); the new cluster is appended to the first level."""
        cl = self.clusters[cid]
        embs = self._regen_embeddings(cid)
        if len(embs) < 2:
            return
        cents, assign = kmeans(embs, 2, iters=10, seed=len(self.clusters))
        texts = self.get_chunks(cl.ids.tolist())
        parts = []
        for half in (0, 1):
            sel = np.where(assign == half)[0]
            chars = int(sum(len(texts[j]) for j in sel))
            parts.append((cl.ids[sel], chars, embs[sel]))
        if any(len(p[0]) == 0 for p in parts):
            return
        # replace cid with part 0; append part 1
        self.storage.delete(cid)
        self.cache.invalidate(cid)
        for slot, (ids, chars, sub) in zip(
                (cid, len(self.clusters)), parts):
            newcl = EdgeCluster(ids=ids, char_count=chars,
                                gen_latency_est=self.cost.embed_latency(chars))
            if self.store_heavy and newcl.gen_latency_est > self.slo_s:
                self.storage.put(slot, sub)
                newcl.stored = True
            if slot == cid:
                self.clusters[cid] = newcl
                self.centroids[cid] = cents[0]
            else:
                self.clusters.append(newcl)
                self.centroids = np.concatenate(
                    [self.centroids, cents[1:2]])

    def _merge_cluster(self, cid: int):
        """Merge an undersized cluster into its nearest active neighbor."""
        cl = self.clusters[cid]
        if self.nlist < 2 or cl.size == 0:
            return
        mask = np.ones(self.nlist, bool)
        mask[cid] = False
        for j, other in enumerate(self.clusters):
            if not other.active:
                mask[j] = False
        if not mask.any():
            return
        sims = self.centroids @ self.centroids[cid]
        sims[~mask] = -np.inf
        tgt = int(np.argmax(sims))
        other = self.clusters[tgt]
        other.ids = np.concatenate([other.ids, cl.ids])
        other.char_count += cl.char_count
        other.gen_latency_est = self.cost.embed_latency(other.char_count)
        self.cache.invalidate(tgt)
        self.cache.invalidate(cid)
        self.storage.delete(cid)
        if other.stored or (self.store_heavy
                            and other.gen_latency_est > self.slo_s):
            self._restore_cluster(tgt)
        cl.active = False
        cl.ids = np.zeros((0,), np.int64)
        cl.char_count = 0
        self.centroids[cid] = -np.ones(self.dim) / np.sqrt(self.dim)  # bury

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        active = [c for c in self.clusters if c.active]
        return {
            "nlist": self.nlist,
            "active_clusters": len(active),
            "ntotal": self.ntotal,
            "stored_clusters": sum(c.stored for c in active),
            "memory_bytes": self.memory_bytes(),
            "storage_bytes": self.storage_bytes(),
            "cache_entries": len(self.cache),
            "cache_hit_rate": self.cache.hit_rate,
            "threshold_s": self.threshold.threshold,
        }
