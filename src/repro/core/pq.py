"""Product quantization: per-subspace codebooks for the 100M-vector tier.

The codec ladder (fp32 → fp16 → int8) bottoms out at ~4x; paper-scale
corpora (18.5 GB, §6.1 of arXiv 2412.21023) need the 8-32x regime that IVF-PQ
systems (FAISS ``IVFx,PQm``, MobileRAG) occupy.  A :class:`PQCodebook` splits
the embedding dimension into ``m`` subspaces, trains 256 Euclidean k-means
centroids per subspace (:func:`repro.core.kmeans.kmeans_euclidean`), and
represents each row as ``m`` uint8 codes — one byte per subspace.

Scoring is asymmetric (ADC): the query stays full-precision, and per-query
lookup tables ``luts[q, j, c] = <query_q[sub_j], codebook[j, c]>`` reduce a
row's inner-product score to ``m`` table lookups + adds.  LUT construction is
O(256·dim) per query and is charged by ``EdgeCostModel.pq_lut_latency``; the
gather+accumulate is charged by ``pq_gather_latency``.

Dims not divisible by ``m`` are zero-padded up to ``m·dsub``: padding
coordinates contribute exact zeros to both reconstruction and inner products,
so encode→decode→score is unaffected.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .kmeans import kmeans_euclidean

KSUB = 256           # centroids per subspace -> one uint8 code per subspace


@dataclasses.dataclass(frozen=True)
class PQCodebook:
    """Trained product quantizer: ``codebooks[j]`` holds the 256 centroids of
    subspace ``j``.  ``version`` stamps every encoded payload (member
    ``cbv``) so stale codes from a pre-retrain era are detected at read
    time."""
    codebooks: np.ndarray        # (m, KSUB, dsub) float32
    dim: int                     # original embedding dim (pre-padding)
    version: int = 0

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def nbytes(self) -> int:
        return int(self.codebooks.nbytes)


def _split(x: np.ndarray, m: int, dsub: int) -> np.ndarray:
    """(n, dim) -> (n, m, dsub), zero-padding the tail subspace."""
    n, dim = x.shape
    pad = m * dsub - dim
    if pad:
        x = np.concatenate(
            [x, np.zeros((n, pad), np.float32)], axis=1)
    return x.reshape(n, m, dsub)


def train_pq(x: np.ndarray, m: int = 8, iters: int = 12, seed: int = 0,
             version: int = 0) -> PQCodebook:
    """Train ``m`` per-subspace codebooks of :data:`KSUB` centroids each.

    ``dsub = ceil(dim / m)``; with fewer than KSUB training rows each
    subspace simply gets ``n`` centroids padded (by repetition of the
    first) up to KSUB so code values are always valid indices."""
    x = np.ascontiguousarray(x, np.float32)
    n, dim = x.shape
    if n == 0:
        raise ValueError("cannot train a PQ codebook on 0 rows")
    m = min(m, dim)
    dsub = -(-dim // m)                                 # ceil division
    sub = _split(x, m, dsub)                            # (n, m, dsub)
    books = np.zeros((m, KSUB, dsub), np.float32)
    for j in range(m):
        cent, _ = kmeans_euclidean(sub[:, j, :], KSUB, iters=iters,
                                   seed=seed + j)
        books[j, :len(cent)] = cent
        if len(cent) < KSUB:                            # n < KSUB rows
            books[j, len(cent):] = cent[0]
    return PQCodebook(codebooks=books, dim=dim, version=version)


def pq_encode(cb: PQCodebook, x: np.ndarray) -> np.ndarray:
    """(n, dim) float -> (n, m) uint8 nearest-centroid codes."""
    x = np.ascontiguousarray(x, np.float32)
    if x.shape[1] != cb.dim:
        raise ValueError(f"dim mismatch: {x.shape[1]} != {cb.dim}")
    sub = _split(x, cb.m, cb.dsub)                      # (n, m, dsub)
    codes = np.empty((x.shape[0], cb.m), np.uint8)
    for j in range(cb.m):
        b = cb.codebooks[j]                             # (KSUB, dsub)
        # ||s - b||^2 = ||s||^2 - 2 s·b + ||b||^2 ; drop the row term
        d = np.sum(b * b, axis=1)[None, :] - 2.0 * (sub[:, j, :] @ b.T)
        codes[:, j] = np.argmin(d, axis=1)
    return codes


def pq_decode(cb: PQCodebook, codes: np.ndarray) -> np.ndarray:
    """(n, m) uint8 -> (n, dim) float32 centroid reconstruction."""
    codes = np.asarray(codes)
    n = codes.shape[0]
    out = np.empty((n, cb.m * cb.dsub), np.float32)
    for j in range(cb.m):
        out[:, j * cb.dsub:(j + 1) * cb.dsub] = cb.codebooks[j][codes[:, j]]
    return out[:, :cb.dim]


def pq_luts(cb: PQCodebook, queries: np.ndarray) -> np.ndarray:
    """Per-query ADC tables: (Q, dim) -> (Q, m, KSUB) float32 with
    ``luts[q, j, c] = <queries[q][sub_j], codebooks[j, c]>`` so a row's
    asymmetric inner-product score is ``sum_j luts[q, j, codes[r, j]]``."""
    queries = np.ascontiguousarray(queries, np.float32)
    if queries.shape[1] != cb.dim:
        raise ValueError(f"dim mismatch: {queries.shape[1]} != {cb.dim}")
    qsub = _split(queries, cb.m, cb.dsub)               # (Q, m, dsub)
    # einsum over the shared subspace axis: (Q, m, dsub) x (m, KSUB, dsub)
    return np.einsum("qjd,jkd->qjk", qsub, cb.codebooks,
                     optimize=True).astype(np.float32)


def quantization_error(cb: PQCodebook, x: np.ndarray) -> np.ndarray:
    """Per-row squared reconstruction error ``||x - decode(encode(x))||^2``
    — the bound the property suite checks encode→decode against."""
    rec = pq_decode(cb, pq_encode(cb, x))
    return np.sum((np.asarray(x, np.float32) - rec) ** 2, axis=1)


def codebook_to_payload(cb: PQCodebook) -> dict:
    """Serializable dict (npz-friendly) for persisting alongside a root."""
    return {"codebooks": cb.codebooks,
            "dim": np.array([cb.dim], np.int64),
            "version": np.array([cb.version], np.int64)}


def codebook_from_payload(payload: dict) -> PQCodebook:
    return PQCodebook(
        codebooks=np.ascontiguousarray(payload["codebooks"], np.float32),
        dim=int(np.asarray(payload["dim"]).reshape(-1)[0]),
        version=int(np.asarray(payload["version"]).reshape(-1)[0]))


def subspace_split(x: np.ndarray, cb: PQCodebook) -> np.ndarray:
    """Public helper for tests: (n, dim) -> (n, m, dsub) padded view."""
    return _split(np.ascontiguousarray(x, np.float32), cb.m, cb.dsub)
