"""Generation-stamped online-maintenance subsystem (§5.4 made correct).

Two pieces make index mutation safe to interleave with (pre-planned)
retrieval:

GENERATION STAMPS.  Every :class:`~repro.core.edgerag.EdgeCluster` carries a
monotonically increasing ``generation``, bumped by *any* mutation — insert,
remove, split, merge, restore, stored-copy drop.  A
:class:`~repro.core.resolver.ResolutionPlan` snapshots the ``(cid,
generation)`` pair of every planned cluster, and
:meth:`~repro.core.resolver.ClusterResolver.execute` compares snapshots
against the live clusters: any mismatch means the payload the plan is about
to score (a prefetched storage blob, a plan-time cache hit) may describe a
membership that no longer exists, so the cluster falls back to fresh
regeneration.  Unlike the older ``len(embs) != size`` guard (kept only as
defense in depth), generations catch SAME-SIZE mutations — remove-one /
insert-one, split reassignment — that leave the row count intact but move
chunks around.  Clusters additionally track ``stored_generation``, the
generation their storage copy reflects; a stored cluster whose stamps
disagree is served by regeneration (and re-persisted) instead of loading the
stale blob.

DEFERRED MAINTENANCE.  The seed executed split / merge / restore
synchronously inside ``insert`` / ``remove`` ("async in the paper;
synchronous here").  :class:`MaintenanceScheduler` turns that work into a
queue of :class:`MaintenanceOp`\\ s: mutations enqueue and return fast, and
the queue drains *between* serving steps under a per-step edge-cost budget
(costs modeled through :class:`~repro.core.costs.EdgeCostModel`).  Every op
is RE-VALIDATED against the cluster's current state at drain time — a queued
split whose cluster has since shrunk is skipped, a queued restore whose
cluster became cheap turns into a stored-copy drop — so the queue converges
to the Alg. 1 invariant (stored ⇔ regeneration cost over SLO) regardless of
how mutations interleaved.  Deferral never affects correctness: an
un-restored cluster resolves through regeneration, an un-split cluster is
merely oversized, an un-merged cluster merely small.  ``drain(None)`` (no
budget) runs the queue to quiescence, after which the synchronous-mode
invariants hold exactly.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

OP_RESTORE = "restore"        # (re)generate + persist the storage copy
OP_DROP_STORE = "drop_store"  # cluster became cheap: delete the stored copy
OP_SPLIT = "split"            # one k=2 split level (follow-ups re-enqueue)
OP_MERGE = "merge"            # fold an undersized cluster into its neighbor
OP_CHECKPOINT = "checkpoint"  # durability snapshot + WAL compaction
CHECKPOINT_CID = -1           # checkpoints are whole-index, not per-cluster


@dataclasses.dataclass
class MaintenanceOp:
    kind: str
    cid: int
    generation: int     # cluster generation when enqueued (telemetry)


@dataclasses.dataclass
class MaintenanceReport:
    """What one :meth:`MaintenanceScheduler.drain` call did."""
    executed: List[Tuple[str, int]] = dataclasses.field(default_factory=list)
    skipped: List[Tuple[str, int]] = dataclasses.field(default_factory=list)
    failed: List[Tuple[str, int]] = dataclasses.field(default_factory=list)
    # ^ ops that raised this drain (re-queued, or quarantined on the Nth)
    quarantined: List[Tuple[str, int]] = \
        dataclasses.field(default_factory=list)
    edge_s: float = 0.0          # modeled edge seconds spent this drain
    remaining: int = 0           # ops still queued when the budget ran out

    @property
    def n_executed(self) -> int:
        return len(self.executed)


class MaintenanceScheduler:
    """Deferred split / merge / restore queue for an ``EdgeRAGIndex``.

    ``budget_s_per_step`` is the default edge-second budget of one
    :meth:`drain` call (None = run to quiescence).  A drain always executes
    at least one runnable op so the queue cannot stall behind a single op
    larger than the budget.  The queue is keyed by ``(kind, cid)``:
    re-enqueueing an op refreshes its stamp instead of duplicating it.
    """

    def __init__(self, index, budget_s_per_step: Optional[float] = None,
                 max_op_failures: int = 3):
        self.index = index
        self.budget_s_per_step = budget_s_per_step
        self.max_op_failures = max_op_failures
        self._queue: "OrderedDict[Tuple[str, int], MaintenanceOp]" = \
            OrderedDict()
        self._failures: Dict[Tuple[str, int], int] = {}
        self.quarantined: "OrderedDict[Tuple[str, int], str]" = OrderedDict()
        # ^ (kind, cid) -> last error; these ops stopped retrying
        self.total_edge_s = 0.0
        self.n_executed = 0
        self.n_skipped = 0
        self.n_failures = 0          # individual op failures (raises) seen

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------
    def enqueue(self, kind: str, cid: int):
        key = (kind, cid)
        # a fresh enqueue is new evidence the op is wanted: lift any
        # quarantine and give it a clean failure budget
        self.quarantined.pop(key, None)
        self._failures.pop(key, None)
        self._queue.pop(key, None)      # refresh: move to the back
        self._queue[key] = MaintenanceOp(
            kind, cid,
            0 if cid < 0 else self.index.clusters[cid].generation)

    def clear(self):
        """Drop every queued op (index rebuilds)."""
        self._queue.clear()
        self._failures.clear()
        self.quarantined.clear()

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> List[MaintenanceOp]:
        return list(self._queue.values())

    # ------------------------------------------------------------------
    # cost model
    # ------------------------------------------------------------------
    def estimate_cost_s(self, kind: str, cid: int) -> float:
        """Modeled edge seconds of one op.  Regeneration dominates restore /
        split / merge; storage writes are charged at the sequential-read
        bandwidth (the cost model has no separate write channel); a split
        adds ~10 Lloyd iterations of 2-means over the cluster."""
        ix = self.index
        if kind == OP_CHECKPOINT:
            return ix.durability.checkpoint_cost_s(ix)
        cl = ix.clusters[cid]
        cost = ix.cost
        put_s = cost.storage_load_latency(cl.size * ix.dim * 4)
        if kind == OP_DROP_STORE:
            return cost.storage_seek_s
        if kind == OP_RESTORE:
            return cost.embed_latency(cl.char_count) + put_s
        if kind == OP_SPLIT:
            kmeans_s = 10 * 2 * cost.search_latency(cl.size, ix.dim)
            return cost.embed_latency(cl.char_count) + kmeans_s + put_s
        if kind == OP_MERGE:
            # when the merge triggers a restore it regenerates the MERGED
            # text — the surviving neighbor's chars dominate, so bill them
            base = cost.search_latency(ix.nlist, ix.dim)
            tgt = ix._merge_target(cid)
            if tgt is None:
                return base
            other = ix.clusters[tgt]
            merged_chars = cl.char_count + other.char_count
            if other.stored or (ix.store_heavy
                                and cost.embed_latency(merged_chars)
                                > ix.slo_s):
                base += (cost.embed_latency(merged_chars)
                         + cost.storage_load_latency(
                             (cl.size + other.size) * ix.dim * 4))
            return base
        raise ValueError(f"unknown maintenance op kind: {kind}")

    # ------------------------------------------------------------------
    # drain
    # ------------------------------------------------------------------
    def _revalidate(self, op: MaintenanceOp) -> Optional[str]:
        """The op kind the cluster's CURRENT state calls for (None = the op
        is no longer needed).  restore / drop_store reconcile to whichever
        direction Alg. 1 wants now, whatever was queued — and so does a
        split whose cluster shrank back under the bound (a split supersedes
        the restore at enqueue time, so the storage reconciliation it
        absorbed must not vanish with it)."""
        ix = self.index
        if op.kind == OP_CHECKPOINT:
            # still wanted iff a durability handle is attached and records
            # accumulated since the last snapshot (another drain may have
            # checkpointed already — then this one is free to skip)
            if (ix.durability is not None
                    and ix.durability.records_since_snapshot > 0):
                return OP_CHECKPOINT
            return None
        cl = ix.clusters[op.cid]
        if op.kind == OP_MERGE:
            if (cl.active and 0 < cl.size < ix.merge_min_size
                    and ix.nlist >= 2):
                return OP_MERGE
            return None
        oversized = (cl.active and cl.size >= 2
                     and cl.char_count > ix.split_max_chars)
        if op.kind == OP_SPLIT and oversized:
            return OP_SPLIT
        # restore / drop_store — or a split no longer needed: reconcile
        # the storage copy with Alg. 1
        if oversized:
            # an oversized cluster always has a split queued (any mutation
            # that saw it oversized enqueued one), and the split
            # re-persists its parts itself — restoring first would be
            # thrown away
            return None
        want_stored = (cl.active and cl.size > 0 and ix.store_heavy
                       and cl.gen_latency_est > ix.slo_s)
        if want_stored:
            fresh = (cl.stored and cl.stored_generation == cl.generation
                     and op.cid in ix.storage)
            return None if fresh else OP_RESTORE
        return OP_DROP_STORE if cl.stored else None

    def _apply(self, kind: str, cid: int) -> float:
        """Run one op; returns EXTRA edge seconds beyond the estimate —
        the durability WAL fsync the op commits (zero with no handle)."""
        ix = self.index
        if kind == OP_CHECKPOINT:
            return ix.durability.checkpoint(ix) \
                - self.estimate_cost_s(kind, cid)
        if kind == OP_RESTORE:
            ix._restore_cluster(cid)
        elif kind == OP_DROP_STORE:
            ix._drop_stored(cid)
        elif kind == OP_SPLIT:
            produced = ix._split_once(cid)
            if not produced:
                # degenerate split: still reconcile the storage copy the
                # split superseded at enqueue time
                ix._reconcile_storage(cid)
            for slot in produced:
                cl = ix.clusters[slot]
                if cl.char_count > ix.split_max_chars and cl.size >= 2:
                    self.enqueue(OP_SPLIT, slot)    # budgeted follow-up
        elif kind == OP_MERGE:
            ix._merge_cluster(cid)
        # commit the op's dirty set as one WAL record (no-op without a
        # durability handle; getattr keeps bare index stubs drainable)
        commit = getattr(ix, "_wal_commit", None)
        return 0.0 if commit is None else commit(kind)

    def drain(self, budget_s: Optional[float] = None,
              strict: bool = False,
              max_ops: Optional[int] = None) -> MaintenanceReport:
        """Run queued ops until the queue is empty or the budget is spent.

        ``budget_s`` overrides ``budget_s_per_step``; None on both means run
        to quiescence.  Skipped (re-validated-away) ops are free.

        By default a drain always executes at least one runnable op, so a
        single op larger than the budget cannot stall the queue forever.
        ``strict=True`` inverts that: no op whose estimate overruns the
        remaining budget runs (FIFO order — the drain stops at the first
        unaffordable op).  Strict drains model maintenance that must fit an
        idle window exactly (e.g. the gap before the next known arrival);
        oversized ops wait for a deeper idle period or an unbudgeted drain.

        ``max_ops`` caps EXECUTED ops this call (skips are still free):
        :class:`FairShareMaintenance` steps tenants one op at a time with
        ``max_ops=1``.
        """
        if budget_s is None:
            budget_s = self.budget_s_per_step
        report = MaintenanceReport()
        failed_this_drain: set = set()
        while self._queue:
            if max_ops is not None and len(report.executed) >= max_ops:
                break
            key, op = next(iter(self._queue.items()))
            if key in failed_this_drain:
                break   # only ops that already raised this drain remain
            try:
                kind = self._revalidate(op)
                est = (0.0 if kind is None
                       else self.estimate_cost_s(kind, op.cid))
            except Exception as e:      # noqa: BLE001 — isolate the op
                self._record_failure(key, op, e, report, failed_this_drain)
                continue
            if kind is None:
                del self._queue[key]
                report.skipped.append((op.kind, op.cid))
                self.n_skipped += 1
                continue
            if (budget_s is not None and (strict or report.executed)
                    and report.edge_s + est > budget_s):
                break                      # budget spent (≥1 op ran unless strict)
            del self._queue[key]
            try:
                extra_s = self._apply(kind, op.cid)
            except Exception as e:      # noqa: BLE001 — isolate the op
                self._record_failure(key, op, e, report, failed_this_drain)
                continue
            report.executed.append((kind, op.cid))
            report.edge_s += est + extra_s
            self.n_executed += 1
        report.remaining = len(self._queue)
        self.total_edge_s += report.edge_s
        return report

    def _record_failure(self, key: Tuple[str, int], op: MaintenanceOp,
                        err: Exception, report: MaintenanceReport,
                        failed_this_drain: set):
        """One op raised: the queue must keep draining.  The op goes to the
        BACK for another try on a later drain, and after
        ``max_op_failures`` raises it is quarantined (kept out of the
        queue, last error recorded) — a poison op can wedge neither this
        drain nor the scheduler.  A fresh :meth:`enqueue` of the same
        (kind, cid) lifts the quarantine."""
        self.n_failures += 1
        report.failed.append(key)
        failed_this_drain.add(key)
        self._queue.pop(key, None)
        n = self._failures.get(key, 0) + 1
        self._failures[key] = n
        if n >= self.max_op_failures:
            self.quarantined[key] = f"{type(err).__name__}: {err}"
            self._failures.pop(key, None)
            report.quarantined.append(key)
        else:
            self._queue[key] = op

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {
            "pending": len(self._queue),
            "executed": self.n_executed,
            "skipped": self.n_skipped,
            "failures": self.n_failures,
            "quarantined": len(self.quarantined),
            "total_edge_s": self.total_edge_s,
        }


class FairShareMaintenance:
    """Round-robin multiplexer over per-tenant :class:`MaintenanceScheduler`s.

    The shared device has ONE maintenance budget per idle window; with a
    plain FIFO a churn-heavy tenant would starve everyone else's restores.
    This drains tenants in round-robin order, one executed op per turn
    (``max_ops=1``), with the rotation cursor persisting ACROSS drains so a
    window that only fits one op still rotates fairly over time.  The
    effective queue is keyed ``(tenant, kind, cid)``: each tenant's
    scheduler keeps its own ``(kind, cid)`` keys and this class supplies
    the tenant axis — report entries come back as
    ``(kind, (tenant, cid))``.

    Interface-compatible with a single :class:`MaintenanceScheduler` where
    the serving layer is concerned (``__len__`` / ``drain`` / ``clear`` /
    ``pending`` / ``total_edge_s`` / ``stats``), so
    :class:`~repro.serving.engine.RAGEngine` and
    :class:`~repro.serving.pipeline.StagedPipeline` drain a router's
    maintenance exactly as they drain an index's.
    """

    def __init__(self):
        self._scheds: "OrderedDict[str, MaintenanceScheduler]" = OrderedDict()
        self._rr = 0                    # rotation cursor, persists
        self.total_edge_s = 0.0
        self.n_executed = 0
        self.per_tenant_edge_s: Dict[str, float] = {}

    def register(self, tenant: str, sched: MaintenanceScheduler):
        assert tenant not in self._scheds, f"tenant {tenant!r} registered"
        self._scheds[tenant] = sched
        self.per_tenant_edge_s.setdefault(tenant, 0.0)

    def __len__(self) -> int:
        return sum(len(s) for s in self._scheds.values())

    @property
    def pending(self) -> List[Tuple[str, MaintenanceOp]]:
        return [(t, op) for t, s in self._scheds.items()
                for op in s.pending]

    @property
    def quarantined(self) -> Dict[Tuple[str, str, int], str]:
        return {(t, k, c): err for t, s in self._scheds.items()
                for (k, c), err in s.quarantined.items()}

    def clear(self):
        for s in self._scheds.values():
            s.clear()

    def drain(self, budget_s: Optional[float] = None,
              strict: bool = False) -> MaintenanceReport:
        """One fair-share pass: rotate tenants, one executed op per turn,
        until every queue is empty / unaffordable or the budget is spent.
        Non-strict drains keep the single-scheduler guarantee — the FIRST
        op may overrun the budget so one oversized op cannot stall the
        whole substrate — after which the budget binds strictly."""
        report = MaintenanceReport()
        scheds = list(self._scheds.items())
        if not scheds:
            return report
        n = len(scheds)
        stalled = 0             # consecutive turns with no queue progress
        while stalled < n:
            # budget check precedes taking the turn: a tenant skipped only
            # because the budget ran out keeps its slot for the next drain
            remaining = None if budget_s is None else budget_s - report.edge_s
            if (remaining is not None and remaining <= 0
                    and (strict or report.executed)):
                break
            tenant, sched = scheds[self._rr % n]
            self._rr += 1
            if not len(sched):
                stalled += 1
                continue
            rep = sched.drain(remaining,
                              strict=strict or bool(report.executed),
                              max_ops=1)
            report.executed += [(k, (tenant, c)) for k, c in rep.executed]
            report.skipped += [(k, (tenant, c)) for k, c in rep.skipped]
            report.failed += [(k, (tenant, c)) for k, c in rep.failed]
            report.quarantined += [(k, (tenant, c))
                                   for k, c in rep.quarantined]
            report.edge_s += rep.edge_s
            self.per_tenant_edge_s[tenant] = (
                self.per_tenant_edge_s.get(tenant, 0.0) + rep.edge_s)
            stalled = 0 if (rep.executed or rep.skipped) else stalled + 1
        report.remaining = len(self)
        self.total_edge_s += report.edge_s
        self.n_executed += report.n_executed
        return report

    def stats(self) -> Dict[str, Dict[str, float]]:
        out = {t: s.stats() for t, s in self._scheds.items()}
        for t in out:
            out[t]["fair_share_edge_s"] = self.per_tenant_edge_s.get(t, 0.0)
        return out
