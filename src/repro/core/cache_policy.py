"""EdgeRAG's caching policy — faithful implementations of the paper's
Algorithm 2 (Cost-aware Least-Frequently-Used replacement) and Algorithm 3
(adaptive Minimum Latency Caching Threshold).

Algorithm 2 as printed contains an obvious typo (``minCost``/``maxCost``
mixed up inside the eviction scan); we implement the stated intent: evict
the cached cluster with the MINIMUM ``genLatency × counter`` weight — cheap
to regenerate and rarely used goes first.  After every access all counters
decay by ``decay_factor`` so stale frequency evidence ages out.

Algorithm 3: the threshold starts at 0 (cache everything).  On a cache miss
whose overall retrieval latency beat the moving average, the threshold is
RAISED (the miss was affordable — stop caching cheap clusters); on a cache
hit it is LOWERED (hits are valuable — admit more).  Clusters whose
generation latency falls below the threshold are neither admitted nor kept.

MULTI-TENANCY: keys may be ints (single-tenant, unchanged) or
``(tenant, cid)`` tuples on a SHARED cache.  Eviction stays one global
argmin over ``gen_latency x counter`` — tenants compete for the one byte
budget exactly as the paper's single-tenant policy competes across
clusters — while ``per_tenant`` tracks each tenant's bytes / entries /
hits / misses / evictions so fairness is observable.
:class:`TenantCacheView` gives one tenant an int-keyed facade (its Alg. 3
``drop_below_threshold`` is scoped to its own entries; other tenants'
thresholds are none of its business).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

_ANY_TENANT = object()      # sentinel: drop_below_threshold over all tenants


def tenant_of(key) -> Optional[str]:
    """Tenant component of a cache/storage key (``None`` for bare ints)."""
    return key[0] if isinstance(key, tuple) else None


@dataclasses.dataclass
class CacheEntry:
    embeddings: np.ndarray
    gen_latency: float
    counter: float = 1.0

    @property
    def nbytes(self) -> int:
        return self.embeddings.nbytes


class CostAwareLFUCache:
    """Algorithm 2. Capacity in bytes (the paper reports ~7% of system mem).

    PERF NOTE — lazy decay + running byte total: the paper's "after every
    access all counters decay by ``decay_factor``" is implemented WITHOUT
    walking every entry per access.  Entries store counters in a scaled
    basis: the effective counter is ``entry.counter * _decay_mult``, and a
    global decay is one multiply of ``_decay_mult`` (a counter bump adds
    ``1 / _decay_mult`` in the scaled basis).  Eviction order is unchanged —
    argmin of ``gen_latency * counter`` is invariant under the common
    positive factor — and ``_decay_mult`` is folded back into the entries
    whenever it underflows toward the f64 floor, so the basis never loses
    precision.  ``total_bytes`` is likewise a maintained running total
    instead of a full scan on every insert.  Hit/miss/eviction semantics
    are identical to the eager implementation (covered by the existing
    tests plus the equivalence test in tests/test_slab_scoring.py).
    """

    _RENORM_BELOW = 1e-150      # fold the global multiplier back into
    #                             entries long before f64 underflow

    def __init__(self, capacity_bytes: int, decay_factor: float = 0.99):
        self.capacity_bytes = capacity_bytes
        self.decay_factor = decay_factor
        self._entries: Dict[object, CacheEntry] = {}
        self._decay_mult = 1.0          # global lazy-decay multiplier
        self._total_bytes = 0           # running byte total
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # per-tenant accounting (module docstring); the None tenant is the
        # bare-int single-tenant key space
        self.per_tenant: Dict[Optional[str], Dict[str, int]] = {}

    def _tstats(self, tenant: Optional[str]) -> Dict[str, int]:
        st = self.per_tenant.get(tenant)
        if st is None:
            st = self.per_tenant[tenant] = {
                "bytes": 0, "entries": 0, "hits": 0, "misses": 0,
                "evictions": 0}
        return st

    def fresh(self) -> "CostAwareLFUCache":
        """A brand-new empty cache with this one's configuration (index
        rebuilds swap to it; the shared-view override clears in place)."""
        return CostAwareLFUCache(self.capacity_bytes, self.decay_factor)

    # ---- Alg. 2 ----
    def access(self, cluster_id) -> Optional[np.ndarray]:
        """Lookup; bumps the counter on hit, decays all counters (O(1))."""
        entry = self._entries.get(cluster_id)
        st = self._tstats(tenant_of(cluster_id))
        if entry is not None:
            entry.counter += 1.0 / self._decay_mult     # effective += 1
            self.hits += 1
            st["hits"] += 1
            out = entry.embeddings
        else:
            self.misses += 1
            st["misses"] += 1
            out = None
        self._decay()
        return out

    def insert(self, cluster_id, embeddings: np.ndarray,
               gen_latency: float, min_latency_threshold: float = 0.0):
        """Insert after a miss+regeneration, honoring the Alg. 3 threshold."""
        if gen_latency < min_latency_threshold:
            return  # not worth caching — cheap to regenerate (Alg. 3)
        nbytes = embeddings.nbytes
        if nbytes > self.capacity_bytes:
            return
        # NOTE: when re-inserting a key that is still cached, the eviction
        # loop runs with the old entry's bytes still counted (and the old
        # entry itself is a legal victim) — exactly the eager original
        while self._total_bytes + nbytes > self.capacity_bytes:
            if not self._evict_one():
                return
        old = self._entries.get(cluster_id)
        st = self._tstats(tenant_of(cluster_id))
        if old is not None:             # replaced, not evicted
            self._total_bytes -= old.nbytes
            st["bytes"] -= old.nbytes
            st["entries"] -= 1
        entry = CacheEntry(
            embeddings=np.ascontiguousarray(embeddings, np.float32),
            gen_latency=float(gen_latency),
            counter=1.0 / self._decay_mult)             # effective 1.0
        self._entries[cluster_id] = entry
        # the running total tracks the STORED (f32) entry, like the eager
        # scan did — the admit/evict decisions above use the caller's
        # nbytes, also like the eager code
        self._total_bytes += entry.nbytes
        st["bytes"] += entry.nbytes
        st["entries"] += 1

    def _drop_entry(self, cluster_id, *, evicted: bool):
        entry = self._entries.pop(cluster_id)
        self._total_bytes -= entry.nbytes
        st = self._tstats(tenant_of(cluster_id))
        st["bytes"] -= entry.nbytes
        st["entries"] -= 1
        if evicted:
            self.evictions += 1
            st["evictions"] += 1

    def _evict_one(self) -> bool:
        if not self._entries:
            return False
        evict_id = min(self._entries,
                       key=lambda i: (self._entries[i].gen_latency
                                      * self._entries[i].counter))
        self._drop_entry(evict_id, evicted=True)
        return True

    def _decay(self):
        self._decay_mult *= self.decay_factor
        if self._decay_mult < self._RENORM_BELOW:
            for e in self._entries.values():            # rare: amortized O(1)
                e.counter *= self._decay_mult
            self._decay_mult = 1.0

    # ---- maintenance used by Alg. 3's "evicts and prevents caching" ----
    def drop_below_threshold(self, threshold: float, tenant=_ANY_TENANT):
        """Evict entries whose gen latency is under ``threshold``; pass
        ``tenant=`` to scope the sweep to one tenant's entries (each
        tenant's Alg. 3 controller governs only its own clusters)."""
        for cid in [c for c, e in self._entries.items()
                    if e.gen_latency < threshold
                    and (tenant is _ANY_TENANT or tenant_of(c) == tenant)]:
            self._drop_entry(cid, evicted=True)

    def invalidate(self, cluster_id):
        if cluster_id in self._entries:
            self._drop_entry(cluster_id, evicted=False)

    def invalidate_tenant(self, tenant: Optional[str]) -> int:
        """Drop every entry belonging to ``tenant``; returns bytes freed."""
        freed = 0
        for cid in [c for c in self._entries if tenant_of(c) == tenant]:
            freed += self._entries[cid].nbytes
            self._drop_entry(cid, evicted=False)
        return freed

    def total_bytes(self) -> int:
        return self._total_bytes

    def tenant_bytes(self, tenant: Optional[str]) -> int:
        st = self.per_tenant.get(tenant)
        return st["bytes"] if st else 0

    def tenant_entries(self, tenant: Optional[str]) -> int:
        st = self.per_tenant.get(tenant)
        return st["entries"] if st else 0

    def __contains__(self, cluster_id) -> bool:
        return cluster_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class TenantCacheView:
    """One tenant's int-keyed facade over a SHARED :class:`CostAwareLFUCache`.

    Key mapping mirrors :class:`~repro.core.storage.TenantStorageView`:
    ``cid -> (tenant, cid)``.  ``total_bytes`` is the SHARED resident
    total — on one device the cache occupies one budget, and the cost
    model's resident-set pressure must see all tenants (this also keeps a
    one-tenant router's ``memory_bytes`` identical to a standalone
    index).  ``tenant_bytes`` / ``hits`` / ``misses`` / ``hit_rate`` /
    ``__len__`` are scoped to this tenant, as is ``drop_below_threshold``
    (per-tenant Alg. 3).  ``fresh`` clears only this tenant's entries."""

    def __init__(self, shared: CostAwareLFUCache, tenant: str):
        self.shared = shared
        self.tenant = str(tenant)

    def _k(self, cid: int) -> Tuple[str, int]:
        return (self.tenant, int(cid))

    @property
    def capacity_bytes(self) -> int:
        return self.shared.capacity_bytes

    @property
    def decay_factor(self) -> float:
        return self.shared.decay_factor

    def fresh(self) -> "TenantCacheView":
        self.shared.invalidate_tenant(self.tenant)
        return self

    def access(self, cid: int) -> Optional[np.ndarray]:
        return self.shared.access(self._k(cid))

    def insert(self, cid: int, embeddings: np.ndarray, gen_latency: float,
               min_latency_threshold: float = 0.0):
        self.shared.insert(self._k(cid), embeddings, gen_latency,
                           min_latency_threshold)

    def invalidate(self, cid: int):
        self.shared.invalidate(self._k(cid))

    def drop_below_threshold(self, threshold: float):
        self.shared.drop_below_threshold(threshold, tenant=self.tenant)

    def total_bytes(self) -> int:
        return self.shared.total_bytes()

    def tenant_bytes(self) -> int:
        return self.shared.tenant_bytes(self.tenant)

    def __contains__(self, cid: int) -> bool:
        return self._k(cid) in self.shared

    def __len__(self) -> int:
        return self.shared.tenant_entries(self.tenant)

    @property
    def hits(self) -> int:
        st = self.shared.per_tenant.get(self.tenant)
        return st["hits"] if st else 0

    @property
    def misses(self) -> int:
        st = self.shared.per_tenant.get(self.tenant)
        return st["misses"] if st else 0

    @property
    def evictions(self) -> int:
        st = self.shared.per_tenant.get(self.tenant)
        return st["evictions"] if st else 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class MinLatencyThresholdController:
    """Algorithm 3.  ``step_s`` is the +-/-- increment in seconds."""

    def __init__(self, step_s: float = 0.010, ema_alpha: float = 0.1):
        self.threshold = 0.0
        self.step_s = step_s
        self.alpha = ema_alpha
        self.moving_avg_latency = 0.0
        self._initialized = False

    def observe(self, cache_miss: bool, last_latency: float) -> float:
        if not self._initialized:
            self.moving_avg_latency = last_latency
            self._initialized = True
        if cache_miss:
            if last_latency < self.moving_avg_latency:
                self.threshold += self.step_s
        else:
            self.threshold = max(0.0, self.threshold - self.step_s)
        self.moving_avg_latency = ((1 - self.alpha) * self.moving_avg_latency
                                   + self.alpha * last_latency)
        return self.threshold
