"""EdgeRAG's caching policy — faithful implementations of the paper's
Algorithm 2 (Cost-aware Least-Frequently-Used replacement) and Algorithm 3
(adaptive Minimum Latency Caching Threshold).

Algorithm 2 as printed contains an obvious typo (``minCost``/``maxCost``
mixed up inside the eviction scan); we implement the stated intent: evict
the cached cluster with the MINIMUM ``genLatency × counter`` weight — cheap
to regenerate and rarely used goes first.  After every access all counters
decay by ``decay_factor`` so stale frequency evidence ages out.

Algorithm 3: the threshold starts at 0 (cache everything).  On a cache miss
whose overall retrieval latency beat the moving average, the threshold is
RAISED (the miss was affordable — stop caching cheap clusters); on a cache
hit it is LOWERED (hits are valuable — admit more).  Clusters whose
generation latency falls below the threshold are neither admitted nor kept.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CacheEntry:
    embeddings: np.ndarray
    gen_latency: float
    counter: float = 1.0

    @property
    def nbytes(self) -> int:
        return self.embeddings.nbytes


class CostAwareLFUCache:
    """Algorithm 2. Capacity in bytes (the paper reports ~7% of system mem).

    PERF NOTE — lazy decay + running byte total: the paper's "after every
    access all counters decay by ``decay_factor``" is implemented WITHOUT
    walking every entry per access.  Entries store counters in a scaled
    basis: the effective counter is ``entry.counter * _decay_mult``, and a
    global decay is one multiply of ``_decay_mult`` (a counter bump adds
    ``1 / _decay_mult`` in the scaled basis).  Eviction order is unchanged —
    argmin of ``gen_latency * counter`` is invariant under the common
    positive factor — and ``_decay_mult`` is folded back into the entries
    whenever it underflows toward the f64 floor, so the basis never loses
    precision.  ``total_bytes`` is likewise a maintained running total
    instead of a full scan on every insert.  Hit/miss/eviction semantics
    are identical to the eager implementation (covered by the existing
    tests plus the equivalence test in tests/test_slab_scoring.py).
    """

    _RENORM_BELOW = 1e-150      # fold the global multiplier back into
    #                             entries long before f64 underflow

    def __init__(self, capacity_bytes: int, decay_factor: float = 0.99):
        self.capacity_bytes = capacity_bytes
        self.decay_factor = decay_factor
        self._entries: Dict[int, CacheEntry] = {}
        self._decay_mult = 1.0          # global lazy-decay multiplier
        self._total_bytes = 0           # running byte total
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ---- Alg. 2 ----
    def access(self, cluster_id: int) -> Optional[np.ndarray]:
        """Lookup; bumps the counter on hit, decays all counters (O(1))."""
        entry = self._entries.get(cluster_id)
        if entry is not None:
            entry.counter += 1.0 / self._decay_mult     # effective += 1
            self.hits += 1
            out = entry.embeddings
        else:
            self.misses += 1
            out = None
        self._decay()
        return out

    def insert(self, cluster_id: int, embeddings: np.ndarray,
               gen_latency: float, min_latency_threshold: float = 0.0):
        """Insert after a miss+regeneration, honoring the Alg. 3 threshold."""
        if gen_latency < min_latency_threshold:
            return  # not worth caching — cheap to regenerate (Alg. 3)
        nbytes = embeddings.nbytes
        if nbytes > self.capacity_bytes:
            return
        # NOTE: when re-inserting a key that is still cached, the eviction
        # loop runs with the old entry's bytes still counted (and the old
        # entry itself is a legal victim) — exactly the eager original
        while self._total_bytes + nbytes > self.capacity_bytes:
            if not self._evict_one():
                return
        old = self._entries.get(cluster_id)
        if old is not None:             # replaced, not evicted
            self._total_bytes -= old.nbytes
        entry = CacheEntry(
            embeddings=np.ascontiguousarray(embeddings, np.float32),
            gen_latency=float(gen_latency),
            counter=1.0 / self._decay_mult)             # effective 1.0
        self._entries[cluster_id] = entry
        # the running total tracks the STORED (f32) entry, like the eager
        # scan did — the admit/evict decisions above use the caller's
        # nbytes, also like the eager code
        self._total_bytes += entry.nbytes

    def _evict_one(self) -> bool:
        if not self._entries:
            return False
        evict_id = min(self._entries,
                       key=lambda i: (self._entries[i].gen_latency
                                      * self._entries[i].counter))
        self._total_bytes -= self._entries[evict_id].nbytes
        del self._entries[evict_id]
        self.evictions += 1
        return True

    def _decay(self):
        self._decay_mult *= self.decay_factor
        if self._decay_mult < self._RENORM_BELOW:
            for e in self._entries.values():            # rare: amortized O(1)
                e.counter *= self._decay_mult
            self._decay_mult = 1.0

    # ---- maintenance used by Alg. 3's "evicts and prevents caching" ----
    def drop_below_threshold(self, threshold: float):
        for cid in [c for c, e in self._entries.items()
                    if e.gen_latency < threshold]:
            self._total_bytes -= self._entries[cid].nbytes
            del self._entries[cid]
            self.evictions += 1

    def invalidate(self, cluster_id: int):
        entry = self._entries.pop(cluster_id, None)
        if entry is not None:
            self._total_bytes -= entry.nbytes

    def total_bytes(self) -> int:
        return self._total_bytes

    def __contains__(self, cluster_id: int) -> bool:
        return cluster_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class MinLatencyThresholdController:
    """Algorithm 3.  ``step_s`` is the +-/-- increment in seconds."""

    def __init__(self, step_s: float = 0.010, ema_alpha: float = 0.1):
        self.threshold = 0.0
        self.step_s = step_s
        self.alpha = ema_alpha
        self.moving_avg_latency = 0.0
        self._initialized = False

    def observe(self, cache_miss: bool, last_latency: float) -> float:
        if not self._initialized:
            self.moving_avg_latency = last_latency
            self._initialized = True
        if cache_miss:
            if last_latency < self.moving_avg_latency:
                self.threshold += self.step_s
        else:
            self.threshold = max(0.0, self.threshold - self.step_s)
        self.moving_avg_latency = ((1 - self.alpha) * self.moving_avg_latency
                                   + self.alpha * last_latency)
        return self.threshold
