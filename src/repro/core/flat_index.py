"""Flat (exhaustive) index — the paper's quality baseline (Table 4 row 1).

Stores every chunk embedding in memory and linearly scans all of them per
query.  Retrieval is exact; the cost model charges the full resident set
(which is what thrashes on edge devices once the index outgrows DRAM —
Fig. 3).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.costs import EdgeCostModel, LatencyBreakdown, WallTimer
from repro.kernels.ivf_topk.ops import topk_ip


class FlatIndex:
    def __init__(self, dim: int, cost_model: Optional[EdgeCostModel] = None):
        self.dim = dim
        self.cost = cost_model or EdgeCostModel()
        self._embs: Optional[np.ndarray] = None
        self._ids: Optional[np.ndarray] = None

    def add(self, embeddings: np.ndarray, ids: np.ndarray):
        embeddings = np.ascontiguousarray(embeddings, np.float32)
        ids = np.asarray(ids, np.int64)
        if self._embs is None:
            self._embs, self._ids = embeddings, ids
        else:
            self._embs = np.concatenate([self._embs, embeddings])
            self._ids = np.concatenate([self._ids, ids])

    @property
    def ntotal(self) -> int:
        return 0 if self._embs is None else len(self._embs)

    def memory_bytes(self) -> int:
        return 0 if self._embs is None else self._embs.nbytes

    def search(self, query: np.ndarray, k: int
               ) -> Tuple[np.ndarray, np.ndarray, LatencyBreakdown]:
        """query (Q, dim) -> (ids (Q,k), scores (Q,k), latency)."""
        query = np.atleast_2d(np.asarray(query, np.float32))
        lat = LatencyBreakdown()
        with WallTimer() as t:
            vals, idx = topk_ip(self._embs, query, k)
            vals, idx = np.asarray(vals), np.asarray(idx)
        lat.wall_s = t.elapsed
        # sequential scan touches the whole index; thrashing if over-memory
        lat.l2_mem_load_s = self.cost.mem_load_latency(
            self._embs.nbytes, resident_bytes=self.memory_bytes())
        lat.l2_search_s = self.cost.search_latency(self.ntotal, self.dim)
        ids = np.where(idx >= 0, self._ids[np.clip(idx, 0, self.ntotal - 1)],
                       -1)
        return ids, vals, lat
