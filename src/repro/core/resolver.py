"""Tiered cluster-resolution pipeline: probe → PLAN → EXECUTE → score.

EdgeRAG's central decision — where does a probed cluster's embedding matrix
come from? — used to live inline in ``EdgeRAGIndex.search_batch``.  This
module makes it an explicit subsystem shared by every consumer (single-query
``search``, ``search_batch``, maintenance regeneration, the sharded scoring
mode, and the serving engine's prefetch hook):

  PLAN     :meth:`ClusterResolver.plan` union-dedups the batch's probed
           clusters (owner = lowest-index query that probed each one) and
           chooses a TIER per unique cluster, walking the tier ladder:

             storage   selective index storage (Alg. 1); any codec —
                       fp32 / fp16 / int8 (core/storage.py)
             cache     cost-aware LFU DRAM cache (Alg. 2); the plan-time
                       lookup is the batch's single counter-bump + decay
             regen     coalesced online regeneration — pending clusters are
                       packed into groups, ONE ``embed_fn`` call per group
                       (one group unless ``max_group_chars`` bounds it)

  EXECUTE  :meth:`ClusterResolver.execute` materializes the plan: a batched
           ``get_many`` storage load (or the plan's prefetched payloads),
           cached matrices, then the coalesced regenerations — charging each
           owner's :class:`LatencyBreakdown` with exactly the single-query
           cost formulas.  A storage key that vanished between plan and
           execute (e.g. a deleted cluster file) falls back to regeneration
           instead of crashing.

STALENESS (core/maintenance.py): the plan snapshots every planned cluster's
``generation`` stamp.  At execute time, any cluster whose generation moved —
an insert, remove, split, merge, restore or stored-copy drop landed between
plan and execution — abandons its planned payload and regenerates over the
cluster's CURRENT membership (clusters merged away resolve to zero rows and
drop out of scoring).  Generations catch same-size mutations; the old
row-count compare is kept only as defense in depth against direct mutators
that forgot to bump.  Stored clusters are additionally only loadable while
``stored_generation == generation`` — a stale or vanished copy is bypassed,
regenerated, and re-persisted (the Alg. 1 self-heal).

The fp32 tier is bit-identical to the pre-refactor inlined logic: the same
state mutations happen in the same order (cache access per unique cluster at
plan time, inserts after regeneration, per-field latency accumulation in
owner order), asserted by the Table-4 parity tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.costs import LatencyBreakdown

TIER_STORAGE = "storage"
TIER_CACHE = "cache"
TIER_REGEN = "regen"


@dataclasses.dataclass
class ResolutionPlan:
    """Explicit per-batch resolution decisions (see module docstring).

    ``owner`` iterates in batch order (dict insertion order: by owning
    query, then that query's probe order) — execution replays charges in
    exactly this order.
    """
    probed_per_q: List[List[int]]        # per query: probed active clusters
    owner: Dict[int, int]                # cluster id -> owning query index
    tier: Dict[int, str]                 # cluster id -> chosen tier
    storage_clusters: List[int]          # storage tier, owner order
    cached: Dict[int, np.ndarray]        # cache tier: plan-time lookups
    regen_groups: List[List[int]]        # one coalesced embed call per group
    restore: List[int] = dataclasses.field(default_factory=list)
    # ^ regen-tier clusters whose storage copy vanished or went stale
    #   out-of-band: execution re-persists them (the Alg. 1 self-heal)
    generations: Dict[int, int] = dataclasses.field(default_factory=dict)
    # ^ plan-time generation stamp per planned cluster; execute() treats any
    #   mismatch with the live cluster as a stale plan entry
    prefetched: Optional[Dict[int, np.ndarray]] = None  # early storage loads

    def fresh(self, cid: int, cluster) -> bool:
        """True iff ``cluster`` has not mutated since this plan was made
        (missing snapshot = plan predates generation stamps: trust it)."""
        return self.generations.get(cid, cluster.generation) \
            == cluster.generation

    @property
    def regen_clusters(self) -> List[int]:
        return [cid for group in self.regen_groups for cid in group]

    @property
    def n_unique(self) -> int:
        return len(self.owner)


class ClusterResolver:
    """Executes the tier ladder for an :class:`EdgeRAGIndex`.

    ``max_group_chars`` bounds the text volume of one coalesced ``embed_fn``
    call (None = a single call for the whole batch, the serving default).
    """

    def __init__(self, index, *, max_group_chars: Optional[int] = None):
        self.index = index
        self.max_group_chars = max_group_chars

    # ------------------------------------------------------------------
    # plan
    # ------------------------------------------------------------------
    def plan(self, probed_per_q: Sequence[Sequence[int]]) -> ResolutionPlan:
        ix = self.index
        owner: Dict[int, int] = {}
        for qi, probed in enumerate(probed_per_q):
            for cid in probed:
                owner.setdefault(cid, qi)
        tier: Dict[int, str] = {}
        storage_clusters: List[int] = []
        cached: Dict[int, np.ndarray] = {}
        pending: List[int] = []
        restore: List[int] = []
        for cid in owner:
            cl = ix.clusters[cid]
            if cl.stored:
                if cl.storage_fresh and cid in ix.storage:
                    tier[cid] = TIER_STORAGE
                    storage_clusters.append(cid)
                    continue
                # storage copy vanished out-of-band, or went stale behind a
                # mutation (deferred maintenance hasn't restored it yet):
                # regenerate AND re-persist (same recovery as an
                # execute-time vanish)
                tier[cid] = TIER_REGEN
                pending.append(cid)
                restore.append(cid)
                continue
            hit = ix.cache.access(cid)   # Alg. 2: one bump + decay per batch
            if hit is not None:
                tier[cid] = TIER_CACHE
                cached[cid] = hit
                continue
            tier[cid] = TIER_REGEN
            pending.append(cid)
        return ResolutionPlan(
            probed_per_q=[list(p) for p in probed_per_q],
            owner=owner, tier=tier, storage_clusters=storage_clusters,
            cached=cached, regen_groups=self._coalesce(pending),
            restore=restore,
            generations={cid: ix.clusters[cid].generation for cid in owner})

    def _coalesce(self, pending: List[int]) -> List[List[int]]:
        if not pending:
            return []
        if self.max_group_chars is None:
            return [list(pending)]
        groups: List[List[int]] = []
        cur: List[int] = []
        chars = 0
        for cid in pending:
            c = self.index.clusters[cid].char_count
            if cur and chars + c > self.max_group_chars:
                groups.append(cur)
                cur, chars = [], 0
            cur.append(cid)
            chars += c
        if cur:
            groups.append(cur)
        return groups

    # ------------------------------------------------------------------
    # prefetch (serving engine hook)
    # ------------------------------------------------------------------
    def prefetch(self, plan: ResolutionPlan) -> ResolutionPlan:
        """Issue the plan's storage loads ahead of execution.  The payloads
        ride along on the plan so execute() doesn't re-read them; the engine
        overlaps their modeled I/O seconds with prefill."""
        if plan.storage_clusters and plan.prefetched is None:
            loaded = self.index.storage.get_many(plan.storage_clusters)
            plan.prefetched = {cid: emb for cid, emb
                               in zip(plan.storage_clusters, loaded)
                               if emb is not None}
        return plan

    # ------------------------------------------------------------------
    # execute
    # ------------------------------------------------------------------
    def execute(self, plan: ResolutionPlan, lats: List[LatencyBreakdown],
                missed: List[bool]) -> Dict[int, np.ndarray]:
        """Materialize ``plan``; returns cluster id -> f32 (n, d) matrix.

        Side effects mirror the single-query path: owners are charged tier
        costs, regenerated clusters refresh ``gen_latency_est`` and enter
        the cache under the current Alg. 3 threshold, and ``missed[qi]`` is
        set for every query that owns a regenerated cluster.
        """
        ix = self.index
        resolved: Dict[int, np.ndarray] = {}
        regen_groups = [list(g) for g in plan.regen_groups]
        fallback: List[int] = []      # stale / vanished since plan time
        if plan.storage_clusters:
            if plan.prefetched is not None:
                loaded = [plan.prefetched.get(c)
                          for c in plan.storage_clusters]
            else:
                loaded = ix.storage.get_many(plan.storage_clusters)
            for cid, embs in zip(plan.storage_clusters, loaded):
                # Staleness guard: a prefetched payload is only scoreable if
                # the cluster's generation never moved after the plan; an
                # execute-time load only if the storage copy reflects the
                # CURRENT generation (a sync restore may have refreshed it
                # after the plan went stale).  Either failure — or a deleted
                # key, or a row-count mismatch (defense in depth) — falls
                # back to regeneration instead of crashing or scoring stale
                # ids.
                cl = ix.clusters[cid]
                fresh = (plan.fresh(cid, cl) if plan.prefetched is not None
                         else cl.storage_fresh)
                if embs is None or not fresh or len(embs) != cl.size:
                    fallback.append(cid)
                    continue
                try:
                    nbytes = ix.storage.stored_bytes(cid)
                except KeyError:
                    fallback.append(cid)
                    continue
                lat = lats[plan.owner[cid]]
                lat.l2_storage_load_s += ix.cost.storage_load_latency(nbytes)
                if ix.storage.codec != "fp32":
                    # decode is compute, not I/O: charged separately so the
                    # engine's prefetch overlap only hides true I/O seconds
                    lat.l2_dequant_s += ix.cost.dequant_latency(embs.size)
                lat.n_storage_loads += 1
                resolved[cid] = embs
        for cid, embs in plan.cached.items():
            # generation guard (same-size mutations included) + row-count
            # defense: a cluster mutated since plan time would misalign the
            # scoring id map
            cl = ix.clusters[cid]
            if not plan.fresh(cid, cl) or len(embs) != cl.size:
                ix.cache.invalidate(cid)   # don't let the stale entry recur
                fallback.append(cid)
                continue
            lat = lats[plan.owner[cid]]
            lat.l2_cache_hit_s += ix.cost.mem_load_latency(
                embs.nbytes, resident_bytes=ix.memory_bytes())
            lat.n_cache_hits += 1
            resolved[cid] = embs
        if fallback:
            regen_groups.append(fallback)
        heal = set(fallback) | set(plan.restore)
        for group in regen_groups:
            # clusters merged away (or emptied) since plan time have no
            # text to regenerate: they resolve to zero rows and drop out
            # of scoring
            dead = [c for c in group if not (ix.clusters[c].active
                                             and ix.clusters[c].size > 0)]
            for c in dead:
                resolved[c] = np.zeros((0, ix.dim), np.float32)
            group = [c for c in group if c not in dead]
            if not group:
                continue
            for cid, sub, chars in self._regen_group(group):
                cl = ix.clusters[cid]
                if (cl.stored and cid in heal
                        and (not cl.storage_fresh or cid not in ix.storage)):
                    # self-heal the vanished/stale storage copy so later
                    # batches load instead of regenerating forever
                    ix.storage.put(cid, sub.copy())
                    cl.stored_generation = cl.generation
                gen_s = ix.cost.embed_latency(chars)
                qi = plan.owner[cid]
                lats[qi].l2_generate_s += gen_s
                lats[qi].n_generated += 1
                lats[qi].chars_embedded += chars
                missed[qi] = True
                cl.gen_latency_est = gen_s
                if not cl.stored:
                    # copy: a view into the group's matrix would pin the
                    # whole group in the cache and break its byte accounting.
                    # (Stored clusters skip the cache: plan() always serves
                    # fresh stored clusters from the storage tier, so a
                    # cached copy would be dead weight.)
                    ix.cache.insert(
                        cid, sub.copy(), gen_s,
                        min_latency_threshold=ix.threshold.threshold)
                resolved[cid] = sub
        return resolved

    # ------------------------------------------------------------------
    # regeneration (shared with the maintenance paths)
    # ------------------------------------------------------------------
    def _regen_group(self, cids: Sequence[int]):
        """ONE ``embed_fn`` call over the group's concatenated texts; yields
        (cid, embeddings view, char count) per cluster."""
        ix = self.index
        texts_per = [ix.get_chunks(ix.clusters[c].ids.tolist())
                     for c in cids]
        flat = [txt for ts in texts_per for txt in ts]
        embs_all = np.ascontiguousarray(ix.embed_fn(flat), np.float32)
        off = 0
        for cid, ts in zip(cids, texts_per):
            sub = embs_all[off:off + len(ts)]
            off += len(ts)
            yield cid, sub, sum(len(txt) for txt in ts)

    def regenerate(self, cids: Sequence[int]) -> List[np.ndarray]:
        """Coalesced regeneration outside a search (restore / split paths).
        No latency attribution, no cache interaction."""
        return [sub.copy() for _, sub, _ in self._regen_group(list(cids))]
