"""Tiered cluster-resolution pipeline: probe → PLAN → EXECUTE → score.

EdgeRAG's central decision — where does a probed cluster's embedding matrix
come from? — used to live inline in ``EdgeRAGIndex.search_batch``.  This
module makes it an explicit subsystem shared by every consumer (single-query
``search``, ``search_batch``, maintenance regeneration, the sharded scoring
mode, and the serving engine's prefetch hook):

  PLAN     :meth:`ClusterResolver.plan` union-dedups the batch's probed
           clusters (owner = lowest-index query that probed each one) and
           chooses a TIER per unique cluster, walking the tier ladder:

             storage   selective index storage (Alg. 1); any codec —
                       fp32 / fp16 / int8 (core/storage.py)
             cache     cost-aware LFU DRAM cache (Alg. 2); the plan-time
                       lookup is the batch's single counter-bump + decay
             regen     coalesced online regeneration — pending clusters are
                       packed into groups, ONE ``embed_fn`` call per group
                       (one group unless ``max_group_chars`` bounds it)

  EXECUTE  :meth:`ClusterResolver.execute` materializes the plan: a batched
           ``get_many`` storage load (or the plan's prefetched payloads),
           cached matrices, then the coalesced regenerations — charging each
           owner's :class:`LatencyBreakdown` with exactly the single-query
           cost formulas.  A storage key that vanished between plan and
           execute (e.g. a deleted cluster file) falls back to regeneration
           instead of crashing.

STALENESS (core/maintenance.py): the plan snapshots every planned cluster's
``generation`` stamp.  At execute time, any cluster whose generation moved —
an insert, remove, split, merge, restore or stored-copy drop landed between
plan and execution — abandons its planned payload and regenerates over the
cluster's CURRENT membership (clusters merged away resolve to zero rows and
drop out of scoring).  Generations catch same-size mutations; the old
row-count compare is kept only as defense in depth against direct mutators
that forgot to bump.  Stored clusters are additionally only loadable while
``stored_generation == generation`` — a stale or vanished copy is bypassed,
regenerated, and re-persisted (the Alg. 1 self-heal).

The fp32 tier is bit-identical to the pre-refactor inlined logic: the same
state mutations happen in the same order (cache access per unique cluster at
plan time, inserts after regeneration, per-field latency accumulation in
owner order), asserted by the Table-4 parity tests.

PACKED-SLAB SCORING (kernels/slab_topk): :meth:`ClusterResolver.execute_slab`
runs ``execute`` in RAW mode — storage-tier clusters load their codec
payloads *undecoded* (``StorageBackend.get_many_raw``) — and packs every
resolved cluster exactly once into a :class:`SlabLayout`: one contiguous
(N_total, d) embedding slab per storage representation present in the batch
(fp32 / fp16 / int8+scales / pq codes), a parallel chunk-id slab, and
per-cluster (offset, length) extents.  The per-cluster payloads become
views into the slab.  Scoring then runs ONE ragged multi-query kernel
launch per segment instead of Q concat-and-top-k rounds, with fp16/int8
segments dequantized inside the kernel's dot-product block (per-row
scales) and pq segments scored by in-kernel LUT gather+accumulate — no
fp32 copy of quantized storage is ever materialized.  Owners are charged
the slab-pack copy (``l2_slab_pack_s``) and the fused decode
(``l2_fused_dequant_s``) or PQ code gather (``l2_pq_gather_s``) once per
slab, not once per probing query.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.costs import LatencyBreakdown
from repro.core.faults import DegradationPolicy, IOOutcome
from repro.kernels.slab_topk.ops import NOT_PROBED

TIER_STORAGE = "storage"
TIER_CACHE = "cache"
TIER_REGEN = "regen"


@dataclasses.dataclass
class ResolutionPlan:
    """Explicit per-batch resolution decisions (see module docstring).

    ``owner`` iterates in batch order (dict insertion order: by owning
    query, then that query's probe order) — execution replays charges in
    exactly this order.
    """
    probed_per_q: List[List[int]]        # per query: probed active clusters
    owner: Dict[int, int]                # cluster id -> owning query index
    tier: Dict[int, str]                 # cluster id -> chosen tier
    storage_clusters: List[int]          # storage tier, owner order
    cached: Dict[int, np.ndarray]        # cache tier: plan-time lookups
    regen_groups: List[List[int]]        # one coalesced embed call per group
    restore: List[int] = dataclasses.field(default_factory=list)
    # ^ regen-tier clusters whose storage copy vanished or went stale
    #   out-of-band: execution re-persists them (the Alg. 1 self-heal)
    generations: Dict[int, int] = dataclasses.field(default_factory=dict)
    # ^ plan-time generation stamp per planned cluster; execute() treats any
    #   mismatch with the live cluster as a stale plan entry
    content_generations: Dict[int, int] = \
        dataclasses.field(default_factory=dict)
    # ^ plan-time CONTENT stamp (membership/content mutations only, not
    #   storage-tier flips) — the post-fetch staleness check: payloads
    #   already fetched stay row-aligned across restore/drop, so only a
    #   content move forces the pipeline's S3 replan
    prefetched: Optional[Dict[int, Dict[str, np.ndarray]]] = None
    # ^ early storage loads — RAW codec payloads (never decoded here; the
    #   slab scorer consumes them via fused dequant)
    io_outcomes: Optional[Dict[int, IOOutcome]] = None
    # ^ prefetch-time per-key I/O costs (retries / stalls / backoff): the
    #   charges belong to the owning query's LatencyBreakdown, which only
    #   exists at execute time
    deadlines: Optional[List[Optional[float]]] = None
    # ^ per-query retrieval deadline budgets (edge seconds; None = no
    #   deadline).  Set when the caller requested deadline-aware serving.
    policy: Optional[DegradationPolicy] = None
    # ^ the degradation ladder knobs; only consulted when deadlines is set
    shed_probes: List[int] = dataclasses.field(default_factory=list)
    # ^ rung-1 sheds per query (probes dropped before planning), recorded
    #   here because the per-query LatencyBreakdowns don't exist at plan
    #   time; search_batch folds them into ``degraded_clusters``

    def fresh(self, cid: int, cluster) -> bool:
        """True iff ``cluster`` has not mutated since this plan was made
        (missing snapshot = plan predates generation stamps: trust it)."""
        return self.generations.get(cid, cluster.generation) \
            == cluster.generation

    def content_fresh(self, cid: int, cluster) -> bool:
        """True iff ``cluster``'s MEMBERSHIP/CONTENT has not moved since
        plan time — storage-tier flips (restore / drop) don't count.  The
        right staleness predicate once payloads are already in hand."""
        return self.content_generations.get(
            cid, cluster.content_generation) == cluster.content_generation

    @property
    def regen_clusters(self) -> List[int]:
        return [cid for group in self.regen_groups for cid in group]

    @property
    def n_unique(self) -> int:
        return len(self.owner)


@dataclasses.dataclass
class SlabPayload:
    """One resolved cluster in its scoring representation.

    ``kind`` is the slab segment it packs into: "fp32" (cache / regen /
    fp32 storage), "fp16", "int8", or "pq" (undecoded storage payloads).
    ``scales`` is the int8 codec's per-row scale column, (n, 1) f32; for
    "pq", ``emb`` holds the (n, m) uint8 code matrix and ``codebook`` the
    backend's :class:`~repro.core.pq.PQCodebook` the codes index into.
    """
    kind: str
    emb: np.ndarray
    scales: Optional[np.ndarray] = None
    codebook: Optional[object] = None       # PQCodebook for kind == "pq"

    @property
    def rows(self) -> int:
        return len(self.emb)

    @property
    def nbytes(self) -> int:
        return self.emb.nbytes + (0 if self.scales is None
                                  else self.scales.nbytes)

    @classmethod
    def from_raw(cls, payload: Dict[str, np.ndarray],
                 codebook=None) -> "SlabPayload":
        """Wrap an undecoded ``StorageBackend`` codec payload."""
        if "q" in payload:
            return cls("int8", payload["q"],
                       np.ascontiguousarray(payload["scale"], np.float32))
        if "codes" in payload:
            assert codebook is not None, "pq payload needs its codebook"
            return cls("pq", payload["codes"], codebook=codebook)
        emb = payload["emb"]
        if emb.dtype == np.float16:
            return cls("fp16", emb)
        return cls("fp32", np.ascontiguousarray(emb, np.float32))


@dataclasses.dataclass
class SlabSegment:
    """One contiguous packed slab: every cluster of one representation."""
    kind: str                       # "fp32" | "fp16" | "int8" | "pq"
    emb: np.ndarray                 # (rows, d) packed, segment dtype —
    #                                 (rows, m) uint8 codes for "pq"
    scales: Optional[np.ndarray]    # (rows, 1) f32 — int8 segments only
    ids: np.ndarray                 # (rows,) int64 parallel chunk-id slab
    clusters: List[int]             # cluster ids in pack order
    codebook: Optional[object] = None   # PQCodebook — pq segments only

    @property
    def rows(self) -> int:
        return len(self.emb)


@dataclasses.dataclass
class SlabLayout:
    """The batch's unique resolved clusters, each packed exactly ONCE.

    ``extent`` maps cluster id -> (kind, row offset, row length) into the
    segment of that representation; clusters that resolved to zero rows
    (merged away between plan and execute) get a zero-length extent and
    never reach scoring.  At most four segments exist (fp32 / fp16 /
    int8 / pq); a pure-fp32 batch packs one.
    """
    dim: int
    segments: List[SlabSegment]
    extent: Dict[int, Tuple[str, int, int]]

    @property
    def total_rows(self) -> int:
        return sum(seg.rows for seg in self.segments)

    def segment(self, kind: str) -> SlabSegment:
        return next(seg for seg in self.segments if seg.kind == kind)

    def view(self, cid: int) -> np.ndarray:
        """The cluster's packed rows — a VIEW into its segment's slab."""
        kind, off, length = self.extent[cid]
        if length == 0:
            return np.zeros((0, self.dim), np.float32)
        return self.segment(kind).emb[off:off + length]

    def nbytes(self, cid: int) -> int:
        """Resident (packed) bytes of one cluster — what a peer query's
        shared-hit DRAM re-read streams."""
        kind, off, length = self.extent[cid]
        if length == 0:
            return 0
        seg = self.segment(kind)
        n = length * seg.emb.shape[1] * seg.emb.itemsize
        if seg.scales is not None:
            n += length * seg.scales.itemsize
        return n

    @classmethod
    def pack(cls, dim: int, order: Sequence[int],
             payloads: Dict[int, SlabPayload],
             ids_of) -> "SlabLayout":
        """Pack ``payloads`` (in ``order``) into per-kind segments.

        ``ids_of(cid)`` supplies the cluster's current chunk ids; the
        staleness guards upstream guarantee they align with the payload
        rows (asserted here as defense in depth).

        A single-cluster segment adopts its payload array as the slab by
        reference instead of copying — with memmap-mode storage the slab
        extent is then a slice of the on-disk mapping and no resident copy
        of the payload ever exists.
        """
        by_kind: Dict[str, List[int]] = {}
        extent: Dict[int, Tuple[str, int, int]] = {}
        for cid in order:
            p = payloads[cid]
            if p.rows == 0:
                extent[cid] = (p.kind, 0, 0)
                continue
            by_kind.setdefault(p.kind, []).append(cid)
        segments: List[SlabSegment] = []
        for kind, cids in by_kind.items():
            first = payloads[cids[0]]
            cb = first.codebook if kind == "pq" else None
            if len(cids) == 1:
                cid = cids[0]
                cl_ids = ids_of(cid)
                assert len(cl_ids) == first.rows, \
                    f"cluster {cid}: {len(cl_ids)} ids vs {first.rows} rows"
                extent[cid] = (kind, 0, first.rows)
                segments.append(SlabSegment(
                    kind=kind, emb=first.emb, scales=first.scales,
                    ids=np.asarray(cl_ids, np.int64), clusters=[cid],
                    codebook=cb))
                continue
            rows = sum(payloads[c].rows for c in cids)
            d = first.emb.shape[1]
            emb = np.empty((rows, d), first.emb.dtype)
            scales = (np.empty((rows, 1), np.float32) if kind == "int8"
                      else None)
            ids = np.empty((rows,), np.int64)
            off = 0
            for cid in cids:
                p = payloads[cid]
                cl_ids = ids_of(cid)
                assert len(cl_ids) == p.rows, \
                    f"cluster {cid}: {len(cl_ids)} ids vs {p.rows} rows"
                emb[off:off + p.rows] = p.emb
                ids[off:off + p.rows] = cl_ids
                if scales is not None:
                    scales[off:off + p.rows] = p.scales
                extent[cid] = (kind, off, p.rows)
                off += p.rows
            segments.append(SlabSegment(kind=kind, emb=emb, scales=scales,
                                        ids=ids, clusters=list(cids),
                                        codebook=cb))
        return cls(dim=dim, segments=segments, extent=extent)

    def query_layout(self, probed_per_q: Sequence[Sequence[int]]):
        """Per-(query, cluster) membership from the plan's probe lists.

        Returns ``(virts, n_valid, n_valid_seg)``: ``virts`` maps each
        segment kind to a (Q, rows) int32 matrix whose entry is the row's
        position in that query's VIRTUAL per-query concatenation (probed
        clusters in probe order) or ``NOT_PROBED``; ``n_valid`` (Q,) is
        each query's total member-row count across segments (its virtual
        concat length), and ``n_valid_seg`` maps kind -> (Q,) per-segment
        member counts (the valid-lane bound for that segment's top-k
        output).  virt is both the scoring mask and the tie-break key that
        keeps slab results identical to the per-query concat loop.
        """
        nq = len(probed_per_q)
        virts = {seg.kind: np.full((nq, seg.rows), NOT_PROBED, np.int32)
                 for seg in self.segments}
        n_valid = np.zeros((nq,), np.int64)
        n_valid_seg = {seg.kind: np.zeros((nq,), np.int64)
                       for seg in self.segments}
        for qi, probed in enumerate(probed_per_q):
            base = 0
            for cid in probed:
                kind, off, length = self.extent[cid]
                if length == 0:
                    continue
                virts[kind][qi, off:off + length] = np.arange(
                    base, base + length, dtype=np.int32)
                base += length
                n_valid_seg[kind][qi] += length
            n_valid[qi] = base
        return virts, n_valid, n_valid_seg


class ClusterResolver:
    """Executes the tier ladder for an :class:`EdgeRAGIndex`.

    ``max_group_chars`` bounds the text volume of one coalesced ``embed_fn``
    call (None = a single call for the whole batch, the serving default).
    """

    def __init__(self, index, *, max_group_chars: Optional[int] = None):
        self.index = index
        self.max_group_chars = max_group_chars

    # ------------------------------------------------------------------
    # plan
    # ------------------------------------------------------------------
    def plan(self, probed_per_q: Sequence[Sequence[int]]) -> ResolutionPlan:
        ix = self.index
        owner: Dict[int, int] = {}
        for qi, probed in enumerate(probed_per_q):
            for cid in probed:
                owner.setdefault(cid, qi)
        tier: Dict[int, str] = {}
        storage_clusters: List[int] = []
        cached: Dict[int, np.ndarray] = {}
        pending: List[int] = []
        restore: List[int] = []
        for cid in owner:
            cl = ix.clusters[cid]
            if cl.stored:
                if cl.storage_fresh and cid in ix.storage:
                    tier[cid] = TIER_STORAGE
                    storage_clusters.append(cid)
                    continue
                # storage copy vanished out-of-band, or went stale behind a
                # mutation (deferred maintenance hasn't restored it yet):
                # regenerate AND re-persist (same recovery as an
                # execute-time vanish)
                tier[cid] = TIER_REGEN
                pending.append(cid)
                restore.append(cid)
                continue
            hit = ix.cache.access(cid)   # Alg. 2: one bump + decay per batch
            if hit is not None:
                tier[cid] = TIER_CACHE
                cached[cid] = hit
                continue
            tier[cid] = TIER_REGEN
            pending.append(cid)
        return ResolutionPlan(
            probed_per_q=[list(p) for p in probed_per_q],
            owner=owner, tier=tier, storage_clusters=storage_clusters,
            cached=cached, regen_groups=self._coalesce(pending),
            restore=restore,
            generations={cid: ix.clusters[cid].generation for cid in owner},
            content_generations={cid: ix.clusters[cid].content_generation
                                 for cid in owner})

    def _coalesce(self, pending: List[int]) -> List[List[int]]:
        if not pending:
            return []
        if self.max_group_chars is None:
            return [list(pending)]
        groups: List[List[int]] = []
        cur: List[int] = []
        chars = 0
        for cid in pending:
            c = self.index.clusters[cid].char_count
            if cur and chars + c > self.max_group_chars:
                groups.append(cur)
                cur, chars = [], 0
            cur.append(cid)
            chars += c
        if cur:
            groups.append(cur)
        return groups

    # ------------------------------------------------------------------
    # prefetch (serving engine hook)
    # ------------------------------------------------------------------
    def prefetch(self, plan: ResolutionPlan) -> ResolutionPlan:
        """Issue the plan's storage loads ahead of execution.  The RAW
        codec payloads ride along on the plan so execute() doesn't re-read
        them (decode stays fused into scoring); the engine overlaps their
        modeled I/O seconds with prefill."""
        if plan.storage_clusters and plan.prefetched is None:
            outcomes: List[IOOutcome] = []
            loaded = self.index.storage.get_many_raw(plan.storage_clusters,
                                                     outcomes=outcomes)
            plan.prefetched = {cid: payload for cid, payload
                               in zip(plan.storage_clusters, loaded)
                               if payload is not None}
            plan.io_outcomes = {o.key: o for o in outcomes}
        return plan

    # ------------------------------------------------------------------
    # execute
    # ------------------------------------------------------------------
    def execute(self, plan: ResolutionPlan, lats: List[LatencyBreakdown],
                missed: List[bool], *, raw: bool = False) -> Dict[int, object]:
        """Materialize ``plan``; returns cluster id -> f32 (n, d) matrix,
        or cluster id -> :class:`SlabPayload` when ``raw=True`` (the slab
        scoring mode: storage-tier clusters stay in their codec
        representation — no decode, no fp32 copy; decode fuses into the
        scoring kernel and is charged at pack time).

        Side effects mirror the single-query path: owners are charged tier
        costs, regenerated clusters refresh ``gen_latency_est`` and enter
        the cache under the current Alg. 3 threshold, and ``missed[qi]`` is
        set for every query that owns a regenerated cluster.
        """
        ix = self.index
        resolved: Dict[int, object] = {}
        regen_groups = [list(g) for g in plan.regen_groups]
        fallback: List[int] = []      # stale / vanished since plan time
        deadlines = plan.deadlines
        policy = plan.policy if deadlines is not None else None
        if policy is None and deadlines is not None:
            policy = DegradationPolicy()

        def _budget_left(qi: int) -> Optional[float]:
            """Remaining deadline budget of one query, against the edge
            seconds its LatencyBreakdown has accrued SO FAR this batch
            (retries and stalls charged earlier in this execute included)."""
            if deadlines is None or deadlines[qi] is None:
                return None
            return deadlines[qi] - lats[qi].retrieval_s

        if plan.storage_clusters:
            if plan.prefetched is not None:
                loaded = [plan.prefetched.get(c)
                          for c in plan.storage_clusters]
                outcomes = plan.io_outcomes or {}
            else:
                olist: List[IOOutcome] = []
                loaded = ix.storage.get_many_raw(plan.storage_clusters,
                                                 outcomes=olist)
                outcomes = {o.key: o for o in olist}
            for cid, payload in zip(plan.storage_clusters, loaded):
                # fault charges (retries / stalls / backoff) land on the
                # owner whether or not the read ultimately succeeded
                self._charge_io(lats[plan.owner[cid]], outcomes.get(cid))
                # Staleness guard: a prefetched payload is only scoreable if
                # the cluster's generation never moved after the plan; an
                # execute-time load only if the storage copy reflects the
                # CURRENT generation (a sync restore may have refreshed it
                # after the plan went stale).  Either failure — or a deleted
                # key, or a row-count mismatch (defense in depth) — falls
                # back to regeneration instead of crashing or scoring stale
                # ids.
                cl = ix.clusters[cid]
                fresh = (plan.fresh(cid, cl) if plan.prefetched is not None
                         else cl.storage_fresh)
                if (payload is None or not fresh
                        or ix.storage.payload_rows(payload) != cl.size):
                    fallback.append(cid)
                    continue
                try:
                    nbytes = ix.storage.stored_bytes(cid)
                except KeyError:
                    fallback.append(cid)
                    continue
                lat = lats[plan.owner[cid]]
                lat.l2_storage_load_s += ix.cost.storage_load_latency(nbytes)
                lat.n_storage_loads += 1
                if raw:
                    resolved[cid] = SlabPayload.from_raw(
                        payload, codebook=ix.storage.pq)
                    continue
                embs = ix.storage.decode(payload)
                if ix.storage.codec != "fp32":
                    # decode is compute, not I/O: charged separately so the
                    # engine's prefetch overlap only hides true I/O seconds
                    lat.l2_dequant_s += ix.cost.dequant_latency(embs.size)
                resolved[cid] = embs
        for cid, embs in plan.cached.items():
            # generation guard (same-size mutations included) + row-count
            # defense: a cluster mutated since plan time would misalign the
            # scoring id map
            cl = ix.clusters[cid]
            if not plan.fresh(cid, cl) or len(embs) != cl.size:
                qi = plan.owner[cid]
                budget = _budget_left(qi)
                if (policy is not None and policy.serve_stale
                        and budget is not None
                        and cl.gen_latency_est > budget
                        and len(embs) == cl.size):
                    # ladder rung 3: the deadline cannot afford the
                    # regeneration, and the stale payload still row-aligns
                    # with the cluster (same-size mutation) — score it,
                    # flagged, and evict it so the next unpressured batch
                    # regenerates a fresh copy
                    lat = lats[qi]
                    lat.l2_cache_hit_s += ix.cost.mem_load_latency(
                        embs.nbytes, resident_bytes=ix.memory_bytes())
                    lat.n_cache_hits += 1
                    lat.stale_served += 1
                    ix.cache.invalidate(cid)
                    resolved[cid] = (SlabPayload("fp32", embs) if raw
                                     else embs)
                    continue
                ix.cache.invalidate(cid)   # don't let the stale entry recur
                fallback.append(cid)
                continue
            lat = lats[plan.owner[cid]]
            lat.l2_cache_hit_s += ix.cost.mem_load_latency(
                embs.nbytes, resident_bytes=ix.memory_bytes())
            lat.n_cache_hits += 1
            resolved[cid] = SlabPayload("fp32", embs) if raw else embs
        if fallback:
            regen_groups.append(fallback)
        heal = set(fallback) | set(plan.restore)
        # ladder rung 2: an owner whose queued regenerations cannot fit its
        # remaining budget sheds the MOST EXPENSIVE ones first; shed
        # clusters fall to _resolve_degraded (stale stored copy when one
        # still row-aligns, else zero rows) and never regenerate
        shed: set = set()
        if policy is not None and policy.shed_regen:
            per_owner: Dict[int, List[int]] = {}
            for group in regen_groups:
                for cid in group:
                    cl = ix.clusters[cid]
                    if cl.active and cl.size > 0:
                        per_owner.setdefault(plan.owner[cid], []).append(cid)
            for qi, cids in per_owner.items():
                budget = _budget_left(qi)
                if budget is None:
                    continue
                total = sum(ix.clusters[c].gen_latency_est for c in cids)
                for c in sorted(cids,
                                key=lambda c: -ix.clusters[c].gen_latency_est):
                    if total <= budget:
                        break
                    shed.add(c)
                    total -= ix.clusters[c].gen_latency_est
        for group in regen_groups:
            # clusters merged away (or emptied) since plan time have no
            # text to regenerate: they resolve to zero rows and drop out
            # of scoring
            dead = [c for c in group if not (ix.clusters[c].active
                                             and ix.clusters[c].size > 0)]
            for c in dead:
                empty = np.zeros((0, ix.dim), np.float32)
                resolved[c] = SlabPayload("fp32", empty) if raw else empty
            group = [c for c in group if c not in dead]
            if shed:
                for cid in group:
                    if cid in shed:
                        self._resolve_degraded(cid, plan, lats, resolved, raw)
                group = [c for c in group if c not in shed]
            if not group:
                continue
            for cid, sub, chars in self._regen_group(group):
                cl = ix.clusters[cid]
                if (cl.stored and cid in heal
                        and (not cl.storage_fresh or cid not in ix.storage)):
                    # self-heal the vanished/stale storage copy so later
                    # batches load instead of regenerating forever; a
                    # budget-refused put (returns 0) leaves the cluster on
                    # the regen path instead
                    if ix.storage.put(cid, sub.copy()) > 0:
                        cl.stored_generation = cl.generation
                    else:
                        cl.stored = False
                        cl.stored_generation = -1
                    # the heal changed durable-relevant state: commit it as
                    # one WAL record, fsync charged to the owning query
                    ix._dirty.add(cid)
                    lats[plan.owner[cid]].wal_fsync_s += \
                        ix._wal_commit("self_heal")
                gen_s = ix.cost.embed_latency(chars)
                qi = plan.owner[cid]
                lats[qi].l2_generate_s += gen_s
                lats[qi].n_generated += 1
                lats[qi].chars_embedded += chars
                missed[qi] = True
                cl.gen_latency_est = gen_s
                if not cl.stored:
                    # copy: a view into the group's matrix would pin the
                    # whole group in the cache and break its byte accounting.
                    # (Stored clusters skip the cache: plan() always serves
                    # fresh stored clusters from the storage tier, so a
                    # cached copy would be dead weight.)
                    ix.cache.insert(
                        cid, sub.copy(), gen_s,
                        min_latency_threshold=ix.threshold.threshold)
                resolved[cid] = SlabPayload("fp32", sub) if raw else sub
        return resolved

    @staticmethod
    def _charge_io(lat: LatencyBreakdown,
                   outcome: Optional[IOOutcome]) -> None:
        """Land one read's fault costs (injected stall seconds, modeled
        retry backoff, retry count) on the owning query."""
        if outcome is None:
            return
        lat.l2_stall_s += outcome.stall_s
        lat.l2_retry_backoff_s += outcome.backoff_s
        lat.retries += outcome.retries

    def _resolve_degraded(self, cid: int, plan: ResolutionPlan,
                          lats: List[LatencyBreakdown],
                          resolved: Dict[int, object], raw: bool) -> None:
        """Resolve one rung-2-shed cluster without regenerating: serve the
        STALE stored copy flagged stale when one exists and still
        row-aligns with the cluster (rung 3 via storage), else skip the
        cluster entirely — zero rows, counted in ``degraded_clusters``."""
        ix = self.index
        cl = ix.clusters[cid]
        lat = lats[plan.owner[cid]]
        policy = plan.policy or DegradationPolicy()
        if policy.serve_stale and cl.stored and cid in ix.storage:
            outcomes: List[IOOutcome] = []
            payload = ix.storage.get_many_raw([cid], outcomes=outcomes)[0]
            self._charge_io(lat, outcomes[0])
            if (payload is not None
                    and ix.storage.payload_rows(payload) == cl.size):
                try:
                    nbytes = ix.storage.stored_bytes(cid)
                except KeyError:
                    nbytes = sum(a.nbytes for a in payload.values())
                lat.l2_storage_load_s += ix.cost.storage_load_latency(nbytes)
                lat.n_storage_loads += 1
                lat.stale_served += 1
                if raw:
                    resolved[cid] = SlabPayload.from_raw(
                        payload, codebook=ix.storage.pq)
                    return
                embs = ix.storage.decode(payload)
                if ix.storage.codec != "fp32":
                    lat.l2_dequant_s += ix.cost.dequant_latency(embs.size)
                resolved[cid] = embs
                return
        lat.degraded_clusters += 1
        empty = np.zeros((0, ix.dim), np.float32)
        resolved[cid] = SlabPayload("fp32", empty) if raw else empty

    # ------------------------------------------------------------------
    # packed-slab execution (the search_batch scoring engine)
    # ------------------------------------------------------------------
    def stale_cids(self, plan: ResolutionPlan) -> List[int]:
        """Planned clusters whose MEMBERSHIP/CONTENT moved since plan time
        — the staged pipeline's S3 entry check: payloads fetched at S2 for
        these clusters may no longer row-align, so the batch re-enters S1
        (re-plan + re-fetch) instead of packing a slab that would trip the
        pack-time defenses.  Storage-tier flips (a bubble-drain restore or
        drop bumping ``generation`` alone) deliberately do NOT count:
        payloads already in hand don't care where later fetches would come
        from, and counting them would make every in-flight plan stale the
        moment maintenance runs."""
        return [cid for cid in plan.owner
                if not plan.content_fresh(cid, self.index.clusters[cid])]

    def pack_slab(self, plan: ResolutionPlan,
                  payloads: Dict[int, object],
                  lats: List[LatencyBreakdown]) -> SlabLayout:
        """Pack resolved RAW payloads into a :class:`SlabLayout`: every
        cluster lands exactly once in the segment of its storage
        representation; the per-cluster payloads become views into the
        slab (:meth:`SlabLayout.view`).  Each cluster's owner is charged
        the pack copy (``l2_slab_pack_s``) and, for fp16/int8 payloads,
        the fused in-kernel decode (``l2_fused_dequant_s``) — once per
        slab, not once per probing query (the old path dequantized and
        re-concatenated shared clusters Q times over).  PQ payloads are
        charged the in-kernel code gather (``l2_pq_gather_s``, rows × m
        lookups) INSTEAD of a dequant: no decode ever happens.
        """
        ix = self.index
        slab = SlabLayout.pack(ix.dim, list(plan.owner), payloads,
                               lambda cid: ix.clusters[cid].ids)
        for cid, owner_qi in plan.owner.items():
            p = payloads[cid]
            if p.rows == 0:
                continue
            lat = lats[owner_qi]
            lat.l2_slab_pack_s += ix.cost.slab_pack_latency(p.nbytes)
            if p.kind == "pq":
                lat.l2_pq_gather_s += ix.cost.pq_gather_latency(p.emb.size)
            elif p.kind != "fp32":
                lat.l2_fused_dequant_s += ix.cost.fused_dequant_latency(
                    p.emb.size)
        return slab

    def execute_slab(self, plan: ResolutionPlan,
                     lats: List[LatencyBreakdown],
                     missed: List[bool]) -> SlabLayout:
        """RAW-mode :meth:`execute` + :meth:`pack_slab` in one step (the
        sequential path; the staged pipeline runs them as separate S2/S3
        stages so decode can overlap the fetch)."""
        payloads = self.execute(plan, lats, missed, raw=True)
        return self.pack_slab(plan, payloads, lats)

    # ------------------------------------------------------------------
    # regeneration (shared with the maintenance paths)
    # ------------------------------------------------------------------
    def _regen_group(self, cids: Sequence[int]):
        """ONE ``embed_fn`` call over the group's concatenated texts; yields
        (cid, embeddings view, char count) per cluster."""
        ix = self.index
        texts_per = [ix.get_chunks(ix.clusters[c].ids.tolist())
                     for c in cids]
        flat = [txt for ts in texts_per for txt in ts]
        embs_all = np.ascontiguousarray(ix.embed_fn(flat), np.float32)
        off = 0
        for cid, ts in zip(cids, texts_per):
            sub = embs_all[off:off + len(ts)]
            off += len(ts)
            yield cid, sub, sum(len(txt) for txt in ts)

    def regenerate(self, cids: Sequence[int]) -> List[np.ndarray]:
        """Coalesced regeneration outside a search (restore / split paths).
        No latency attribution, no cache interaction."""
        return [sub.copy() for _, sub, _ in self._regen_group(list(cids))]
