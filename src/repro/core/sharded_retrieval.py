"""Beyond-paper: pod-sharded second-level retrieval (DESIGN.md §2.1).

The paper's premise is one memory-starved device.  On a pod, EdgeRAG's
pruning is still what makes an index fit per-chip HBM next to the model —
and the second-level search itself parallelizes: candidate embeddings shard
round-robin over the "data" axis, every shard runs the fused top-k scan
over its local rows (the same ivf_topk hot loop the Pallas kernel
implements), and ONE all-gather of per-shard (k) candidates — k·shards
rows, not the corpus — merges globally.

Communication per query: shards × k × (4+4) bytes ≈ 16·10·8 = 1.3 kB.
A replicated scan would move nothing but duplicate ALL compute; gathering
raw candidates would move the whole probed set.  This is the standard
distributed-top-k trade.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.distributed import shard_map   # jax 0.4/0.5 compat shim

NEG_INF = -1e30


def sharded_topk_ip(embs, queries, k: int, mesh, axis: str = "data"
                    ) -> Tuple[jax.Array, jax.Array]:
    """embs (N, D) row-sharded over ``axis``; queries (Q, D) replicated.

    Returns (scores (Q, k), global row idx (Q, k)) — identical to
    kernels.ivf_topk.ops.topk_ip on the gathered matrix.
    """
    n, d = embs.shape
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    pad = (-n) % n_shards
    if pad:
        embs = jnp.pad(embs, ((0, pad), (0, 0)))
    n_padded = embs.shape[0]

    def local_fn(emb_loc, q):
        shard = jax.lax.axis_index(axis)
        s_rows = emb_loc.shape[0]
        scores = q.astype(jnp.float32) @ emb_loc.astype(jnp.float32).T
        base = shard * s_rows + jnp.arange(s_rows)
        scores = jnp.where((base < n)[None, :], scores, NEG_INF)
        kk = min(k, s_rows)
        vals, idx = jax.lax.top_k(scores, kk)              # (Q, kk) local
        gidx = base[idx]
        # gather the per-shard candidates everywhere, merge locally
        all_vals = jax.lax.all_gather(vals, axis, axis=1)  # (Q, S, kk)
        all_idx = jax.lax.all_gather(gidx, axis, axis=1)
        qn = all_vals.shape[0]
        flat_v = all_vals.reshape(qn, -1)
        flat_i = all_idx.reshape(qn, -1)
        mv, mi = jax.lax.top_k(flat_v, k)
        return mv, jnp.take_along_axis(flat_i, mi, axis=1).astype(jnp.int32)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=(P(), P()),
        check_vma=False)
    with mesh:
        return fn(embs, queries)


class ShardedFlatSearch:
    """Pod-scale exhaustive search service over a pruned-or-not corpus slab.

    Used by the pod serving story (examples) and as the reference
    implementation the Pallas ivf_topk kernel would back on real hardware.
    """

    def __init__(self, embeddings: np.ndarray, mesh, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis
        self.n = embeddings.shape[0]
        n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
        pad = (-self.n) % n_shards
        emb = np.pad(embeddings.astype(np.float32), ((0, pad), (0, 0)))
        sharding = NamedSharding(mesh, P(axis, None))
        self.embs = jax.device_put(jnp.asarray(emb), sharding)

    def search(self, queries: np.ndarray, k: int):
        q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        vals, idx = sharded_topk_ip(self.embs, q, k, self.mesh, self.axis)
        return np.asarray(vals), np.asarray(idx)
