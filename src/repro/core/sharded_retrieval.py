"""Beyond-paper: pod-sharded second-level retrieval (DESIGN.md §2.1).

The paper's premise is one memory-starved device.  On a pod, EdgeRAG's
pruning is still what makes an index fit per-chip HBM next to the model —
and the second-level search itself parallelizes: candidate embeddings shard
round-robin over the "data" axis, every shard runs the fused top-k scan
over its local rows (the same ivf_topk hot loop the Pallas kernel
implements), and ONE all-gather of per-shard (k) candidates — k·shards
rows, not the corpus — merges globally.

Communication per query: shards × k × (4+4) bytes ≈ 16·10·8 = 1.3 kB.
A replicated scan would move nothing but duplicate ALL compute; gathering
raw candidates would move the whole probed set.  This is the standard
distributed-top-k trade.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.slab_topk.ops import ROW_PAD
from repro.kernels.slab_topk.ref import NOT_PROBED
from repro.models.distributed import shard_map   # jax 0.4/0.5 compat shim

NEG_INF = -1e30


def sharded_topk_ip(embs, queries, k: int, mesh, axis: str = "data"
                    ) -> Tuple[jax.Array, jax.Array]:
    """embs (N, D) row-sharded over ``axis``; queries (Q, D) replicated.

    Returns (scores (Q, k), global row idx (Q, k)) — identical to
    kernels.ivf_topk.ops.topk_ip on the gathered matrix.
    """
    n, d = embs.shape
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    pad = (-n) % n_shards
    if pad:
        embs = jnp.pad(embs, ((0, pad), (0, 0)))
    n_padded = embs.shape[0]

    def local_fn(emb_loc, q):
        shard = jax.lax.axis_index(axis)
        s_rows = emb_loc.shape[0]
        scores = q.astype(jnp.float32) @ emb_loc.astype(jnp.float32).T
        base = shard * s_rows + jnp.arange(s_rows)
        scores = jnp.where((base < n)[None, :], scores, NEG_INF)
        kk = min(k, s_rows)
        vals, idx = jax.lax.top_k(scores, kk)              # (Q, kk) local
        gidx = base[idx]
        # gather the per-shard candidates everywhere, merge locally
        all_vals = jax.lax.all_gather(vals, axis, axis=1)  # (Q, S, kk)
        all_idx = jax.lax.all_gather(gidx, axis, axis=1)
        qn = all_vals.shape[0]
        flat_v = all_vals.reshape(qn, -1)
        flat_i = all_idx.reshape(qn, -1)
        mv, mi = jax.lax.top_k(flat_v, k)
        return mv, jnp.take_along_axis(flat_i, mi, axis=1).astype(jnp.int32)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=(P(), P()),
        check_vma=False)
    with mesh:
        return fn(embs, queries)


def sharded_slab_topk(emb, queries, virt, k: int, mesh, axis: str = "data",
                      scales=None, luts=None) -> Tuple[jax.Array, jax.Array]:
    """Pod-sharded ragged multi-query top-k over ONE packed slab per batch.

    The pre-slab sharded route issued one ``sharded_topk_ip`` per query
    over that query's re-concatenated clusters — Q all-gathers and Q
    copies of every shared cluster.  Here the batch's packed slab ``emb``
    (N, D; fp32/fp16/int8 — or (N, m) uint8 PQ codes when ``luts`` is
    given) row-shards over ``axis`` together with its membership matrix
    ``virt`` (Q, N, sharded on N) and optional per-row ``scales`` (N, 1);
    the per-query PQ LUTs (Q, m, 256) replicate like the queries they
    stand in for.  Every shard scores its local rows for ALL queries with
    fused dequant (or LUT gather+accumulate), selects its local best-k by
    (score desc, virt asc), and one all-gather of k·shards candidates per
    query merges globally under the same total order.  Results are
    identical to ``kernels.slab_topk.slab_topk`` on the unsharded slab.
    """
    n, d = emb.shape
    nq = virt.shape[0]
    if n == 0 or k == 0:
        return (jnp.full((nq, k), -np.inf, jnp.float32),
                jnp.full((nq, k), ROW_PAD, jnp.int32))
    k_eff = min(k, n)      # same clamp-and-pad contract as ops.slab_topk
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    pad = (-n) % n_shards
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0)))
        virt = jnp.pad(virt, ((0, 0), (0, pad)),
                       constant_values=NOT_PROBED)
        if scales is not None:
            scales = jnp.pad(scales, ((0, pad), (0, 0)))
    kk = min(k_eff, emb.shape[0] // n_shards)

    def local_fn(emb_loc, q, virt_loc, *extras):
        from repro.kernels.slab_topk.ref import lex_topk, pq_adc_scores
        shard = jax.lax.axis_index(axis)
        s_rows = emb_loc.shape[0]
        if luts is not None:
            scores = pq_adc_scores(emb_loc, extras[0].astype(jnp.float32))
        else:
            scores = q.astype(jnp.float32) @ emb_loc.astype(jnp.float32).T
            if extras:
                scores = scores * extras[0].astype(jnp.float32)[:, 0][None]
        masked = jnp.where(virt_loc < NOT_PROBED, scores, NEG_INF)
        # local best-kk by (score desc, virt asc)
        lvals, lidx = lex_topk(masked, virt_loc, kk)
        lvirt = jnp.take_along_axis(virt_loc, lidx, axis=1)
        lrows = shard * s_rows + lidx
        # gather the per-shard candidates everywhere, merge locally under
        # the SAME total order
        av = jax.lax.all_gather(lvals, axis, axis=1)        # (Q, S, kk)
        at = jax.lax.all_gather(lvirt, axis, axis=1)
        ar = jax.lax.all_gather(lrows, axis, axis=1)
        qn = av.shape[0]
        fv, ft, fr = (a.reshape(qn, -1) for a in (av, at, ar))
        mv, midx = lex_topk(fv, ft, k_eff)
        return mv, jnp.take_along_axis(fr, midx, axis=1).astype(jnp.int32)

    in_specs = [P(axis, None), P(None, None), P(None, axis)]
    operands = [emb, queries, virt]
    if luts is not None:
        in_specs.append(P(None, None, None))    # replicated, like queries
        operands.append(jnp.asarray(luts, jnp.float32))
    elif scales is not None:
        in_specs.append(P(axis, None))
        operands.append(scales)
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=tuple(in_specs), out_specs=(P(), P()),
                   check_vma=False)
    with mesh:
        vals, rows = fn(*operands)
    if k_eff < k:
        vals = jnp.pad(vals, ((0, 0), (0, k - k_eff)),
                       constant_values=-np.inf)
        rows = jnp.pad(rows, ((0, 0), (0, k - k_eff)),
                       constant_values=ROW_PAD)
    return vals, rows


class ShardedFlatSearch:
    """Pod-scale exhaustive search service over a pruned-or-not corpus slab.

    Used by the pod serving story (examples) and as the reference
    implementation the Pallas ivf_topk kernel would back on real hardware.
    """

    def __init__(self, embeddings: np.ndarray, mesh, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis
        self.n = embeddings.shape[0]
        n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
        pad = (-self.n) % n_shards
        emb = np.pad(embeddings.astype(np.float32), ((0, pad), (0, 0)))
        sharding = NamedSharding(mesh, P(axis, None))
        self.embs = jax.device_put(jnp.asarray(emb), sharding)

    def search(self, queries: np.ndarray, k: int):
        q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        vals, idx = sharded_topk_ip(self.embs, q, k, self.mesh, self.axis)
        return np.asarray(vals), np.asarray(idx)
