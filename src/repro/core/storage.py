"""Second-level embedding storage backend with quantized codecs.

Models the paper's split between DRAM (first-level centroids, cache) and
SD-card storage (precomputed heavy-cluster embeddings).  The "disk" flavor
actually writes .npz files so persistence is real; the "memory" flavor keeps
payloads in a dict (fast unit tests).  Either way the *edge* latency of a
load comes from the cost model, not this machine's SSD.

Codecs (beyond-paper: MobileRAG-style on-device memory budgeting): the
stored payload can be narrowed below fp32 —

  fp32   bit-exact roundtrip (default; keeps the Table-4 parity claims)
  fp16   half-precision embeddings                       (2x fewer bytes)
  int8   per-row symmetric int8 + fp16 scales, reusing
         models/quantization.py's KV-cache scheme        (~3.9x fewer bytes)
  pq     product quantization (core/pq.py): one uint8 code per subspace
         against a backend-held codebook                 (8-32x fewer bytes)

PQ CODEC: payloads are ``{"codes": uint8 (n, m), "cbv": version}`` — the
codebook itself lives on the backend (``self.pq``), trained once at index
build (``train_pq``) and persisted next to on-disk roots as
``pq_codebook.npz`` so a reopened root still decodes.  ``cbv`` pins each
blob to the codebook version that encoded it; after a drift retrain
(version bump) a stale blob fails its read like a corrupt one —
quarantine-dropped WITHOUT retries (the mismatch is deterministic) so the
resolver regenerates at full precision and self-heals a fresh copy under
the new codebook.  A ``put`` with no codebook yet lazily trains one on
that put's rows (standalone-backend convenience; the index trains on the
full corpus before its first put).

MODES: ``memory`` (dict), ``disk`` (.npz files), and ``memmap`` — disk
layout and crash-safe atomic writes, but reads return ``np.memmap`` views
into the uncompressed npz members instead of loading arrays, so a
100M-vector tier's payloads are never resident: ``get_many_raw`` hands the
slab packer memmap-backed payloads it slices, not copies.  Checksum
verification still touches every byte (it pages the mapping through the
OS cache — the integrity guarantee is kept deliberately); the win is that
nothing is ever *retained* in process memory.

``get``/``get_many`` always return contiguous f32 matrices (decode on
load); ``stored_bytes``/``total_bytes`` report the encoded payload size in
memory mode and the ``os.stat`` on-disk size in disk/memmap modes (what
the medium actually stores and a load actually streams) — byte accounting
NEVER reads payload data.

RAW-CODEC LOADS (``get_many_raw``): the packed-slab scoring engine scores
fp16/int8 clusters directly in their storage representation (fused
in-kernel dequantization, kernels/slab_topk), so it loads payloads
*undecoded*: ``get_many_raw`` returns each cluster's codec payload dict
exactly as stored — ``{"emb": f32|f16}`` or ``{"q": int8, "scale": f16}``
— with a missing key yielding ``None``, same ordering contract as
``get_many``.  Callers must treat the payload arrays as READ-ONLY (memory
mode hands out the live stored arrays, not copies); ``payload_rows`` gives
the row count without decoding and ``decode`` turns a raw payload into the
f32 matrix ``get`` would have returned.

FAILURE MODEL (core/faults.py): every ``put`` stores a per-key CRC-32
checksum alongside the payload (a ``"crc"`` member, stripped before any
payload reaches a caller and excluded from byte accounting) and every load
verifies it, so a bit-flipped or truncated blob — real or injected — is
always detected, never silently scored.  ``get`` / ``get_many`` /
``get_many_raw`` retry failed reads up to ``retry_limit`` times with
exponential backoff (``backoff_base_s * 2**attempt`` MODELED edge seconds,
no real sleep); per-key costs land in the caller-supplied
:class:`~repro.core.faults.IOOutcome` list and aggregate in ``io_stats``.
After retries exhaust, the read degrades to a missing key (``None`` /
``KeyError``) so callers fall back to regeneration; a checksum failure
that survives every retry additionally QUARANTINE-DROPS the blob, so the
resolver's Alg. 1 self-heal re-persists a fresh copy instead of re-reading
rot forever.  A genuinely absent key is returned immediately without
retries (today's semantics).  Setting ``self.faults`` to a
:class:`~repro.core.faults.FaultInjector` makes reads go through its
deterministic fault/stall model; ``None`` (default) leaves the fast path
byte-identical to the pre-fault-model backend.

Disk-mode ``put`` is CRASH-SAFE: the payload is written to a temp file in
the same directory and atomically ``os.replace``d over the key's path, so
an interrupted write can never leave a torn payload behind (and a torn
file from an older writer is caught by the checksum / container parse and
degrades like any corrupt blob).

MULTI-TENANCY: keys may be plain ints (single-tenant, the historical
contract — paths and accounting unchanged) or ``(tenant, cid)`` tuples.
Tuple keys land in per-tenant ``tenant_<name>/`` subdirectories on disk and
are first-class dict keys in memory mode; ``keys()`` enumerates both forms.
:class:`TenantStorageView` gives one tenant an int-keyed facade over a
shared backend so :class:`~repro.core.edgerag.EdgeRAGIndex` needs no
changes to run on shared storage.  ``budget_bytes`` imposes a SHARED byte
budget across every key (all tenants): a ``put`` that would exceed it
refuses — stores nothing, returns 0, bumps ``io_stats["put_rejected"]`` —
and the caller keeps the cluster on the regeneration path.  The budget is
an in-process quota over bytes this instance knows about (its own writes
plus lazily discovered pre-existing blobs), not an fsck of the root.

ROOT COLLISION GUARD: memory mode has always refused to touch a filesystem
root at all (``_path`` raises).  Disk mode extends that safety to WRITERS:
the first ``put`` claims the ``(root, namespace)`` slot in a process-wide
registry, and a second live instance writing to the same slot raises
``RuntimeError`` instead of silently interleaving blobs with the first.
Reopening a root read-only (metadata/get) never claims, and a dead writer's
claim expires with it.  Pass distinct ``namespace=`` strings (each gets its
own subdirectory of ``root``) to intentionally co-locate several stores
under one root.
"""
from __future__ import annotations

import os
import re
import struct
import tempfile
import weakref
import zipfile
import zlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.faults import (CorruptPayloadError, FaultInjector,
                               InjectedFault, IOOutcome)
from repro.core.pq import (PQCodebook, codebook_from_payload,
                           codebook_to_payload, pq_decode, pq_encode,
                           train_pq)

CODECS = ("fp32", "fp16", "int8", "pq")
MODES = ("memory", "disk", "memmap")
_CODEBOOK_FILE = "pq_codebook.npz"


class StaleCodebookError(CorruptPayloadError):
    """PQ payload encoded under an older codebook version.  Deterministic —
    retrying the read cannot help — so reads skip the backoff ladder and
    quarantine-drop immediately, putting the cluster on the regen +
    re-encode self-heal path."""

_CLUSTER_FILE = re.compile(r"^cluster_(\d+)\.npz$")
_TENANT_DIR = re.compile(r"^tenant_([A-Za-z0-9._-]+)$")
# tmp files OUR writers leave behind when a put/train dies mid-write —
# the only .tmp names clear() is allowed to sweep (foreign files stay)
_STALE_TMP = re.compile(r"^(cluster_\d+\.npz|pq_codebook\.npz)\.tmp$")
_NAMESPACE_RE = re.compile(r"^[A-Za-z0-9._-]*$")
_CHECKSUM_KEY = "crc"

#: blob key: a bare cluster id, or ``(tenant, cid)`` on a shared backend
StorageKey = Union[int, Tuple[str, int]]


def payload_checksum(payload: Dict[str, np.ndarray]) -> int:
    """CRC-32 over the payload's arrays (name, dtype, shape, data) — any
    single bit flip or truncation changes it."""
    crc = 0
    for name in sorted(payload):
        a = np.ascontiguousarray(payload[name])
        crc = zlib.crc32(f"{name}:{a.dtype.str}:{a.shape}".encode(), crc)
        crc = zlib.crc32(a.view(np.uint8).reshape(-1), crc)
    return crc


class StorageBackend:
    """Keyed blob store for per-cluster embedding matrices."""

    # live disk WRITERS by (realpath(root), namespace); weakrefs so a
    # garbage-collected writer releases its claim (module docstring)
    _disk_claims: Dict[Tuple[str, str], "weakref.ref[StorageBackend]"] = {}

    def __init__(self, mode: str = "memory", root: Optional[str] = None,
                 codec: str = "fp32", *, retry_limit: int = 3,
                 backoff_base_s: float = 0.002, namespace: str = "",
                 budget_bytes: Optional[int] = None, pq_m: int = 8):
        assert mode in MODES, f"mode must be one of {MODES}, got {mode}"
        assert codec in CODECS, f"codec must be one of {CODECS}, got {codec}"
        assert _NAMESPACE_RE.match(namespace), \
            f"namespace must match [A-Za-z0-9._-]*, got {namespace!r}"
        self.mode = mode
        self.codec = codec
        self.namespace = namespace
        self.budget_bytes = budget_bytes
        self.pq_m = pq_m
        self.pq: Optional[PQCodebook] = None
        self._mem: Dict[StorageKey, Dict[str, np.ndarray]] = {}
        self._nbytes: Dict[StorageKey, int] = {}    # stored payload bytes
        self._crcs: Dict[StorageKey, int] = {}      # payload CRC at put time
        self.root: Optional[str] = None
        self._base: Optional[str] = None            # root[/namespace]
        if mode != "memory":
            self.root = root or tempfile.mkdtemp(prefix="edgerag_store_")
            self._base = (os.path.join(self.root, namespace) if namespace
                          else self.root)
            os.makedirs(self._base, exist_ok=True)
            cb_path = os.path.join(self._base, _CODEBOOK_FILE)
            if os.path.exists(cb_path):      # reopened root: restore codebook
                with np.load(cb_path) as z:
                    self.pq = codebook_from_payload(
                        {name: z[name] for name in z.files})
        # failure model (module docstring): injector hook + retry policy
        self.faults: Optional[FaultInjector] = None
        self.retry_limit = retry_limit
        self.backoff_base_s = backoff_base_s
        self.io_stats: Dict[str, float] = {
            "reads": 0, "verified": 0, "failed_attempts": 0, "retries": 0,
            "exhausted": 0, "corrupt_dropped": 0, "backoff_s": 0.0,
            "stall_s": 0.0, "put_rejected": 0}

    # ---- codec ----------------------------------------------------------
    def _encode(self, emb: np.ndarray) -> Dict[str, np.ndarray]:
        emb = np.ascontiguousarray(emb, np.float32)
        if self.codec == "fp32":
            return {"emb": emb}
        if self.codec == "fp16":
            return {"emb": emb.astype(np.float16)}
        if self.codec == "pq":
            if self.pq is None:      # standalone-backend convenience: the
                self.train_pq(emb)   # index trains on the corpus at build
            return {"codes": pq_encode(self.pq, emb),
                    "cbv": np.array([self.pq.version], np.int32)}
        from repro.models.quantization import quantize_rows
        q, scale = quantize_rows(emb)
        return {"q": q, "scale": scale}

    def _decode(self, payload: Dict[str, np.ndarray]) -> np.ndarray:
        if "q" in payload:
            from repro.models.quantization import dequantize_rows
            return dequantize_rows(payload["q"], payload["scale"])
        if "codes" in payload:
            if self.pq is None:
                raise CorruptPayloadError(
                    "pq payload but no codebook on this backend")
            return pq_decode(self.pq, payload["codes"])
        return np.ascontiguousarray(payload["emb"], np.float32)

    def decode(self, payload: Dict[str, np.ndarray]) -> np.ndarray:
        """Decode a raw payload (from ``get_many_raw``) to f32 (n, d)."""
        return self._decode(payload)

    @staticmethod
    def payload_rows(payload: Dict[str, np.ndarray]) -> int:
        """Row count of a raw payload without decoding it."""
        if "q" in payload:
            return len(payload["q"])
        if "codes" in payload:
            return len(payload["codes"])
        return len(payload["emb"])

    # ---- PQ codebook lifecycle ------------------------------------------
    def train_pq(self, embeddings: np.ndarray, *, iters: int = 12,
                 seed: int = 0) -> PQCodebook:
        """(Re)train the product-quantization codebook on ``embeddings``.

        First call -> version 0; later calls (drift retrains) bump the
        version, which invalidates every blob encoded under the old one:
        their next read raises :class:`StaleCodebookError`, quarantine-
        drops, and the resolver self-heals a fresh copy.  On-disk modes
        persist the codebook next to the root so reopens decode."""
        version = 0 if self.pq is None else self.pq.version + 1
        self.pq = train_pq(embeddings, m=self.pq_m, iters=iters, seed=seed,
                           version=version)
        if self.mode != "memory":
            self._claim_root()
            cb_path = os.path.join(self._base, _CODEBOOK_FILE)
            tmp = cb_path + ".tmp"
            try:
                with open(tmp, "wb") as f:
                    np.savez(f, **codebook_to_payload(self.pq))
                os.replace(tmp, cb_path)
            except BaseException:
                if os.path.exists(tmp):
                    os.remove(tmp)
                raise
        return self.pq

    # ---- filesystem (disk mode only) ------------------------------------
    def _path(self, key: StorageKey) -> str:
        if self.root is None:
            raise RuntimeError(
                "memory-mode StorageBackend has no filesystem root")
        if isinstance(key, tuple):
            tenant, cid = key
            return os.path.join(self._base, f"tenant_{tenant}",
                                f"cluster_{cid}.npz")
        return os.path.join(self._base, f"cluster_{key}.npz")

    def _claim_root(self):
        """First write claims the ``(root, namespace)`` slot; a second LIVE
        writer on the same slot is a collision, not a merge (module
        docstring).  Read-only reopens never claim."""
        slot = (os.path.realpath(self.root), self.namespace)
        ref = StorageBackend._disk_claims.get(slot)
        owner = ref() if ref is not None else None
        if owner is not None and owner is not self:
            raise RuntimeError(
                f"storage root collision: another live StorageBackend is "
                f"already writing to root={self.root!r} "
                f"namespace={self.namespace!r}; their blobs would silently "
                f"overwrite each other — give each writer its own "
                f"namespace= (or root)")
        StorageBackend._disk_claims[slot] = weakref.ref(self)

    def _load(self, key: int) -> Optional[Dict[str, np.ndarray]]:
        """Raw physical read (checksum member included).  A present-but-
        unreadable disk blob (torn container) raises
        :class:`CorruptPayloadError` instead of propagating zip/npy
        internals."""
        if self.mode == "memory":
            return self._mem.get(key)
        path = self._path(key)
        if not os.path.exists(path):
            return None
        if self.mode == "memmap":
            return self._load_memmap(path, key)
        try:
            with np.load(path) as z:
                return {name: z[name] for name in z.files}
        except Exception as e:
            raise CorruptPayloadError(f"unreadable blob for key {key}: {e}")

    @staticmethod
    def _load_memmap(path: str, key: StorageKey
                     ) -> Dict[str, np.ndarray]:
        """Open an npz as read-only ``np.memmap`` views, one per member.

        ``np.savez`` stores members uncompressed (ZIP_STORED), so each
        array's data is a contiguous byte range of the container file:
        local-file-header offset + 30 + name/extra lengths + the .npy
        header.  Mapping that range gives a zero-copy view — nothing is
        read until a consumer touches pages (CRC verification does, by
        design; slab packing slices first and touches only what it
        scores)."""
        try:
            out: Dict[str, np.ndarray] = {}
            with zipfile.ZipFile(path) as z, open(path, "rb") as raw:
                for info in z.infolist():
                    name = info.filename
                    if name.endswith(".npy"):
                        name = name[:-4]
                    with z.open(info) as f:
                        version = np.lib.format.read_magic(f)
                        read_header = getattr(
                            np.lib.format,
                            "read_array_header_%d_%d" % version)
                        shape, fortran, dtype = read_header(f)
                        header_len = f.tell()
                    if info.compress_type != zipfile.ZIP_STORED or fortran:
                        raise ValueError(
                            f"member {name} is not memmap-able")
                    # the central directory's header_offset points at the
                    # local file header: 30 fixed bytes, then name + extra
                    raw.seek(info.header_offset + 26)
                    n_name, n_extra = struct.unpack("<HH", raw.read(4))
                    offset = (info.header_offset + 30 + n_name + n_extra
                              + header_len)
                    if int(np.prod(shape, dtype=np.int64)) == 0:
                        out[name] = np.empty(shape, dtype)
                    else:
                        out[name] = np.memmap(path, mode="r", dtype=dtype,
                                              shape=tuple(shape),
                                              offset=offset)
            return out
        except Exception as e:
            raise CorruptPayloadError(f"unreadable blob for key {key}: {e}")

    # ---- verified / retried reads ----------------------------------------
    def _read_once(self, key: int, outcome: IOOutcome
                   ) -> Optional[Dict[str, np.ndarray]]:
        """One read attempt: physical load, injected faults, checksum
        verification.  Returns the CRC-stripped payload, ``None`` for a
        genuinely absent key, or raises the attempt's failure."""
        payload = self._load(key)
        if payload is None:
            return None
        if self.faults is not None:
            payload = self.faults.perturb(key, payload, outcome)
        crc = payload.get(_CHECKSUM_KEY)
        if crc is None:                 # legacy blob: unverifiable
            return payload
        body = {k: v for k, v in payload.items() if k != _CHECKSUM_KEY}
        if payload_checksum(body) != int(np.asarray(crc).reshape(-1)[0]):
            raise CorruptPayloadError(key)
        if "codes" in body and self.pq is not None:
            cbv = int(np.asarray(body.get("cbv", -1)).reshape(-1)[0])
            if cbv != self.pq.version:
                raise StaleCodebookError(key)
        self.io_stats["verified"] += 1
        return body

    def _load_checked(self, key: int, outcome: IOOutcome
                      ) -> Optional[Dict[str, np.ndarray]]:
        """Bounded retry-with-exponential-backoff around :meth:`_read_once`
        (module docstring).  Backoff is MODELED edge seconds recorded on
        ``outcome``, never a real sleep."""
        self.io_stats["reads"] += 1
        last_err: Optional[str] = None
        for attempt in range(self.retry_limit + 1):
            if attempt:
                backoff = self.backoff_base_s * (2 ** (attempt - 1))
                outcome.retries += 1
                outcome.backoff_s += backoff
                self.io_stats["retries"] += 1
                self.io_stats["backoff_s"] += backoff
            try:
                payload = self._read_once(key, outcome)
            except StaleCodebookError:
                # deterministic mismatch: retries cannot help, fall through
                # to the quarantine-drop below without burning backoff
                last_err = "corrupt"
                self.io_stats["failed_attempts"] += 1
                break
            except CorruptPayloadError:
                last_err = "corrupt"
            except InjectedFault as e:
                last_err = "io" if isinstance(e, IOError) else "missing"
            else:
                if payload is not None:
                    self.io_stats["stall_s"] += outcome.stall_s
                    return payload
                # genuinely absent (the blob is not there, faulty or not):
                # retrying cannot help — degrade immediately, as before
                outcome.ok = False
                outcome.error = "missing"
                self.io_stats["stall_s"] += outcome.stall_s
                return None
            self.io_stats["failed_attempts"] += 1
        outcome.ok = False
        outcome.error = last_err
        self.io_stats["exhausted"] += 1
        self.io_stats["stall_s"] += outcome.stall_s
        if last_err == "corrupt":
            # quarantine-drop the rotten blob: the caller regenerates and
            # the resolver's Alg. 1 self-heal re-persists a fresh copy
            self.io_stats["corrupt_dropped"] += 1
            self.delete(key)
        return None

    # ---- public API ------------------------------------------------------
    def put(self, key: StorageKey, embeddings: np.ndarray) -> int:
        """Returns the stored byte size — exact encoded payload bytes in
        memory mode, the ``os.stat`` on-disk file size in disk/memmap
        modes (container + checksum included: what the medium holds) — or
        0 if the shared ``budget_bytes`` refused the write (nothing
        stored; the caller keeps the cluster on the regen path).  On-disk
        writes are atomic: temp file + ``os.replace``, so a crash
        mid-write never tears the blob."""
        payload = self._encode(embeddings)
        nbytes = sum(a.nbytes for a in payload.values())
        if self.budget_bytes is not None:
            used = sum(self._nbytes.values()) - self._nbytes.get(key, 0)
            if used + nbytes > self.budget_bytes:
                self.io_stats["put_rejected"] += 1
                return 0
        crc = payload_checksum(payload)
        stored = dict(payload)
        stored[_CHECKSUM_KEY] = np.array([crc], np.uint32)
        if self.mode == "memory":
            self._mem[key] = stored
        else:
            self._claim_root()
            path = self._path(key)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp"
            try:
                with open(tmp, "wb") as f:
                    np.savez(f, **stored)
                os.replace(tmp, path)
            except BaseException:
                if os.path.exists(tmp):
                    os.remove(tmp)
                raise
            nbytes = os.stat(path).st_size
        self._nbytes[key] = nbytes
        self._crcs[key] = crc
        return self._nbytes[key]

    def get(self, key: int) -> np.ndarray:
        payload = self._load_checked(key, IOOutcome(key))
        if payload is None:
            raise KeyError(key)
        return self._decode(payload)

    def get_many(self, keys: Sequence[int],
                 outcomes: Optional[List[IOOutcome]] = None
                 ) -> List[Optional[np.ndarray]]:
        """Batched load, results in ``keys`` order; a missing key — or one
        whose reads exhausted their retries — yields ``None`` (callers fall
        back to regeneration instead of crashing).  ``outcomes`` collects
        one :class:`IOOutcome` per key (retries / stall / backoff)."""
        out: List[Optional[np.ndarray]] = []
        for key in keys:
            o = IOOutcome(key)
            payload = self._load_checked(key, o)
            if outcomes is not None:
                outcomes.append(o)
            out.append(None if payload is None else self._decode(payload))
        return out

    def get_many_raw(self, keys: Sequence[int],
                     outcomes: Optional[List[IOOutcome]] = None
                     ) -> List[Optional[Dict[str, np.ndarray]]]:
        """Batched load of UNDECODED codec payloads, results in ``keys``
        order, missing/exhausted key -> ``None`` (see module docstring:
        payloads are read-only; the slab scorer consumes them via fused
        dequant).  Checksums are verified and stripped; ``outcomes``
        collects per-key :class:`IOOutcome` records."""
        out: List[Optional[Dict[str, np.ndarray]]] = []
        for key in keys:
            o = IOOutcome(key)
            out.append(self._load_checked(key, o))
            if outcomes is not None:
                outcomes.append(o)
        return out

    def payload_crc(self, key: StorageKey) -> int:
        """CRC-32 of the stored payload, WITHOUT reading the payload data:
        the ``"crc"`` member recorded at put time (cached per key; a fresh
        instance on an old root lazily reads just that member from the
        container).  Raises ``KeyError`` for an absent or unreadable blob.
        This is what crash recovery (core/durability.py) compares against
        the manifest's recorded checksum to detect a blob that was
        replaced mid-op before the WAL record landed."""
        if key in self._crcs:
            return self._crcs[key]
        if self.mode == "memory":
            if key not in self._mem:
                raise KeyError(key)
            crc = int(np.asarray(
                self._mem[key][_CHECKSUM_KEY]).reshape(-1)[0])
        else:
            try:
                with np.load(self._path(key)) as z:
                    crc = int(np.asarray(z[_CHECKSUM_KEY]).reshape(-1)[0])
            except Exception:
                raise KeyError(key)
        self._crcs[key] = crc
        return crc

    def delete(self, key: int):
        self._nbytes.pop(key, None)
        self._crcs.pop(key, None)
        if self.mode == "memory":
            self._mem.pop(key, None)
            return
        path = self._path(key)
        if os.path.exists(path):
            os.remove(path)
        # a crashed put can strand its temp file next to the blob: sweep it
        # so the directory never accumulates torn garbage
        if os.path.exists(path + ".tmp"):
            os.remove(path + ".tmp")

    def clear(self):
        """Drop every stored cluster (index rebuilds) — plus, on disk
        roots, the persisted PQ codebook file and any stale ``.tmp`` files
        a crashed put left behind, so a rebuild on this root never decodes
        against a leftover codebook version or trips over torn garbage.
        (The in-memory codebook is kept: a rebuild's ``train_pq`` bumps
        its version, preserving the stale-blob invalidation semantics.)"""
        for key in self.keys():
            self.delete(key)
        self._nbytes.clear()
        self._crcs.clear()
        if self.mode == "memory":
            return
        cb_path = os.path.join(self._base, _CODEBOOK_FILE)
        if os.path.exists(cb_path):
            os.remove(cb_path)
        dirs = [self._base] + [
            os.path.join(self._base, e) for e in os.listdir(self._base)
            if _TENANT_DIR.match(e)
            and os.path.isdir(os.path.join(self._base, e))]
        for d in dirs:
            for f in os.listdir(d):
                if _STALE_TMP.match(f):
                    os.remove(os.path.join(d, f))

    def __contains__(self, key: StorageKey) -> bool:
        if self.mode == "memory":
            return key in self._mem
        return os.path.exists(self._path(key))

    def keys(self) -> List[StorageKey]:
        if self.mode == "memory":
            return list(self._mem)
        # foreign files in a user-supplied root are not ours to touch:
        # only cluster_<n>.npz blobs and tenant_<name>/ subdirectories
        # of our base directory are enumerated
        out: List[StorageKey] = [
            int(m.group(1)) for m in
            (_CLUSTER_FILE.match(f) for f in os.listdir(self._base)) if m]
        for entry in os.listdir(self._base):
            td = _TENANT_DIR.match(entry)
            if not td or not os.path.isdir(os.path.join(self._base, entry)):
                continue
            tenant = td.group(1)
            for f in os.listdir(os.path.join(self._base, entry)):
                m = _CLUSTER_FILE.match(f)
                if m:
                    out.append((tenant, int(m.group(1))))
        return out

    def stored_bytes(self, key: int) -> int:
        """Stored bytes of one cluster (what a load streams): exact encoded
        bytes in memory mode, the on-disk file size otherwise."""
        if key not in self._nbytes:       # e.g. fresh instance on an old root
            if self.mode == "memory":
                if key not in self._mem:
                    raise KeyError(key)
                self._nbytes[key] = sum(
                    a.nbytes for name, a in self._mem[key].items()
                    if name != _CHECKSUM_KEY)
            else:
                self._nbytes[key] = self._disk_payload_nbytes(key)
        return self._nbytes[key]

    def _disk_payload_nbytes(self, key: int) -> int:
        """On-disk size via ``os.stat`` — byte accounting must never READ
        the payload (at memmap scale, opening and parsing every blob to
        count bytes would page the whole tier through memory).  The stat
        size is also the honest number: container framing and the CRC
        member are bytes the medium stores and a load streams."""
        try:
            return os.stat(self._path(key)).st_size
        except OSError:
            raise KeyError(key)

    def total_bytes(self) -> int:
        return sum(self.stored_bytes(k) for k in self.keys())

    def tenant_bytes(self, tenant: str) -> int:
        """Encoded bytes held under one tenant's ``(tenant, cid)`` keys."""
        return sum(self.stored_bytes(k) for k in self.keys()
                   if isinstance(k, tuple) and k[0] == tenant)


class TenantStorageView:
    """One tenant's int-keyed facade over a SHARED :class:`StorageBackend`.

    Every cluster id is rewritten to ``(tenant, cid)`` before it reaches
    the backend, so an :class:`~repro.core.edgerag.EdgeRAGIndex` holding a
    view is oblivious to its neighbors while all tenants' blobs compete for
    the backend's one ``budget_bytes`` quota.  ``keys`` / ``clear`` /
    ``total_bytes`` are scoped to this tenant; ``io_stats`` and ``faults``
    are the backend's (the device has one storage medium — faults and IO
    accounting are physical, not per-tenant)."""

    def __init__(self, backend: StorageBackend, tenant: str):
        self.backend = backend
        self.tenant = str(tenant)

    def _k(self, cid: int) -> Tuple[str, int]:
        return (self.tenant, int(cid))

    # shared physical properties ------------------------------------------
    @property
    def mode(self) -> str:
        return self.backend.mode

    @property
    def codec(self) -> str:
        return self.backend.codec

    @property
    def root(self) -> Optional[str]:
        return self.backend.root

    @property
    def io_stats(self) -> Dict[str, float]:
        return self.backend.io_stats

    @property
    def faults(self) -> Optional[FaultInjector]:
        return self.backend.faults

    @faults.setter
    def faults(self, injector: Optional[FaultInjector]):
        self.backend.faults = injector

    @property
    def pq(self) -> Optional[PQCodebook]:
        """The SHARED product-quantization codebook (one physical medium,
        one codebook — tenants share it like they share ``io_stats``)."""
        return self.backend.pq

    def train_pq(self, embeddings: np.ndarray, **kw) -> PQCodebook:
        return self.backend.train_pq(embeddings, **kw)

    # key-mapped blob API --------------------------------------------------
    def put(self, cid: int, embeddings: np.ndarray) -> int:
        return self.backend.put(self._k(cid), embeddings)

    def get(self, cid: int) -> np.ndarray:
        try:
            return self.backend.get(self._k(cid))
        except KeyError:
            raise KeyError(cid)

    def get_many(self, cids: Sequence[int],
                 outcomes: Optional[List[IOOutcome]] = None
                 ) -> List[Optional[np.ndarray]]:
        return self.backend.get_many([self._k(c) for c in cids], outcomes)

    def get_many_raw(self, cids: Sequence[int],
                     outcomes: Optional[List[IOOutcome]] = None
                     ) -> List[Optional[Dict[str, np.ndarray]]]:
        return self.backend.get_many_raw([self._k(c) for c in cids],
                                         outcomes)

    def delete(self, cid: int):
        self.backend.delete(self._k(cid))

    def __contains__(self, cid: int) -> bool:
        return self._k(cid) in self.backend

    def keys(self) -> List[int]:
        return [k[1] for k in self.backend.keys()
                if isinstance(k, tuple) and k[0] == self.tenant]

    def clear(self):
        """Drop THIS tenant's blobs only (its index rebuilds)."""
        for cid in self.keys():
            self.delete(cid)

    def stored_bytes(self, cid: int) -> int:
        try:
            return self.backend.stored_bytes(self._k(cid))
        except KeyError:
            raise KeyError(cid)

    def payload_crc(self, cid: int) -> int:
        try:
            return self.backend.payload_crc(self._k(cid))
        except KeyError:
            raise KeyError(cid)

    def total_bytes(self) -> int:
        return self.backend.tenant_bytes(self.tenant)

    def decode(self, payload: Dict[str, np.ndarray]) -> np.ndarray:
        return self.backend.decode(payload)

    @staticmethod
    def payload_rows(payload: Dict[str, np.ndarray]) -> int:
        return StorageBackend.payload_rows(payload)
