"""Second-level embedding storage backend with quantized codecs.

Models the paper's split between DRAM (first-level centroids, cache) and
SD-card storage (precomputed heavy-cluster embeddings).  The "disk" flavor
actually writes .npz files so persistence is real; the "memory" flavor keeps
payloads in a dict (fast unit tests).  Either way the *edge* latency of a
load comes from the cost model, not this machine's SSD.

Codecs (beyond-paper: MobileRAG-style on-device memory budgeting): the
stored payload can be narrowed below fp32 —

  fp32   bit-exact roundtrip (default; keeps the Table-4 parity claims)
  fp16   half-precision embeddings                       (2x fewer bytes)
  int8   per-row symmetric int8 + fp16 scales, reusing
         models/quantization.py's KV-cache scheme        (~3.9x fewer bytes)

``get``/``get_many`` always return contiguous f32 matrices (decode on
load); ``stored_bytes``/``total_bytes`` report the *encoded* payload size,
which is what the cost model charges for a storage load.

RAW-CODEC LOADS (``get_many_raw``): the packed-slab scoring engine scores
fp16/int8 clusters directly in their storage representation (fused
in-kernel dequantization, kernels/slab_topk), so it loads payloads
*undecoded*: ``get_many_raw`` returns each cluster's codec payload dict
exactly as stored — ``{"emb": f32|f16}`` or ``{"q": int8, "scale": f16}``
— with a missing key yielding ``None``, same ordering contract as
``get_many``.  Callers must treat the payload arrays as READ-ONLY (memory
mode hands out the live stored arrays, not copies); ``payload_rows`` gives
the row count without decoding and ``decode`` turns a raw payload into the
f32 matrix ``get`` would have returned.
"""
from __future__ import annotations

import os
import re
import tempfile
import zipfile
from typing import Dict, List, Optional, Sequence

import numpy as np

CODECS = ("fp32", "fp16", "int8")

_CLUSTER_FILE = re.compile(r"^cluster_(\d+)\.npz$")


class StorageBackend:
    """Keyed blob store for per-cluster embedding matrices."""

    def __init__(self, mode: str = "memory", root: Optional[str] = None,
                 codec: str = "fp32"):
        assert mode in ("memory", "disk")
        assert codec in CODECS, f"codec must be one of {CODECS}, got {codec}"
        self.mode = mode
        self.codec = codec
        self._mem: Dict[int, Dict[str, np.ndarray]] = {}
        self._nbytes: Dict[int, int] = {}       # encoded payload bytes
        self.root: Optional[str] = None
        if mode == "disk":
            self.root = root or tempfile.mkdtemp(prefix="edgerag_store_")
            os.makedirs(self.root, exist_ok=True)

    # ---- codec ----------------------------------------------------------
    def _encode(self, emb: np.ndarray) -> Dict[str, np.ndarray]:
        emb = np.ascontiguousarray(emb, np.float32)
        if self.codec == "fp32":
            return {"emb": emb}
        if self.codec == "fp16":
            return {"emb": emb.astype(np.float16)}
        from repro.models.quantization import quantize_rows
        q, scale = quantize_rows(emb)
        return {"q": q, "scale": scale}

    def _decode(self, payload: Dict[str, np.ndarray]) -> np.ndarray:
        if "q" in payload:
            from repro.models.quantization import dequantize_rows
            return dequantize_rows(payload["q"], payload["scale"])
        return np.ascontiguousarray(payload["emb"], np.float32)

    def decode(self, payload: Dict[str, np.ndarray]) -> np.ndarray:
        """Decode a raw payload (from ``get_many_raw``) to f32 (n, d)."""
        return self._decode(payload)

    @staticmethod
    def payload_rows(payload: Dict[str, np.ndarray]) -> int:
        """Row count of a raw payload without decoding it."""
        return len(payload["q"] if "q" in payload else payload["emb"])

    # ---- filesystem (disk mode only) ------------------------------------
    def _path(self, key: int) -> str:
        if self.root is None:
            raise RuntimeError(
                "memory-mode StorageBackend has no filesystem root")
        return os.path.join(self.root, f"cluster_{key}.npz")

    def _load(self, key: int) -> Optional[Dict[str, np.ndarray]]:
        if self.mode == "memory":
            return self._mem.get(key)
        path = self._path(key)
        if not os.path.exists(path):
            return None
        with np.load(path) as z:
            return {name: z[name] for name in z.files}

    # ---- public API ------------------------------------------------------
    def put(self, key: int, embeddings: np.ndarray) -> int:
        """Returns encoded (stored) byte size."""
        payload = self._encode(embeddings)
        self._nbytes[key] = sum(a.nbytes for a in payload.values())
        if self.mode == "memory":
            self._mem[key] = payload
        else:
            np.savez(self._path(key), **payload)
        return self._nbytes[key]

    def get(self, key: int) -> np.ndarray:
        payload = self._load(key)
        if payload is None:
            raise KeyError(key)
        return self._decode(payload)

    def get_many(self, keys: Sequence[int]) -> List[Optional[np.ndarray]]:
        """Batched load, results in ``keys`` order; a missing key yields
        ``None`` (callers fall back to regeneration instead of crashing)."""
        out: List[Optional[np.ndarray]] = []
        for key in keys:
            payload = self._load(key)
            out.append(None if payload is None else self._decode(payload))
        return out

    def get_many_raw(self, keys: Sequence[int]
                     ) -> List[Optional[Dict[str, np.ndarray]]]:
        """Batched load of UNDECODED codec payloads, results in ``keys``
        order, missing key -> ``None`` (see module docstring: payloads are
        read-only; the slab scorer consumes them via fused dequant)."""
        return [self._load(key) for key in keys]

    def delete(self, key: int):
        self._nbytes.pop(key, None)
        if self.mode == "memory":
            self._mem.pop(key, None)
        elif os.path.exists(self._path(key)):
            os.remove(self._path(key))

    def clear(self):
        """Drop every stored cluster (index rebuilds)."""
        for key in self.keys():
            self.delete(key)
        self._nbytes.clear()

    def __contains__(self, key: int) -> bool:
        if self.mode == "memory":
            return key in self._mem
        return os.path.exists(self._path(key))

    def keys(self) -> List[int]:
        if self.mode == "memory":
            return list(self._mem)
        # foreign files in a user-supplied root are not ours to touch
        return [int(m.group(1)) for m in
                (_CLUSTER_FILE.match(f) for f in os.listdir(self.root)) if m]

    def stored_bytes(self, key: int) -> int:
        """Encoded payload bytes of one cluster (what a load streams)."""
        if key not in self._nbytes:       # e.g. fresh instance on an old root
            if self.mode == "memory":
                if key not in self._mem:
                    raise KeyError(key)
                self._nbytes[key] = sum(a.nbytes
                                        for a in self._mem[key].values())
            else:
                self._nbytes[key] = self._disk_payload_nbytes(key)
        return self._nbytes[key]

    def _disk_payload_nbytes(self, key: int) -> int:
        """Payload size from the .npy headers inside the zip — no array
        data is read (total_bytes on a reopened root stays a metadata
        query, not an O(store) load)."""
        path = self._path(key)
        if not os.path.exists(path):
            raise KeyError(key)
        total = 0
        with zipfile.ZipFile(path) as z:
            for name in z.namelist():
                with z.open(name) as f:
                    version = np.lib.format.read_magic(f)
                    read_header = getattr(
                        np.lib.format,
                        "read_array_header_%d_%d" % version)
                    shape, _, dtype = read_header(f)
                    total += int(np.prod(shape, dtype=np.int64)
                                 * dtype.itemsize)
        return total

    def total_bytes(self) -> int:
        return sum(self.stored_bytes(k) for k in self.keys())
