"""Second-level embedding storage backend.

Models the paper's split between DRAM (first-level centroids, cache) and
SD-card storage (precomputed heavy-cluster embeddings).  The "disk" flavor
actually writes .npy files so persistence is real; the "memory" flavor keeps
arrays in a dict (fast unit tests).  Either way the *edge* latency of a load
comes from the cost model, not this machine's SSD.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, Optional

import numpy as np


class StorageBackend:
    """Keyed blob store for per-cluster embedding matrices."""

    def __init__(self, mode: str = "memory", root: Optional[str] = None):
        assert mode in ("memory", "disk")
        self.mode = mode
        self._mem: Dict[int, np.ndarray] = {}
        if mode == "disk":
            self.root = root or tempfile.mkdtemp(prefix="edgerag_store_")
            os.makedirs(self.root, exist_ok=True)

    def _path(self, key: int) -> str:
        return os.path.join(self.root, f"cluster_{key}.npy")

    def put(self, key: int, embeddings: np.ndarray) -> int:
        """Returns stored byte size."""
        emb = np.ascontiguousarray(embeddings, np.float32)
        if self.mode == "memory":
            self._mem[key] = emb
        else:
            np.save(self._path(key), emb)
        return emb.nbytes

    def get(self, key: int) -> np.ndarray:
        if self.mode == "memory":
            return self._mem[key]
        return np.load(self._path(key))

    def delete(self, key: int):
        if self.mode == "memory":
            self._mem.pop(key, None)
        elif os.path.exists(self._path(key)):
            os.remove(self._path(key))

    def __contains__(self, key: int) -> bool:
        if self.mode == "memory":
            return key in self._mem
        return os.path.exists(self._path(key))

    def keys(self):
        if self.mode == "memory":
            return list(self._mem)
        return [int(f.split("_")[1].split(".")[0])
                for f in os.listdir(self.root) if f.endswith(".npy")]

    def total_bytes(self) -> int:
        if self.mode == "memory":
            return sum(a.nbytes for a in self._mem.values())
        return sum(os.path.getsize(self._path(k)) for k in self.keys())
