from repro.train.optimizer import (adamw_init, adamw_update,  # noqa
                                   cosine_schedule, global_norm)
from repro.train.train_step import TrainState, make_train_step, train_state_init  # noqa
from repro.train.checkpoint import load_checkpoint, save_checkpoint  # noqa
