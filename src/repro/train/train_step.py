"""train_step: loss + grads + AdamW update, one jit-able function.

This is what the train_4k dry-run shape lowers for every architecture.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.train.optimizer import (AdamWState, adamw_init, adamw_update,
                                   cosine_schedule)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array


def train_state_init(params) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4,
                    total_steps: int = 10_000, compute_dtype=jnp.float32,
                    attn_impl: str = "auto", dist=None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, cfg, batch,
                                   compute_dtype=compute_dtype,
                                   attn_impl=attn_impl, dist=dist)
        lr = cosine_schedule(state.step, peak_lr=peak_lr, total=total_steps)
        new_params, new_opt, gnorm = adamw_update(
            grads, state.opt, state.params, lr=lr)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
