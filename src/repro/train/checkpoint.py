"""Checkpointing: pytree <-> .npz with path-keyed entries.

Good enough for single-host examples; a production deployment would swap in
a sharded async checkpointer, but the on-disk format (path-addressable
leaves) is the same idea.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def load_checkpoint(path: str, like: Any) -> Any:
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, v in flat:
        key = _path_str(p)
        arr = data[key]
        assert arr.shape == v.shape, (key, arr.shape, v.shape)
        leaves.append(arr.astype(v.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
