"""Hand-rolled AdamW + schedules (no optax dependency)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return AdamWState(mu=zeros(), nu=zeros(),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    if clip_norm:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count), gnorm


def cosine_schedule(step, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)
