"""Pallas TPU kernel: ragged multi-query top-k over a packed cluster slab.

The batch's unique probed clusters live packed exactly once in one
contiguous (N, D) slab; each query's probe set is a *subset* of the slab's
rows.  The grid is (Q // BLOCK_Q, N // BLOCK_N) with N minor (sequential),
like ``ivf_topk`` — but the masking input ``virt`` (Q, N) int32 makes the
scan ragged: a row only competes for query q when ``virt[q, r] <
NOT_PROBED``, and ``virt`` doubles as the tie-break key (the row's position
in q's virtual per-query concatenation), so the selected rows are exactly
``jax.lax.top_k`` over the virtual concat the pre-slab per-query loop
materialized Q times.

Fused dequantization: the slab block is loaded HBM->VMEM in its compact
storage dtype.  fp16 widens in registers before the MXU dot (lossless);
int8 dots in f32 and applies the per-row scale to the (BLOCK_Q, BLOCK_N)
score block — one multiply per score instead of per element, and no
(N, D) fp32 copy ever materializes.

PQ (fourth representation): the slab block is the (BLOCK_N, m) uint8 code
matrix and the per-query ADC tables (BLOCK_Q, m, 256) ride in as the
second operand (queries are not needed — the LUTs already are the query).
TPU VMEM has no efficient dynamic gather, so the in-kernel
gather+accumulate is expressed as m one-hot matmuls: ``onehot(codes[:, j])``
is a (256, BLOCK_N) selection matrix and ``luts[:, j, :] @ onehot`` lands
on the MXU, accumulating the exact same ``sum_j luts[q, j, code]`` as the
reference gather.  No decoded row and no codebook ever enter the kernel.

Top-k maintenance is k iterations of a row-vectorized lexicographic
(max-score, min-virt) select over the (BLOCK_Q, k + BLOCK_N) candidate
matrix, same shape of work as ``ivf_topk`` with one extra reduction for
the tie-break lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.slab_topk.ref import NEG_INF, NOT_PROBED

EXHAUSTED = NOT_PROBED + 1      # virt key after a candidate is consumed
ROW_SENTINEL = 2**30


def _slab_merge_rows(scores, virt, base_idx, run_v, run_t, run_r, k: int):
    """Merge a block's (BQ, BN) scores into the running (BQ, k) best by
    (score desc, virt asc).

    Each of the k iterations does a row-wise max over scores, then a
    row-wise argmin over the virt key restricted to score-maximal columns —
    virt is unique per (query, valid row), so the selection is a total
    order and the block-streaming merge equals a global sort.
    """
    cand_v = jnp.concatenate([run_v, scores], axis=1)        # (BQ, k + BN)
    cand_t = jnp.concatenate([run_t, virt], axis=1)
    cand_r = jnp.concatenate(
        [run_r, jnp.broadcast_to(base_idx[None], scores.shape)], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)

    def body(i, carry):
        v, t, out_v, out_t, out_r = carry
        m = jnp.max(v, axis=1, keepdims=True)                # (BQ, 1)
        tie = jnp.where(v == m, t, EXHAUSTED)                # min virt among
        j = jnp.argmin(tie, axis=1)                          # score-maximal
        best_v = jnp.take_along_axis(v, j[:, None], axis=1)
        best_t = jnp.take_along_axis(t, j[:, None], axis=1)
        best_r = jnp.take_along_axis(cand_r, j[:, None], axis=1)
        out_v = jax.lax.dynamic_update_slice(out_v, best_v, (0, i))
        out_t = jax.lax.dynamic_update_slice(out_t, best_t, (0, i))
        out_r = jax.lax.dynamic_update_slice(out_r, best_r, (0, i))
        sel = col == j[:, None]
        v = jnp.where(sel, NEG_INF, v)
        t = jnp.where(sel, EXHAUSTED, t)                     # never re-picked
        return v, t, out_v, out_t, out_r

    bq = scores.shape[0]
    init = (cand_v, cand_t,
            jnp.full((bq, k), NEG_INF, jnp.float32),
            jnp.full((bq, k), EXHAUSTED, jnp.int32),
            jnp.full((bq, k), ROW_SENTINEL, jnp.int32))
    _, _, out_v, out_t, out_r = jax.lax.fori_loop(0, k, body, init)
    return out_v, out_t, out_r


def _kernel(emb_ref, q_ref, virt_ref, *rest,
            k: int, block_n: int, block_q: int, mode: str):
    if mode == "scaled":
        scale_ref, out_v_ref, out_r_ref, run_v, run_t, run_r = rest
    else:
        out_v_ref, out_r_ref, run_v, run_t, run_r = rest
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        run_v[...] = jnp.full((block_q, k), NEG_INF, jnp.float32)
        run_t[...] = jnp.full((block_q, k), EXHAUSTED, jnp.int32)
        run_r[...] = jnp.full((block_q, k), ROW_SENTINEL, jnp.int32)

    if mode == "pq":
        # ADC via one-hot matmul (module docstring): q_ref holds the
        # per-query LUTs, emb_ref the uint8 codes
        codes = emb_ref[...].astype(jnp.int32)               # (BN, m)
        luts = q_ref[...].astype(jnp.float32)                # (BQ, m, 256)
        iota = jax.lax.iota(jnp.int32, 256)
        scores = jnp.zeros((block_q, block_n), jnp.float32)
        for j in range(codes.shape[1]):                      # m is static
            onehot = (codes[:, j][None, :] == iota[:, None]
                      ).astype(jnp.float32)                  # (256, BN)
            scores = scores + jax.lax.dot_general(           # (BQ, BN) MXU
                luts[:, j, :], onehot, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    else:
        emb = emb_ref[...].astype(jnp.float32)               # (BN, D) widen
        q = q_ref[...].astype(jnp.float32)                   # (BQ, D)
        scores = jax.lax.dot_general(                        # (BQ, BN) MXU
            q, emb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        if mode == "scaled":
            # fused dequant: per-row scale on the score block, not the slab
            scores = scores * scale_ref[...].astype(jnp.float32).T  # (1, BN)
    virt = virt_ref[...]                                     # (BQ, BN)
    scores = jnp.where(virt < NOT_PROBED, scores, NEG_INF)
    base = nb * block_n + jax.lax.iota(jnp.int32, block_n)
    v, t, r = _slab_merge_rows(scores, virt, base,
                               run_v[...], run_t[...], run_r[...], k)
    run_v[...] = v
    run_t[...] = t
    run_r[...] = r

    @pl.when(nb == pl.num_programs(1) - 1)
    def _done():
        out_v_ref[...] = run_v[...]
        out_r_ref[...] = run_r[...]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "block_q",
                                             "interpret"))
def slab_topk_pallas(emb, queries, virt, k: int, scales=None, luts=None, *,
                     block_n: int = 512, block_q: int = 8,
                     interpret: bool = True):
    """emb (N, D) f32/f16/int8 — or (N, m) uint8 PQ codes when ``luts``
    (Q, m, 256) is given; queries (Q, D) f32, virt (Q, N) int32, scales
    (N, 1) f16/f32 or None -> (vals (Q, k) f32, rows (Q, k) int32).

    Pads N and Q to block multiples internally; padded slab rows get
    ``virt = NOT_PROBED`` so they never score, padded query rows are
    sliced off.  Requires k <= N (the ops layer clamps).
    """
    n, d = emb.shape
    nq = virt.shape[0]
    block_q = max(1, min(block_q, nq))
    n_pad = (-n) % block_n
    if n_pad:
        emb = jnp.pad(emb, ((0, n_pad), (0, 0)))
        virt = jnp.pad(virt, ((0, 0), (0, n_pad)),
                       constant_values=NOT_PROBED)
        if scales is not None:
            scales = jnp.pad(scales, ((0, n_pad), (0, 0)))
    q_pad = (-nq) % block_q
    if q_pad:
        virt = jnp.pad(virt, ((0, q_pad), (0, 0)),
                       constant_values=NOT_PROBED)
        if luts is not None:
            luts = jnp.pad(luts, ((0, q_pad), (0, 0), (0, 0)))
        else:
            queries = jnp.pad(queries, ((0, q_pad), (0, 0)))
    n_blocks = emb.shape[0] // block_n
    q_blocks = virt.shape[0] // block_q

    mode = "pq" if luts is not None else (
        "scaled" if scales is not None else "fp32")
    kernel = functools.partial(_kernel, k=k, block_n=block_n,
                               block_q=block_q, mode=mode)
    if mode == "pq":
        # queries never enter the kernel: the LUTs replace them
        q_operand = luts
        q_spec = pl.BlockSpec((block_q, d, 256), lambda qi, ni: (qi, 0, 0))
    else:
        q_operand = queries
        q_spec = pl.BlockSpec((block_q, d), lambda qi, ni: (qi, 0))
    in_specs = [
        pl.BlockSpec((block_n, d), lambda qi, ni: (ni, 0)),
        q_spec,
        pl.BlockSpec((block_q, block_n), lambda qi, ni: (qi, ni)),
    ]
    operands = [emb, q_operand, virt]
    if mode == "scaled":
        in_specs.append(pl.BlockSpec((block_n, 1), lambda qi, ni: (ni, 0)))
        operands.append(scales)
    out_v, out_r = pl.pallas_call(
        kernel,
        grid=(q_blocks, n_blocks),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, ni: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((virt.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((virt.shape[0], k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    if q_pad:
        out_v, out_r = out_v[:nq], out_r[:nq]
    return out_v, out_r
