"""Public op: ragged multi-query top-k over a packed cluster slab.

Dispatch mirrors ``ivf_topk.ops``:
  * on TPU: the Pallas fused kernel (compiled);
  * elsewhere (this CPU container): the pure-jnp oracle under jit (the
    EdgeRAG runtime fast path) or the Pallas kernel in interpret mode
    (exercised by tests).

The slab may be fp32, fp16, int8 (+ per-row ``scales`` (N, 1)), or PQ
codes (+ per-query ``luts`` (Q, m, 256)); quantized slabs are scored with
fused dequantization and PQ slabs with fused in-kernel gather+accumulate —
no fp32 copy of the slab is ever materialized (see ref.py for the exact
contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ivf_topk.ops import on_tpu
from repro.kernels.slab_topk.kernel import slab_topk_pallas
from repro.kernels.slab_topk.ref import NOT_PROBED, slab_topk_ref

__all__ = ["slab_topk", "NOT_PROBED", "ROW_PAD"]

ROW_PAD = np.int32(2**30)    # row index of a padded output lane

_jit_ref = jax.jit(slab_topk_ref, static_argnames=("k",))


def slab_topk(emb, queries, virt, k: int, *, scales=None, luts=None,
              impl: str = "auto"):
    """emb (N, D) f32/f16/int8 — or (N, m) uint8 PQ codes when ``luts``
    (Q, m, 256) is given; queries (Q, D), virt (Q, N) int32, scales (N, 1)
    or None -> (vals (Q, k) f32, rows (Q, k) int32).

    One launch scores ALL queries against the packed slab; per query the
    best k member rows (``virt < NOT_PROBED``) by (score desc, virt asc).
    PADDING: lanes past a query's member count are NOT self-describing —
    they carry ~NEG_INF (-1e30) scores and arbitrary in-range non-member
    rows (``ROW_PAD`` appears only in the k > N overflow lanes).  Callers
    MUST mask by the per-query member count (``SlabLayout.query_layout``'s
    ``n_valid_seg``) before gathering ids; never detect padding from the
    returned values.

    impl: "auto" | "ref" | "pallas".
    """
    n = emb.shape[0]
    nq = queries.shape[0]
    if n == 0 or k == 0:
        return (jnp.full((nq, k), -np.inf, jnp.float32),
                jnp.full((nq, k), ROW_PAD, jnp.int32))
    k_eff = min(k, n)
    emb = jnp.asarray(emb)
    queries = jnp.asarray(queries, jnp.float32)
    virt = jnp.asarray(virt, jnp.int32)
    if scales is not None:
        scales = jnp.asarray(scales, jnp.float32)
    if luts is not None:
        luts = jnp.asarray(luts, jnp.float32)
    if impl == "pallas" or (impl == "auto" and on_tpu()):
        vals, rows = slab_topk_pallas(emb, queries, virt, k_eff, scales,
                                      luts, interpret=not on_tpu())
    else:
        vals, rows = _jit_ref(emb, queries, virt, k=k_eff, scales=scales,
                              luts=luts)
    if k_eff < k:
        pad = k - k_eff
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-np.inf)
        rows = jnp.pad(rows, ((0, 0), (0, pad)), constant_values=ROW_PAD)
    return vals, rows
