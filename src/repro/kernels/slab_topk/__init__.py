from repro.kernels.slab_topk.ops import NOT_PROBED, ROW_PAD, slab_topk
from repro.kernels.slab_topk.ref import slab_topk_ref

__all__ = ["slab_topk", "slab_topk_ref", "NOT_PROBED", "ROW_PAD"]
