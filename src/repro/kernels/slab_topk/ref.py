"""Pure-jnp oracle for the packed-slab ragged multi-query top-k.

Contract: the batch's unique probed clusters are packed ONCE into one
contiguous slab ``emb`` (N, D) — fp32, fp16, or int8 (+ per-row scales).
``virt`` (Q, N) int32 encodes both membership and ordering: ``virt[q, r]``
is row ``r``'s position in query ``q``'s *virtual* per-query concatenation
(its probed clusters laid out in probe order), or :data:`NOT_PROBED` when
query ``q`` did not probe the cluster owning row ``r``.

Selection per query is the best k rows by (score DESC, virt ASC).  The
virtual-index tie-break makes the result *identical* — ids included — to
``jax.lax.top_k`` over the per-query concatenated matrix the pre-slab
scoring loop built, so the fp32 slab path stays bit-compatible with the
sequential per-query reference while scoring every query in one launch.

Fused dequantization: fp16 slabs are widened in the score matmul (exact —
fp16 -> f32 is lossless, bit-identical to dequantize-then-score); int8
slabs apply the per-row fp16 scale to the (Q, N) score block AFTER the
integer-valued dot product instead of scaling all N*D elements first
(one multiply per score, not per element — equal to dequantize-then-score
up to a single f32 rounding per score).

PQ (fourth representation): ``emb`` is the (N, m) uint8 code matrix and
``luts`` the per-query ADC tables (Q, m, 256) built ONCE per batch by
``core.pq.pq_luts``.  A row's asymmetric inner-product score is
``sum_j luts[q, j, emb[r, j]]`` — m gathers + adds, never touching the
codebook or a decoded fp32 row.  Equal to decode-then-score up to f32
summation order (each term IS the exact subspace inner product).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NOT_PROBED = 2**30          # virt sentinel: row not in this query's probe set
NEG_INF = -1e30
_EXHAUSTED = NOT_PROBED + 1  # virt key of an already-selected row


def lex_topk(masked: jax.Array, virt: jax.Array, k: int):
    """Best k columns per row by (masked DESC, virt ASC), exactly.

    XLA CPU only fast-paths ``lax.top_k`` on f32 — integer top-k and every
    variadic ``lax.sort`` fall back to a ~50x slower generic path — so the
    lexicographic selection runs in two f32-friendly phases:

      1. ``lax.top_k(masked, k)``: the selected VALUE multiset is
         independent of how ties break, so the returned (sorted, ties
         adjacent) values are already exact.
      2. k iterations of a row-vectorized argmin: lane i takes the
         minimum-virt not-yet-taken column whose value compare-equals
         ``vals[:, i]`` — consecutive equal-value lanes therefore walk the
         tie group in ascending virt order, reproducing ``lax.top_k``'s
         stable equal-compare behavior on the virtual concat (including
         the -0.0 == +0.0 corner; returned vals are re-gathered from
         ``masked`` so even their sign bits match).
    """
    vals, _ = jax.lax.top_k(masked, k)                       # (Q, k)
    col = jax.lax.broadcasted_iota(jnp.int32, masked.shape, 1)
    tie0 = jnp.where(virt < NOT_PROBED, virt, NOT_PROBED)

    def body(i, carry):
        tie, rows = carry
        target = jax.lax.dynamic_slice_in_dim(vals, i, 1, axis=1)  # (Q, 1)
        j = jnp.argmin(jnp.where(masked == target, tie, _EXHAUSTED),
                       axis=1)                               # (Q,)
        rows = jax.lax.dynamic_update_slice(
            rows, j[:, None].astype(jnp.int32), (0, i))
        tie = jnp.where(col == j[:, None], _EXHAUSTED, tie)  # consume
        return tie, rows

    _, rows = jax.lax.fori_loop(
        0, k, body, (tie0, jnp.zeros((masked.shape[0], k), jnp.int32)))
    return jnp.take_along_axis(masked, rows, axis=1), rows


def pq_adc_scores(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Asymmetric-distance scores from PQ codes: codes (N, m) integer,
    luts (Q, m, 256) f32 -> (Q, N) f32 with
    ``out[q, r] = sum_j luts[q, j, codes[r, j]]``."""
    codes = codes.astype(jnp.int32)
    m = codes.shape[1]
    nq, n = luts.shape[0], codes.shape[0]

    def body(j, acc):
        lut_j = jax.lax.dynamic_index_in_dim(luts, j, 1, keepdims=False)
        c_j = jax.lax.dynamic_index_in_dim(codes, j, 1, keepdims=False)
        return acc + jnp.take(lut_j, c_j, axis=1)        # gather (Q, N)

    return jax.lax.fori_loop(0, m, body, jnp.zeros((nq, n), jnp.float32))


def slab_topk_ref(emb: jax.Array, queries: jax.Array, virt: jax.Array,
                  k: int, scales: Optional[jax.Array] = None,
                  luts: Optional[jax.Array] = None):
    """emb (N, D) f32/f16/int8 — or (N, m) uint8 PQ codes when ``luts``
    (Q, m, 256) is given; queries (Q, D) f32; virt (Q, N) int32; scales
    (N, 1) f32 per-row (int8 slabs) or None.

    Returns (vals (Q, k) f32, rows (Q, k) int32): the best k slab rows per
    query by (score desc, virt asc).  Lanes beyond a query's candidate
    count carry ``NEG_INF`` scores and arbitrary member-free rows —
    callers mask by the per-query valid count.  Requires k <= N (dispatch
    clamps).
    """
    if luts is not None:
        scores = pq_adc_scores(emb, luts.astype(jnp.float32))
    else:
        scores = queries.astype(jnp.float32) @ emb.astype(jnp.float32).T
        if scales is not None:
            scores = scores * scales.astype(jnp.float32)[:, 0][None, :]
    masked = jnp.where(virt < NOT_PROBED, scores, NEG_INF)
    return lex_topk(masked, virt, k)
