"""Pallas TPU decode-attention: one query token vs. a long KV cache.

This is the memory-bound serve_step hot loop (decode_32k / long_500k): the
whole cache streams HBM→VMEM once per step, so the kernel's job is to keep
that stream saturated while the VPU does the (1, BK) score row and the
online softmax.  The cache layout (B, S, KH, D) is kept sequence-major —
the natural decode layout, contiguous along the streamed axis.

Grid: (B, H, Skv/BK), KV minor/sequential; per-(b,h) scratch: running max
(1,), denominator (1,), accumulator (1, D).  cache_len rides in SMEM for
validity masking (also covers ring buffers: pass cache_len >= Smax).

GQA note: all H/KH query heads of a group re-stream the same KV block; the
§Perf pass may instead tile heads into the block (one stream per KV head) —
recorded as a hillclimb candidate, baseline keeps the simple layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bk: int, window: int, scale: float, ks_ref=None, vs_ref=None):
    _kernel_body(len_ref, q_ref, k_ref, v_ref, None, None, o_ref,
                 m_scr, l_scr, acc_scr, bk=bk, window=window, scale=scale)


def _kernel_q8(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
               m_scr, l_scr, acc_scr, *, bk: int, window: int, scale: float):
    _kernel_body(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                 m_scr, l_scr, acc_scr, bk=bk, window=window, scale=scale)


def _kernel_body(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                 m_scr, l_scr, acc_scr, *, bk: int, window: int,
                 scale: float):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[0]
    k_pos = ik * bk + jax.lax.iota(jnp.int32, bk)
    valid = k_pos < cache_len
    if window:
        valid = jnp.logical_and(valid, k_pos > cache_len - 1 - window)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (D,)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (BK, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if ks_ref is not None:
            # int8 cache: dequantize in VMEM right after the HBM stream —
            # the HBM traffic (the decode bottleneck) is halved
            k = k * ks_ref[0, :, 0]
            v = v * vs_ref[0, :, 0]
        s = k @ q                                            # (BK,)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.exp(s - m_new)                               # (BK,)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[0] = l_scr[0] * alpha + p.sum()
        acc_scr[0] = acc_scr[0] * alpha + p @ v
        m_scr[0] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[0] / jnp.maximum(l_scr[0], 1e-20)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, cache_len, *,
                            window: int = 0, bk: int = 512,
                            interpret: bool = True):
    """q (B,H,D); k/v cache (B,Smax,KH,D); cache_len scalar -> (B,H,D)."""
    b, h, d = q.shape
    smax, kh = k_cache.shape[1], k_cache.shape[2]
    group = h // kh
    bk = min(bk, smax)
    assert smax % bk == 0, "cache length must be a block multiple"
    scale = d ** -0.5
    lens = jnp.asarray(cache_len, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, bk=bk, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h, smax // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bi, hi, ik: (bi, hi, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bi, hi, ik: (bi, ik, hi // group, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bi, hi, ik: (bi, ik, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi, ik: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k_cache, v_cache)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention_pallas_q8(q, k_q, k_scale, v_q, v_scale, cache_len, *,
                               window: int = 0, bk: int = 512,
                               interpret: bool = True):
    """Int8-cache variant: k_q/v_q (B,Smax,KH,D) int8 with per-(token,head)
    scales (B,Smax,KH,1) f32; dequant happens post-VMEM-load in the kernel.
    HBM cache traffic is halved vs bf16 — the §Roofline decode bottleneck."""
    b, h, d = q.shape
    smax, kh = k_q.shape[1], k_q.shape[2]
    group = h // kh
    bk = min(bk, smax)
    assert smax % bk == 0
    scale = d ** -0.5
    lens = jnp.asarray(cache_len, jnp.int32).reshape(1)
    kernel = functools.partial(_kernel_q8, bk=bk, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h, smax // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bi, hi, ik: (bi, hi, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bi, hi, ik: (bi, ik, hi // group, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bi, hi, ik: (bi, ik, hi // group, 0)),
            pl.BlockSpec((1, bk, 1, 1),
                         lambda bi, hi, ik: (bi, ik, hi // group, 0)),
            pl.BlockSpec((1, bk, 1, 1),
                         lambda bi, hi, ik: (bi, ik, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi, ik: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k_q, v_q, k_scale, v_scale)
