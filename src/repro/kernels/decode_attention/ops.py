"""Public op: single-token decode attention with dispatch."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     impl: str = "auto"):
    """q (B,H,D); k/v cache (B,Smax,KH,D) -> (B,H,D)."""
    if impl == "pallas" or (impl == "auto" and on_tpu()):
        return decode_attention_pallas(q, k_cache, v_cache, cache_len,
                                       window=window, interpret=not on_tpu())
    return decode_attention_ref(q, k_cache, v_cache, cache_len, window=window)
