"""Pure-jnp oracle for single-token decode attention.

Contract: q (B, H, D) — one new token per sequence — against a KV cache
(B, Smax, KH, D) of which the first ``cache_len`` entries are valid
(ring-buffer caches pass cache_len >= Smax so everything is valid).
Optional trailing window restricts to the last ``window`` valid positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, cache_len, *, window: int = 0):
    b, h, d = q.shape
    kh = k_cache.shape[2]
    rep = h // kh
    k = jnp.repeat(k_cache, rep, axis=2).astype(jnp.float32)  # (B,S,H,D)
    v = jnp.repeat(v_cache, rep, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k) * d ** -0.5
    idx = jnp.arange(k_cache.shape[1])
    valid = idx < cache_len
    if window:
        valid &= idx > cache_len - 1 - window
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs, v).astype(q.dtype)
