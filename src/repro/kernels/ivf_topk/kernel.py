"""Pallas TPU kernel: fused inner-product distance + running top-k scan.

The compute hot-spot EdgeRAG inherits from FAISS is the second-level search:
score every candidate embedding in the probed clusters against the query and
keep the best k.  FAISS does a CPU linear scan; the TPU-native formulation
streams candidate rows HBM→VMEM and fuses the MXU distance matmul with an
on-chip running top-k, so no (N,) score vector ever hits HBM.

Multi-query tiling: queries are processed in blocks of ``block_q`` rows with
grid (Q // BLOCK_Q, N // BLOCK_N) — the N axis is the minor (sequential)
grid dim, so the (BLOCK_Q, k) running-best VMEM scratch persists across
candidate blocks of one query block.  Each candidate block is therefore
streamed from HBM once per *query block* instead of once per query: a batch
of B queries costs ceil(B / BLOCK_Q) passes over the candidates, not B.

Top-k maintenance is k iterations of a row-vectorized (argmax, mask) over
the (BLOCK_Q, k + BLOCK_N) candidate matrix — all BLOCK_Q rows advance per
iteration (pure VPU work; k is small, ≤ 128).  The single-query path is the
degenerate case BLOCK_Q = 1.

BlockSpec tiling: emb block (BLOCK_N, D) f32 in VMEM (default 512×768×4 ≈
1.5 MiB), query block (BLOCK_Q, D), outputs (BLOCK_Q, k).  D stays whole:
dim 768 = 6×128 lanes, MXU-aligned.  The true candidate count rides in SMEM
so padded rows can be masked; padded query rows are sliced off outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _topk_merge_rows(scores, base_idx, run_vals, run_idx, k: int):
    """Merge a block's scores (BQ, BN) into the running (BQ, k) best.

    Vectorized across the BQ query rows: each of the k iterations does one
    row-wise argmax over the (BQ, k + BN) candidate matrix and masks the
    selected column per row.  Ties break toward the lower column index —
    running entries (already sorted, earlier N blocks) win over new
    candidates, matching ``jax.lax.top_k`` order.
    """
    bq = scores.shape[0]
    cand_vals = jnp.concatenate([run_vals, scores], axis=1)   # (BQ, k + BN)
    cand_idx = jnp.concatenate(
        [run_idx, jnp.broadcast_to(base_idx[None], scores.shape)], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cand_vals.shape, 1)

    def body(i, carry):
        vals, out_v, out_i = carry
        j = jnp.argmax(vals, axis=1)                          # (BQ,)
        best_v = jnp.take_along_axis(vals, j[:, None], axis=1)
        best_i = jnp.take_along_axis(cand_idx, j[:, None], axis=1)
        out_v = jax.lax.dynamic_update_slice(out_v, best_v, (0, i))
        out_i = jax.lax.dynamic_update_slice(out_i, best_i, (0, i))
        vals = jnp.where(col == j[:, None], NEG_INF, vals)
        return vals, out_v, out_i

    init = (cand_vals,
            jnp.full((bq, k), NEG_INF, jnp.float32),
            jnp.full((bq, k), jnp.int32(2**30), jnp.int32))
    _, out_v, out_i = jax.lax.fori_loop(0, k, body, init)
    return out_v, out_i


def _kernel(valid_ref, emb_ref, q_ref, out_v_ref, out_i_ref,
            run_v, run_i, *, k: int, block_n: int, block_q: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        run_v[...] = jnp.full((block_q, k), NEG_INF, jnp.float32)
        run_i[...] = jnp.full((block_q, k), jnp.int32(2**30), jnp.int32)

    emb = emb_ref[...].astype(jnp.float32)                   # (BN, D)
    q = q_ref[...].astype(jnp.float32)                       # (BQ, D)
    scores = jax.lax.dot_general(                            # (BQ, BN) via MXU
        q, emb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    base = nb * block_n + jax.lax.iota(jnp.int32, block_n)
    scores = jnp.where((base < valid_ref[0])[None], scores, NEG_INF)
    v, i = _topk_merge_rows(scores, base, run_v[...], run_i[...], k)
    run_v[...] = v
    run_i[...] = i

    @pl.when(nb == pl.num_programs(1) - 1)
    def _done():
        out_v_ref[...] = run_v[...]
        out_i_ref[...] = run_i[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "block_n", "block_q", "interpret"))
def topk_ip_pallas(embs, queries, k: int, *, block_n: int = 512,
                   block_q: int = 8, interpret: bool = True):
    """embs (N, D) f32, queries (Q, D) f32 -> (scores (Q,k), idx (Q,k)).

    Queries are tiled in blocks of ``block_q`` (clamped to Q); each
    candidate block is read once per query block.  Q and N are padded to
    block multiples internally; padded outputs are sliced off.
    """
    n, d = embs.shape
    q = queries.shape[0]
    block_q = max(1, min(block_q, q))
    n_pad = (-n) % block_n
    if n_pad:
        embs = jnp.pad(embs, ((0, n_pad), (0, 0)))
    q_pad = (-q) % block_q
    if q_pad:
        queries = jnp.pad(queries, ((0, q_pad), (0, 0)))
    n_blocks = embs.shape[0] // block_n
    q_blocks = queries.shape[0] // block_q
    valid = jnp.array([n], jnp.int32)

    kernel = functools.partial(_kernel, k=k, block_n=block_n,
                               block_q=block_q)
    out_v, out_i = pl.pallas_call(
        kernel,
        grid=(q_blocks, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n, d), lambda qi, ni: (ni, 0)),
            pl.BlockSpec((block_q, d), lambda qi, ni: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, ni: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((queries.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((queries.shape[0], k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(valid, embs, queries)
    if q_pad:
        out_v, out_i = out_v[:q], out_i[:q]
    return out_v, out_i
