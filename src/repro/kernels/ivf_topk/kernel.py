"""Pallas TPU kernel: fused inner-product distance + running top-k scan.

The compute hot-spot EdgeRAG inherits from FAISS is the second-level search:
score every candidate embedding in the probed clusters against the query and
keep the best k.  FAISS does a CPU linear scan; the TPU-native formulation
streams candidate rows HBM→VMEM exactly once and fuses the MXU distance
matmul with an on-chip running top-k, so no (N,) score vector ever hits HBM.

Grid: (Q, N // BLOCK_N) — the N axis is the minor (sequential) grid dim, so
the (k,) running-best VMEM scratch persists across blocks of one query.
Top-k maintenance is k iterations of (argmax, mask) over the (BLOCK_N + k,)
candidate vector — k is small (≤ 128), pure VPU work.

BlockSpec tiling: emb block (BLOCK_N, D) f32 in VMEM (default 512×768×4 ≈
1.5 MiB), query row (1, D), outputs (1, k).  D stays whole: dim 768 =
6×128 lanes, MXU-aligned.  The true candidate count rides in SMEM so padded
rows can be masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _topk_merge(scores, base_idx, run_vals, run_idx, k: int):
    """Merge a block's scores (B,) into the running (k,) best."""
    cand_vals = jnp.concatenate([run_vals, scores])          # (k + B,)
    cand_idx = jnp.concatenate([run_idx, base_idx])

    def body(i, carry):
        vals, out_v, out_i = carry
        j = jnp.argmax(vals)
        out_v = out_v.at[i].set(vals[j])
        out_i = out_i.at[i].set(cand_idx[j])
        vals = vals.at[j].set(NEG_INF)
        return vals, out_v, out_i

    init = (cand_vals,
            jnp.full((k,), NEG_INF, jnp.float32),
            jnp.full((k,), jnp.int32(2**30), jnp.int32))
    _, out_v, out_i = jax.lax.fori_loop(0, k, body, init)
    return out_v, out_i


def _kernel(valid_ref, emb_ref, q_ref, out_v_ref, out_i_ref,
            run_v, run_i, *, k: int, block_n: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        run_v[...] = jnp.full((k,), NEG_INF, jnp.float32)
        run_i[...] = jnp.full((k,), jnp.int32(2**30), jnp.int32)

    emb = emb_ref[...].astype(jnp.float32)                   # (B, D)
    q = q_ref[...].astype(jnp.float32)                       # (1, D)
    scores = (emb @ q.T)[:, 0]                               # (B,) via MXU
    base = nb * block_n + jax.lax.iota(jnp.int32, block_n)
    scores = jnp.where(base < valid_ref[0], scores, NEG_INF)
    v, i = _topk_merge(scores, base, run_v[...], run_i[...], k)
    run_v[...] = v
    run_i[...] = i

    @pl.when(nb == pl.num_programs(1) - 1)
    def _done():
        out_v_ref[...] = run_v[...][None]
        out_i_ref[...] = run_i[...][None]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def topk_ip_pallas(embs, queries, k: int, *, block_n: int = 512,
                   interpret: bool = True):
    """embs (N, D) f32, queries (Q, D) f32 -> (scores (Q,k), idx (Q,k))."""
    n, d = embs.shape
    q = queries.shape[0]
    n_pad = (-n) % block_n
    if n_pad:
        embs = jnp.pad(embs, ((0, n_pad), (0, 0)))
    n_blocks = embs.shape[0] // block_n
    valid = jnp.array([n], jnp.int32)

    kernel = functools.partial(_kernel, k=k, block_n=block_n)
    out_v, out_i = pl.pallas_call(
        kernel,
        grid=(q, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n, d), lambda qi, ni: (ni, 0)),
            pl.BlockSpec((1, d), lambda qi, ni: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((1, k), lambda qi, ni: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        interpret=interpret,
    )(valid, embs, queries)
    return out_v, out_i
