"""Pure-jnp oracle for the fused IVF second-level search.

Contract: given candidate embeddings (N, D) and queries (Q, D), return the
top-k inner-product scores and row indices per query.  Ties broken toward
the lower index (matches the kernel's strict-greater running merge).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ip_ref(embs: jax.Array, queries: jax.Array, k: int):
    """embs: (N, D); queries: (Q, D) -> (scores (Q, k), idx (Q, k) int32)."""
    scores = queries.astype(jnp.float32) @ embs.astype(jnp.float32).T  # (Q, N)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
