from repro.kernels.ivf_topk.ops import topk_ip  # noqa
