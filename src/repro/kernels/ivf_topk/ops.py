"""Public op: top-k inner-product search over candidate embeddings.

Dispatch policy:
  * on TPU: the Pallas fused kernel (compiled);
  * elsewhere (this CPU container): either the Pallas kernel in interpret
    mode (tests exercise this) or the pure-jnp oracle (fast path used by the
    EdgeRAG runtime — interpret-mode Python loops are slow at real sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ivf_topk.kernel import topk_ip_pallas
from repro.kernels.ivf_topk.ref import topk_ip_ref

_jit_ref = jax.jit(topk_ip_ref, static_argnames=("k",))


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def topk_ip(embs, queries, k: int, *, impl: str = "auto"):
    """embs (N, D), queries (Q, D) -> (scores (Q, k), idx (Q, k)).

    impl: "auto" | "ref" | "pallas".
    """
    n = embs.shape[0]
    k_eff = min(k, n)
    if impl == "pallas" or (impl == "auto" and on_tpu()):
        vals, idx = topk_ip_pallas(jnp.asarray(embs, jnp.float32),
                                   jnp.asarray(queries, jnp.float32),
                                   k_eff, interpret=not on_tpu())
    else:
        vals, idx = _jit_ref(jnp.asarray(embs, jnp.float32),
                             jnp.asarray(queries, jnp.float32), k=k_eff)
    if k_eff < k:
        pad = k - k_eff
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-np.inf)
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
    return vals, idx
