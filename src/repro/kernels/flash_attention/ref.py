"""Pure-jnp oracle for the flash-attention kernel (prefill hot loop).

Layout contract (kernel-native, head-major):
  q: (B, H, Sq, D); k, v: (B, KH, Skv, D), H % KH == 0.
Returns (B, H, Sq, D).  Causal + optional sliding window, in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    b, h, sq, d = q.shape
    kh = k.shape[1]
    rep = h // kh
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qp = jnp.arange(sq)
    kp = jnp.arange(k.shape[2])
    ok = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window:
        ok &= kp[None, :] > qp[:, None] - window
    scores = jnp.where(ok[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
