"""Public op: flash attention with layout adaptation + dispatch.

Model code uses (B, S, H, D); the kernel is head-major (B, H, S, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto"):
    """q (B,S,H,D); k,v (B,S,KH,D) -> (B,S,H,D)."""
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    if impl == "pallas" or (impl == "auto" and on_tpu()):
        out = flash_attention_pallas(qh, kh, vh, causal=causal, window=window,
                                     interpret=not on_tpu())
    else:
        out = flash_attention_ref(qh, kh, vh, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)
