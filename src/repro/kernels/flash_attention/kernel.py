"""Pallas TPU flash-attention (prefill): online-softmax over KV blocks.

Grid: (B, H, Sq/BQ, Skv/BK) — KV is the minor sequential axis so the
(BQ,)-shaped running max / denominator and the (BQ, D) accumulator live in
VMEM scratch across KV blocks of one query tile.

BlockSpec tiling (all VMEM):
  q    (1, 1, BQ, D)   index (b, h, iq, 0)
  k/v  (1, 1, BK, D)   index (b, h // (H/KH), ik, 0)   ← GQA head fold
  out  (1, 1, BQ, D)   index (b, h, iq, 0)

BQ = BK = 128 default: MXU-native 128-lane tiles; scratch footprint
BQ*D*4 + 2*BQ*4 ≈ 66 KiB at D=128 — far under the ~16 MiB VMEM budget,
leaving room for XLA to double-buffer the HBM→VMEM k/v streams.

Causal masking is positional (global indices); fully-masked KV blocks are
skipped with @pl.when so the causal prefill does ~half the block work —
same trick as the reference TPU flash kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bk: int, causal: bool, window: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq)
    k_pos = ik * bk + jax.lax.iota(jnp.int32, bk)
    # block-level skip: causal ⇒ KV blocks fully in the future do nothing;
    # sliding window ⇒ KV blocks fully behind the window do nothing
    needed = ik >= 0  # traced True
    if causal:
        needed = jnp.logical_and(needed, (ik * bk) <= (iq * bq + bq - 1))
    if window:
        needed = jnp.logical_and(
            needed, (ik * bk + bk - 1) > (iq * bq - window))

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                          # (BQ, BK) MXU
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-20)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q (B,H,Sq,D); k,v (B,KH,Skv,D) -> (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    assert h % kh == 0
    group = h // kh
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad seq to block multiple"
    grid = (b, h, sq // bq, skv // bk)
    scale = d ** -0.5

    kernel = functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, iq, ik: (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, iq, ik: (bi, hi // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, iq, ik: (bi, hi // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, iq, ik: (bi, hi, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
