"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

Pattern: 5 Mamba2 blocks then one SHARED attention block (one set of attention
weights reused at every application — the Zamba trick), repeated 9 times for
54 layers.  The shared block's params are stored once and closed over by the
scan, exactly matching the memory-saving motivation of the paper.
"""
from repro.configs.base import ModelConfig, register


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2",
                       "shared_attn"),
        ssm_state_size=64,
        ssm_head_dim=64,
        ssm_expand=2,
        source="arXiv:2411.15242",
        notes="shared attention weights reused across all 9 applications",
    )
