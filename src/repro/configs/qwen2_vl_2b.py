"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
M-RoPE (multimodal rotary: temporal/height/width sections), dynamic resolution.
[arXiv:2409.12191]

The ViT vision encoder + projector is a STUB per the assignment carve-out:
``input_specs`` supplies pre-projected patch embeddings (B, P, d_model) plus
3-axis M-RoPE position ids (3, B, S).  We implement the language decoder that
consumes interleaved text tokens and vision embeddings.
"""
from repro.configs.base import ModelConfig, register


@register("qwen2-vl-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        block_pattern=("attn",),
        use_mrope=True,
        mrope_sections=(16, 24, 24),    # sums to head_dim//2
        rope_theta=1_000_000.0,
        source="arXiv:2409.12191",
        notes="M-RoPE; ViT frontend stubbed, patch embeds via input_specs",
    )
