"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1024/expert
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060]
"""
from repro.configs.base import ModelConfig, register


@register("olmoe-1b-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        arch_type="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,                    # per-expert FFN width
        vocab_size=50304,
        block_pattern=("moe",),
        num_experts=64,
        num_experts_per_tok=8,
        rope_theta=10_000.0,
        tie_embeddings=False,
        source="arXiv:2409.02060",
        notes="fine-grained 64-expert MoE, every layer",
    )
