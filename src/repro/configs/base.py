"""Model configuration system.

Every assigned architecture (and the paper's own embedder / generator) is
expressed as a :class:`ModelConfig`.  Configs are plain frozen dataclasses so
they can be hashed, used as jit static args, and printed into EXPERIMENTS.md.

The ``block_pattern`` field drives the scan-over-blocks model assembly in
``repro.models.model``:  the layer stack is ``depth_repeat`` repetitions of
the pattern, and each pattern entry is the *kind* of block ("attn",
"swa" sliding-window attention, "moe", "mamba2", "rwkv6", "shared_attn").
Keeping the pattern short and scanning over repetitions keeps HLO size flat
in depth — essential for the 512-way SPMD dry-run on this container.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

BlockKind = str  # "attn" | "swa" | "moe" | "swa_moe" | "mamba2" | "rwkv6" | "shared_attn"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    # --- block pattern (see module docstring) ---
    block_pattern: Tuple[BlockKind, ...] = ("attn",)
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    expert_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # --- SSM (mamba2 / rwkv6) ---
    ssm_state_size: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2                 # mamba2 inner dim = expand * d_model
    ssm_conv_width: int = 4
    # --- attention details ---
    rope_theta: float = 10_000.0
    use_mrope: bool = False             # qwen2-vl multimodal RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w head_dim split
    sliding_window: int = 0             # window for "swa" blocks
    attn_logit_softcap: float = 0.0
    # --- embedding / IO ---
    tie_embeddings: bool = True
    embedding_inputs: bool = False      # audio/vlm stub frontends feed embeddings
    norm_eps: float = 1e-6
    # --- source citation ---
    source: str = ""
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}")

    # ---- derived quantities -------------------------------------------------
    @property
    def depth_repeat(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("mamba2", "rwkv6") for k in self.block_pattern)

    @property
    def has_ssm_state(self) -> bool:
        return any(k in ("mamba2", "rwkv6") for k in self.block_pattern)

    @property
    def ssm_inner_dim(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_inner_dim // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (matches models.model.init to the unit)."""
        c = self
        n = c.vocab_size * c.d_model          # token embedding
        if not c.tie_embeddings:
            n += c.vocab_size * c.d_model
        n += c.d_model                         # final norm
        per_pattern = 0
        for kind in c.block_pattern:
            if kind in ("attn", "swa", "shared_attn"):
                per_pattern += c.d_model * (c.q_dim + 2 * c.kv_dim)  # qkv
                per_pattern += c.q_dim * c.d_model                   # out proj
                per_pattern += 2 * c.d_model                         # 2 norms
                per_pattern += 3 * c.d_model * c.d_ff                # swiglu mlp
            elif kind in ("moe", "swa_moe"):
                per_pattern += c.d_model * (c.q_dim + 2 * c.kv_dim)
                per_pattern += c.q_dim * c.d_model
                per_pattern += 2 * c.d_model
                per_pattern += c.d_model * c.num_experts             # router
                per_pattern += 3 * c.num_experts * c.d_model * c.d_ff
            elif kind == "mamba2":
                # mixer-only block (real Mamba stacks carry no FFN; for
                # zamba2 the d_ff MLP lives in the shared attention block)
                d_in = c.ssm_inner_dim
                nh = c.ssm_num_heads
                per_pattern += c.d_model * (2 * d_in + 2 * c.ssm_state_size + nh)
                per_pattern += nh + nh                               # A_log, D
                per_pattern += d_in                                  # gate norm
                per_pattern += d_in * c.d_model                      # out proj
                per_pattern += c.d_model                             # pre-norm
            elif kind == "rwkv6":
                H = c.d_model // c.ssm_head_dim
                per_pattern += 5 * c.d_model * c.d_model             # r,k,v,g,o
                per_pattern += 2 * c.d_model * 64 + 0                # decay lora (w1,w2)
                per_pattern += 64 * c.d_model
                per_pattern += H * c.ssm_head_dim                    # u (bonus)
                per_pattern += 2 * c.d_model                         # 2 norms
                per_pattern += 2 * c.d_model * c.d_ff                # rwkv channel-mix (k,v)
            else:
                raise ValueError(kind)
        n += per_pattern * self.depth_repeat
        # shared blocks are counted once, not per repeat: subtract extras
        shared = [k for k in self.block_pattern if k == "shared_attn"]
        if shared and self.depth_repeat > 1:
            sz = (c.d_model * (c.q_dim + 2 * c.kv_dim) + c.q_dim * c.d_model
                  + 2 * c.d_model + 3 * c.d_model * c.d_ff)
            n -= sz * len(shared) * (self.depth_repeat - 1)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        dense_expert = 3 * self.d_model * self.d_ff
        n_moe_blocks = sum(1 for k in self.block_pattern if k in ("moe", "swa_moe"))
        n_moe_blocks *= self.depth_repeat
        inactive = (self.num_experts - self.num_experts_per_tok)
        return self.param_count() - n_moe_blocks * inactive * dense_expert

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims, runs on CPU."""
        pat = self.block_pattern
        if num_layers % len(pat) != 0:
            num_layers = len(pat)
        head_dim = 64
        num_heads = max(2, d_model // head_dim)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        # keep GQA ratio representative: kv <= heads, heads % kv == 0
        while num_heads % num_kv:
            num_kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=max(128, d_model * 2),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, max_experts) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2) if self.num_experts else 0,
            expert_capacity_factor=4.0,   # dropless at smoke scale
            ssm_state_size=min(self.ssm_state_size, 16) if self.ssm_state_size else 0,
            ssm_head_dim=32,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            mrope_sections=(16, 8, 8),
        )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa
        _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs():
    from repro import configs as _c
    _c.load_all()
    return sorted(_REGISTRY)
