"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192,
vocab=2048 (EnCodec codebook).  Decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

The EnCodec conv codec frontend is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings of shape (B, S, d_model).
The decoder itself (what we implement) is a standard causal transformer whose
logits rank the 2048-entry codebook.
"""
from repro.configs.base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        arch_type="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        block_pattern=("attn",),
        embedding_inputs=True,          # EnCodec frontend stubbed
        tie_embeddings=False,
        source="arXiv:2306.05284",
        notes="decoder-only over EnCodec tokens; codec frontend stubbed",
    )
