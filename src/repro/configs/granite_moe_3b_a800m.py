"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family; the assignment's bracket note
"32 experts" matches the 1b-a400m card — the 3b-a800m spec line says 40e, which
we follow.]
"""
from repro.configs.base import ModelConfig, register


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        arch_type="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,                      # per-expert FFN width
        vocab_size=49155,
        block_pattern=("moe",),
        num_experts=40,
        num_experts_per_tok=8,
        rope_theta=10_000.0,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        notes="every layer MoE, fine-grained experts (d_ff=512), top-8 of 40",
    )
