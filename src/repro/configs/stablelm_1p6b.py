"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32 = MHA) d_ff=5632
vocab=100352. [hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ModelConfig, register


@register("stablelm-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        arch_type="dense",
        num_layers=24,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=5632,
        vocab_size=100352,
        block_pattern=("attn",),
        rope_theta=10_000.0,
        tie_embeddings=False,
        source="hf:stabilityai/stablelm-2-1_6b",
    )
