"""Assigned input shapes.

Each shape names the step function that is lowered for it in the dry-run:
  * ``train``   -> ``train_step``   (loss + grads + optimizer update)
  * ``prefill`` -> ``prefill_step`` (full-sequence forward, KV cache out)
  * ``decode``  -> ``serve_step``   (ONE new token against a seq_len cache)

``long_500k`` additionally requires sub-quadratic attention: SSM/hybrid archs
run natively; all attention archs switch to the sliding-window serving mode
(window 8192) — see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"
    # decode shapes: cache length is seq_len and the step consumes 1 token
    sliding_window_mode: bool = False


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode",
                            sliding_window_mode=True),
}

# Serving window used by attention archs for long_500k (DESIGN.md §4).
LONG_CONTEXT_WINDOW = 8_192


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
