"""Config registry.  ``--arch <id>`` ids use dashes; module files use
underscores.  ``load_all()`` imports every config module so the registry is
populated."""
from repro.configs.base import ModelConfig, get_config, list_configs, register  # noqa
from repro.configs.shapes import INPUT_SHAPES, InputShape, get_shape, LONG_CONTEXT_WINDOW  # noqa

ASSIGNED_ARCHS = (
    "granite-moe-3b-a800m",
    "musicgen-large",
    "qwen2-vl-2b",
    "starcoder2-7b",
    "yi-9b",
    "zamba2-2.7b",
    "rwkv6-1.6b",
    "stablelm-1.6b",
    "gemma3-12b",
    "olmoe-1b-7b",
)

PAPER_MODELS = ("gte-base-en-v1.5", "sheared-llama-2.7b")

_LOADED = False


def load_all():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        granite_moe_3b_a800m, musicgen_large, qwen2_vl_2b, starcoder2_7b,
        yi_9b, zamba2_2p7b, rwkv6_1p6b, stablelm_1p6b, gemma3_12b,
        olmoe_1b_7b, paper_models,
    )
    _LOADED = True


load_all()
