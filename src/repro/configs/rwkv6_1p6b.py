"""rwkv6-1.6b (Finch) [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536.  Data-dependent decay WKV6 recurrence. [arXiv:2404.05892]

num_heads here is the WKV head count (d_model / ssm_head_dim = 32 heads of 64).
Decode state is O(1) in sequence length: (B, H, d_head, d_head) per layer plus
the token-shift carry — this arch runs long_500k natively.
"""
from repro.configs.base import ModelConfig, register


@register("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        arch_type="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,               # wkv heads
        num_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        block_pattern=("rwkv6",),
        ssm_state_size=64,          # = ssm_head_dim: matrix-valued state
        ssm_head_dim=64,
        tie_embeddings=False,
        source="arXiv:2404.05892",
        notes="Finch: per-channel data-dependent decay via low-rank (lora) proj",
    )
