"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152.  GQA + RoPE. [arXiv:2402.19173]
"""
from repro.configs.base import ModelConfig, register


@register("starcoder2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        arch_type="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        block_pattern=("attn",),
        rope_theta=100_000.0,
        tie_embeddings=False,
        source="arXiv:2402.19173",
        notes="36 heads is not a multiple of the 16-way model axis: relies on "
              "GSPMD padding at baseline (see EXPERIMENTS.md §Perf)",
    )
