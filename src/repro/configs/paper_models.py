"""The paper's own two models (Table 3), expressed in the same config system.

* ``gte-base-en-v1.5`` — the embedding model EdgeRAG regenerates cluster
  embeddings with (dim 768).  We model it as a 12-layer bidirectional encoder;
  its forward cost is what Alg. 1/2/3 profile and trade against storage.
* ``sheared-llama-2.7b`` — the generation model; its prefill latency is the
  second TTFT term.
"""
from repro.configs.base import ModelConfig, register


@register("gte-base-en-v1.5")
def gte_base() -> ModelConfig:
    return ModelConfig(
        name="gte-base-en-v1.5",
        arch_type="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=30528,
        block_pattern=("attn",),
        rope_theta=10_000.0,
        source="arXiv:2308.03281 (gte); paper Table 3",
        notes="embedding model, dim=768; used bidirectionally (is_causal=False)",
    )


@register("sheared-llama-2.7b")
def sheared_llama() -> ModelConfig:
    return ModelConfig(
        name="sheared-llama-2.7b",
        arch_type="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab_size=32000,
        block_pattern=("attn",),
        rope_theta=10_000.0,
        tie_embeddings=False,
        source="arXiv:2310.06694; paper Table 3",
        notes="generation model for TTFT prefill",
    )
