"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144.  5:1 local(sliding-window 1024):global attention, 128k context.
[hf:google/gemma-3-1b-pt family]

head_dim=256 (gemma3 uses wide heads: q_dim 4096 != d_model).  Pattern is
(swa x5, attn x1) repeated 8 times = 48 layers.  Logit softcapping per gemma.
"""
from repro.configs.base import ModelConfig, register


@register("gemma3-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        arch_type="dense",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        block_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
        sliding_window=1024,
        rope_theta=1_000_000.0,
        attn_logit_softcap=0.0,
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
        notes="5:1 local:global; local layers window=1024. For long_500k the "
              "global layers switch to the 8192 serving window (DESIGN.md §4)",
    )
