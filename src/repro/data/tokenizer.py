"""Hashing word tokenizer — deterministic, vocabulary-free.

The paper's stack uses a trained sentencepiece; offline we hash whitespace
words into a fixed id space.  Deterministic across processes (no PYTHONHASHSEED
dependence: FNV-1a).
"""
from __future__ import annotations

from typing import List

import numpy as np


def _fnv1a(s: str) -> int:
    h = 0xCBF29CE484222325
    for ch in s.encode("utf-8"):
        h ^= ch
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class HashingTokenizer:
    def __init__(self, vocab_size: int = 30528, lowercase: bool = True):
        self.vocab_size = vocab_size
        self.lowercase = lowercase
        self.pad_id = 0
        self.bos_id = 1

    def encode(self, text: str, max_len: int = 0) -> List[int]:
        if self.lowercase:
            text = text.lower()
        ids = [self.bos_id] + [
            2 + _fnv1a(w) % (self.vocab_size - 2) for w in text.split()]
        if max_len:
            ids = ids[:max_len]
        return ids

    def encode_batch(self, texts: List[str], max_len: int) -> np.ndarray:
        """Padded (B, max_len) int32 + attention mask."""
        out = np.zeros((len(texts), max_len), np.int32)
        mask = np.zeros((len(texts), max_len), np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t, max_len)
            out[i, :len(ids)] = ids
            mask[i, :len(ids)] = 1
        return out, mask
