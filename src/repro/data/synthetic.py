"""Synthetic BEIR-like corpora matched to the paper's workload structure.

Real BEIR isn't downloadable offline, so we generate corpora that preserve
the three properties EdgeRAG exploits (Table 2, Fig. 4, Fig. 5):

  1. topical cluster structure with a LOG-NORMAL size tail — a few clusters
     are far larger than the median (Fig. 5's tail-heavy generation cost);
  2. skewed query access with the paper's chunk REUSE RATIOS — queries
     revisit clusters Zipf-style (Table 2 'Reuse Ratio' column);
  3. per-chunk text whose char count drives the embedding cost model.

Each dataset entry carries the paper's Table 2 identity (records, embedding
bytes, fits-in-memory flag) so benchmarks can scale the cost model's device
memory to reproduce the in/out-of-memory regimes at laptop record counts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.embedder import TableEmbedder

_WORDS = ("the quick brown fox jumps over lazy dog alpha beta gamma delta "
          "epsilon zeta eta theta iota kappa lambda sigma tau phi chi psi "
          "omega data vector index query cluster memory cache edge device "
          "retrieval augmented generation model token latency storage").split()


@dataclasses.dataclass
class BeirSpec:
    """Paper Table 2 row."""
    name: str
    corpus_mb: float
    n_records: int
    emb_bytes: int
    unique_access: int
    total_access: int
    reuse_ratio: float
    fits_in_memory: bool
    slo_s: float


BEIR_SPECS: Dict[str, BeirSpec] = {
    "scidocs": BeirSpec("scidocs", 86, 3_600, 113 << 20, 1157, 2000, 1.73, True, 1.0),
    "fiqa": BeirSpec("fiqa", 130, 25_000, 217 << 20, 2974, 13286, 4.47, True, 1.0),
    "quora": BeirSpec("quora", 641, 523_000, int(1.5 * 2**30), 15672, 30000, 1.91, True, 1.0),
    "nq": BeirSpec("nq", 4_600, 2_680_000, int(8.3 * 2**30), 8186, 10235, 1.25, False, 1.5),
    "hotpotqa": BeirSpec("hotpotqa", 11_000, 5_420_000, int(15.4 * 2**30), 15519, 22098, 1.42, False, 1.5),
    "fever": BeirSpec("fever", 7_500, 5_230_000, int(18.5 * 2**30), 5783, 13922, 2.41, False, 1.5),
}


@dataclasses.dataclass
class SyntheticDataset:
    name: str
    spec: Optional[BeirSpec]
    chunk_ids: np.ndarray               # (n,)
    texts: List[str]
    embeddings: np.ndarray              # (n, dim) unit-norm (for clustering)
    topic_of_chunk: np.ndarray          # (n,) ground-truth topic
    query_embs: np.ndarray              # (nq, dim)
    query_chars: np.ndarray             # (nq,)
    query_topic: np.ndarray             # (nq,)
    embedder: TableEmbedder
    scale: float = 1.0                  # n_records / spec.n_records

    @property
    def n(self) -> int:
        return len(self.chunk_ids)

    def __post_init__(self):
        self._store: Dict[int, str] = {
            int(i): t for i, t in zip(self.chunk_ids, self.texts)}

    def get_chunks(self, ids: Sequence[int]) -> List[str]:
        return [self._store[int(i)] for i in ids]

    def add_chunk(self, chunk_id: int, text: str,
                  embedding: Optional[np.ndarray] = None):
        """Register a new chunk (online insertion path)."""
        self._store[int(chunk_id)] = text
        if embedding is not None:
            self.embedder.table[int(chunk_id)] = np.asarray(
                embedding, np.float32)

    def relevant(self, qi: int, min_overlap: int = 1) -> set:
        """Ground-truth relevant chunk ids for query qi (same topic)."""
        return set(np.where(self.topic_of_chunk == self.query_topic[qi])[0]
                   .tolist())


def _make_text(did: int, n_chars: int, rng: np.random.Generator) -> str:
    words = [f"doc-{did}"]
    ln = len(words[0])
    while ln < n_chars:
        w = _WORDS[int(rng.integers(len(_WORDS)))]
        words.append(w)
        ln += len(w) + 1
    return " ".join(words)[:max(n_chars, len(words[0]))]


def generate_dataset(name: str = "synthetic", n_records: int = 2000,
                     dim: int = 64, n_topics: int = 64,
                     n_queries: int = 200, seed: int = 0,
                     tail_sigma: float = 1.0, zipf_a: float = 1.3,
                     mean_chunk_chars: int = 300,
                     noise: float = 0.35) -> SyntheticDataset:
    """Build a corpus with log-normal topic sizes and Zipf query reuse."""
    rng = np.random.default_rng(seed)
    spec = BEIR_SPECS.get(name)
    # topic sizes: log-normal tail (Fig. 5 shape), normalized to n_records
    raw = rng.lognormal(mean=0.0, sigma=tail_sigma, size=n_topics)
    sizes = np.maximum(1, np.round(raw / raw.sum() * n_records)).astype(int)
    while sizes.sum() > n_records:
        sizes[np.argmax(sizes)] -= 1
    while sizes.sum() < n_records:
        sizes[np.argmin(sizes)] += 1
    topics = rng.standard_normal((n_topics, dim)).astype(np.float32)
    topics /= np.linalg.norm(topics, axis=1, keepdims=True)

    embs, topic_of_chunk, texts = [], [], []
    table: Dict[int, np.ndarray] = {}
    did = 0
    for t, sz in enumerate(sizes):
        vecs = topics[t][None] + noise * rng.standard_normal((sz, dim))
        vecs = (vecs / np.linalg.norm(vecs, axis=1, keepdims=True)
                ).astype(np.float32)
        for v in vecs:
            chars = max(40, int(rng.normal(mean_chunk_chars,
                                           mean_chunk_chars * 0.3)))
            texts.append(_make_text(did, chars, rng))
            table[did] = v
            embs.append(v)
            topic_of_chunk.append(t)
            did += 1
    embeddings = np.stack(embs)
    topic_of_chunk = np.asarray(topic_of_chunk)

    # queries: Zipf over topics ranked by size (big clusters get re-hit),
    # reproducing Table 2's reuse skew
    rank = np.argsort(-sizes)
    zipf_draws = rng.zipf(zipf_a, size=n_queries)
    q_topics = rank[np.minimum(zipf_draws - 1, n_topics - 1)]
    q_vecs = (topics[q_topics]
              + noise * rng.standard_normal((n_queries, dim)))
    q_vecs = (q_vecs / np.linalg.norm(q_vecs, axis=1, keepdims=True)
              ).astype(np.float32)
    q_chars = rng.integers(40, 160, size=n_queries)

    ds = SyntheticDataset(
        name=name, spec=spec,
        chunk_ids=np.arange(did, dtype=np.int64),
        texts=texts, embeddings=embeddings,
        topic_of_chunk=topic_of_chunk,
        query_embs=q_vecs, query_chars=q_chars,
        query_topic=np.asarray(q_topics),
        embedder=TableEmbedder(table, dim),
        scale=(n_records / spec.n_records) if spec else 1.0)
    return ds


def scaled_beir(name: str, n_records: int = 3000, dim: int = 64,
                n_queries: int = 200, seed: int = 0) -> SyntheticDataset:
    """Scaled-down analogue of a Table 2 dataset (same skew structure).

    The number of topics scales with sqrt(n) and the Zipf parameter is tuned
    per dataset so the realized reuse ratio approaches Table 2's column.
    """
    spec = BEIR_SPECS[name]
    # higher reuse ratio -> more concentrated queries -> larger zipf a
    zipf_a = {"scidocs": 1.5, "fiqa": 2.2, "quora": 1.6, "nq": 1.25,
              "hotpotqa": 1.35, "fever": 1.8}[name]
    n_topics = max(16, int(np.sqrt(n_records) * 2))
    return generate_dataset(name=name, n_records=n_records, dim=dim,
                            n_topics=n_topics, n_queries=n_queries,
                            seed=seed, zipf_a=zipf_a)
