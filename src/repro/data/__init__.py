from repro.data.chunking import chunk_text  # noqa
from repro.data.tokenizer import HashingTokenizer  # noqa
from repro.data.embedder import HashingEmbedder, ModelEmbedder  # noqa
from repro.data.synthetic import (BEIR_SPECS, SyntheticDataset,  # noqa
                                  generate_dataset)
