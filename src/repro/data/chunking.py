"""Corpus pre-processing: split documents into overlapping chunks
(RAG indexing step ①, Fig. 1a)."""
from __future__ import annotations

from typing import List


def chunk_text(text: str, chunk_chars: int = 300,
               overlap_chars: int = 50) -> List[str]:
    """Overlapping character-window chunking, snapped to word boundaries."""
    if len(text) <= chunk_chars:
        return [text] if text else []
    chunks = []
    stride = chunk_chars - overlap_chars
    start = 0
    while start < len(text):
        end = min(start + chunk_chars, len(text))
        if end < len(text):
            # snap end to the previous word boundary
            sp = text.rfind(" ", start, end)
            if sp > start + chunk_chars // 2:
                end = sp
        chunks.append(text[start:end])
        if end == len(text):
            break
        start = end - overlap_chars
        if start <= 0:
            start = end
    return chunks
