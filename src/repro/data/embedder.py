"""Embedding models behind one protocol: ``embed(texts) -> (n, dim) f32``
(unit-normalized), plus ``dim``.

* :class:`HashingEmbedder` — deterministic char-3-gram random projection.
  Fast and similarity-preserving enough for index unit tests.  Trigram
  hashing runs as a vectorized numpy bulk path (FNV-1a over byte windows),
  so one call over many texts is one feature matmul, not a Python loop per
  character.
* :class:`ModelEmbedder` — the real thing: wraps the gte-base JAX model
  (``repro.models.encode``) behind the tokenizer.  Batches are padded to
  power-of-two row counts so the jitted encode compiles once per bucket and
  a coalesced regeneration call is a single device program.
* :class:`TableEmbedder` — oracle for synthetic corpora: chunk texts carry a
  ``doc-<id>`` prefix that resolves to a precomputed vector, so regeneration
  at retrieval time reproduces indexing-time embeddings exactly (the paper's
  determinism assumption for online generation).  Non-oracle rows fall back
  to one batched :class:`HashingEmbedder` call.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence

import numpy as np

from repro.data.tokenizer import HashingTokenizer, _fnv1a

_FNV_BASIS = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


class HashingEmbedder:
    def __init__(self, dim: int = 768, seed: int = 0, n_features: int = 4096):
        self.dim = dim
        rng = np.random.default_rng(seed)
        self._proj = rng.standard_normal((n_features, dim)).astype(np.float32)
        self._proj /= np.sqrt(n_features)
        self.n_features = n_features
        self.calls = 0
        self.chars_embedded = 0

    def _trigram_hashes(self, text: str) -> np.ndarray:
        """FNV-1a hash of every char trigram, vectorized over byte windows.

        Equivalent to hashing ``text[i:i+3]`` per position when the text is
        pure ASCII (one byte per char); multibyte texts take the exact
        per-character path.
        """
        t = text.lower()
        data = t.encode("utf-8")
        if len(data) != len(t):          # non-ASCII: exact per-char fallback
            return np.asarray(
                [_fnv1a(t[i:i + 3]) for i in range(len(t) - 2)], np.uint64)
        arr = np.frombuffer(data, np.uint8).astype(np.uint64)
        n = len(arr) - 2
        if n <= 0:
            return np.zeros(0, np.uint64)
        with np.errstate(over="ignore"):
            h = np.full(n, _FNV_BASIS, np.uint64)
            for j in range(3):
                h ^= arr[j:j + n]
                h *= _FNV_PRIME          # wraps mod 2^64 like _fnv1a
        return h

    def _features(self, text: str) -> np.ndarray:
        h = self._trigram_hashes(text)
        if len(h) == 0:
            return np.zeros(self.n_features, np.float32)
        return np.bincount(
            (h % np.uint64(self.n_features)).astype(np.int64),
            minlength=self.n_features).astype(np.float32)

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        self.calls += 1
        self.chars_embedded += sum(len(t) for t in texts)
        feats = np.stack([self._features(t) for t in texts])
        out = feats @ self._proj
        norms = np.linalg.norm(out, axis=1, keepdims=True)
        return out / np.clip(norms, 1e-9, None)

    __call__ = embed


class TableEmbedder:
    """Oracle lookup for synthetic corpora (texts carry 'doc-<id> ...')."""

    def __init__(self, table: Dict[int, np.ndarray], dim: int):
        self.table = table
        self.dim = dim
        self.calls = 0
        self.chars_embedded = 0
        self._fallback = HashingEmbedder(dim=dim, seed=1)

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        self.calls += 1
        self.chars_embedded += sum(len(t) for t in texts)
        out = np.empty((len(texts), self.dim), np.float32)
        misses: List[int] = []
        for i, t in enumerate(texts):
            if t.startswith("doc-"):
                did = int(t[4:t.index(" ")] if " " in t else t[4:])
                out[i] = self.table[did]
            else:
                misses.append(i)
        if misses:                       # one batched fallback call
            out[misses] = self._fallback.embed([texts[i] for i in misses])
        return out

    __call__ = embed


class ModelEmbedder:
    """gte-base-en-v1.5 (paper Table 3) running in this framework."""

    def __init__(self, cfg=None, params=None, *, max_len: int = 128,
                 seed: int = 0, reduced: bool = True):
        import jax
        from repro.configs import get_config
        from repro.models import encode, init_params
        self._encode = encode
        if cfg is None:
            cfg = get_config("gte-base-en-v1.5")
            if reduced:
                cfg = cfg.reduced(num_layers=2, d_model=256)
        self.cfg = cfg
        self.dim = cfg.d_model
        if params is None:
            params = init_params(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.tokenizer = HashingTokenizer(vocab_size=cfg.vocab_size)
        self.max_len = max_len
        self.calls = 0
        self.chars_embedded = 0

    @functools.cached_property
    def _jit_encode(self):
        import jax
        return jax.jit(lambda p, toks, mask: self._encode(
            p, self.cfg, {"tokens": toks, "attn_mask": mask}))

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        """Batched encode: rows are padded to the next power-of-two batch
        size so the jitted program compiles once per bucket — a coalesced
        regeneration over many clusters is ONE device program."""
        self.calls += 1
        self.chars_embedded += sum(len(t) for t in texts)
        toks, mask = self.tokenizer.encode_batch(list(texts), self.max_len)
        b = toks.shape[0]
        bucket = 1 << max(0, (b - 1).bit_length())
        if bucket > b:                   # pad rows; padded rows sliced off
            pad = ((0, bucket - b), (0, 0))
            toks = np.pad(toks, pad)
            mask = np.pad(mask, pad)
            mask[b:, 0] = 1              # keep padded rows mask-valid
        out = np.asarray(self._jit_encode(self.params, toks, mask))
        return out[:b]

    __call__ = embed
