"""Embedding models behind one protocol: ``embed(texts) -> (n, dim) f32``
(unit-normalized), plus ``dim``.

* :class:`HashingEmbedder` — deterministic char-3-gram random projection.
  Fast and similarity-preserving enough for index unit tests.
* :class:`ModelEmbedder` — the real thing: wraps the gte-base JAX model
  (``repro.models.encode``) behind the tokenizer.  Used by the e2e examples.
* :class:`TableEmbedder` — oracle for synthetic corpora: chunk texts carry a
  ``doc-<id>`` prefix that resolves to a precomputed vector, so regeneration
  at retrieval time reproduces indexing-time embeddings exactly (the paper's
  determinism assumption for online generation).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence

import numpy as np

from repro.data.tokenizer import HashingTokenizer, _fnv1a


class HashingEmbedder:
    def __init__(self, dim: int = 768, seed: int = 0, n_features: int = 4096):
        self.dim = dim
        rng = np.random.default_rng(seed)
        self._proj = rng.standard_normal((n_features, dim)).astype(np.float32)
        self._proj /= np.sqrt(n_features)
        self.n_features = n_features
        self.calls = 0
        self.chars_embedded = 0

    def _features(self, text: str) -> np.ndarray:
        f = np.zeros(self.n_features, np.float32)
        t = text.lower()
        for i in range(len(t) - 2):
            f[_fnv1a(t[i:i + 3]) % self.n_features] += 1.0
        return f

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        self.calls += 1
        self.chars_embedded += sum(len(t) for t in texts)
        feats = np.stack([self._features(t) for t in texts])
        out = feats @ self._proj
        norms = np.linalg.norm(out, axis=1, keepdims=True)
        return out / np.clip(norms, 1e-9, None)

    __call__ = embed


class TableEmbedder:
    """Oracle lookup for synthetic corpora (texts carry 'doc-<id> ...')."""

    def __init__(self, table: Dict[int, np.ndarray], dim: int):
        self.table = table
        self.dim = dim
        self.calls = 0
        self.chars_embedded = 0
        self._fallback = HashingEmbedder(dim=dim, seed=1)

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        self.calls += 1
        self.chars_embedded += sum(len(t) for t in texts)
        out = np.empty((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            if t.startswith("doc-"):
                did = int(t[4:t.index(" ")] if " " in t else t[4:])
                out[i] = self.table[did]
            else:
                out[i] = self._fallback.embed([t])[0]
        return out

    __call__ = embed


class ModelEmbedder:
    """gte-base-en-v1.5 (paper Table 3) running in this framework."""

    def __init__(self, cfg=None, params=None, *, max_len: int = 128,
                 seed: int = 0, reduced: bool = True):
        import jax
        from repro.configs import get_config
        from repro.models import encode, init_params
        self._encode = encode
        if cfg is None:
            cfg = get_config("gte-base-en-v1.5")
            if reduced:
                cfg = cfg.reduced(num_layers=2, d_model=256)
        self.cfg = cfg
        self.dim = cfg.d_model
        if params is None:
            params = init_params(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.tokenizer = HashingTokenizer(vocab_size=cfg.vocab_size)
        self.max_len = max_len
        self.calls = 0
        self.chars_embedded = 0

    @functools.cached_property
    def _jit_encode(self):
        import jax
        return jax.jit(lambda p, toks, mask: self._encode(
            p, self.cfg, {"tokens": toks, "attn_mask": mask}))

    def embed(self, texts: Sequence[str]) -> np.ndarray:
        self.calls += 1
        self.chars_embedded += sum(len(t) for t in texts)
        toks, mask = self.tokenizer.encode_batch(list(texts), self.max_len)
        return np.asarray(self._jit_encode(self.params, toks, mask))

    __call__ = embed
