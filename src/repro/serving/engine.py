"""RAG serving engine: retrieve → assemble context → prefill → decode.

Ties the EdgeRAG index to the generation model.  TTFT = retrieval latency +
prefill latency (paper §3.1); decode is measured but excluded from the
paper's headline metric (it is not optimized by EdgeRAG).

The engine runs the REAL pipeline end to end on this machine (reduced model
configs, synthetic corpora) while accounting edge latency through the cost
model — both are reported on every response.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.core.costs import EdgeCostModel, LatencyBreakdown
from repro.core.faults import DegradationPolicy
from repro.data.tokenizer import HashingTokenizer


@dataclasses.dataclass
class RAGResponse:
    query: str
    chunk_ids: List[int]
    context: List[str]
    output_tokens: List[int]
    retrieval: LatencyBreakdown
    prefill_edge_s: float
    ttft_edge_s: float
    ttft_wall_s: float
    decode_wall_s: float = 0.0
    prefetch_saved_s: float = 0.0    # edge seconds hidden by prefetch overlap
    maintenance_s: float = 0.0       # deferred-maintenance edge seconds the
    #                                  batch drained after decode (amortized;
    #                                  off the TTFT critical path)
    # failure model / degradation ladder (core/faults.py):
    deadline_s: Optional[float] = None   # TTFT deadline this request carried
    outcome: str = "ok"              # "ok" | "degraded" | "missed"
    retries: int = 0                 # storage read attempts retried
    degraded_clusters: int = 0       # probes / regens shed under deadline
    stale_served: int = 0            # stale payloads scored, flagged


class RAGEngine:
    """index + generator behind one ``answer()`` call."""

    def __init__(self, index, generator=None, *,
                 cost_model: Optional[EdgeCostModel] = None,
                 k: int = 10, nprobe: int = 8, max_new_tokens: int = 16,
                 maintenance_budget_s: Optional[float] = None):
        self.index = index
        self.generator = generator        # GeneratorModel or None (sim-only)
        self.cost = cost_model or EdgeCostModel()
        self.k = k
        self.nprobe = nprobe
        self.max_new_tokens = max_new_tokens
        # per-step budget for draining the index's deferred-maintenance
        # queue after decode (None = the scheduler's own default)
        self.maintenance_budget_s = maintenance_budget_s

    def answer_batch(self, queries: Sequence[str], query_embs: np.ndarray,
                     get_chunks: Callable[[Sequence[int]], List[str]],
                     *, batcher=None, prefetch: bool = False,
                     deadlines: Optional[Sequence[Optional[float]]] = None,
                     policy: Optional[DegradationPolicy] = None
                     ) -> List[RAGResponse]:
        """Batched serving path: one ``search_batch`` drives retrieval for
        the whole batch (cross-query cluster dedup + a single coalesced
        embed call), then decode either goes through a
        :class:`~repro.serving.batching.ContinuousBatcher` (``batcher=``,
        prompts admitted into decode slots so retrieval batching and decode
        batching compose) or falls back to the per-query generator.
        Wall-clock figures are amortized uniformly over the batch.

        ``prefetch=True``: plan the batch first (``index.plan_batch``) and
        issue the plan's storage loads ahead of execution, so in edge
        accounting the storage I/O overlaps the rest of retrieval — each
        query's effective retrieval time is ``max(io, compute)`` instead of
        their sum (``prefetch_saved_s`` reports the hidden seconds).
        Retrieved ids/contexts are identical either way.

        ``deadlines``: per-request TTFT deadline budgets (edge seconds,
        None entries = no deadline).  A fraction of each deadline
        (``DegradationPolicy.prefill_reserve_frac``) is reserved for
        prefill; the rest becomes the retrieval budget handed to
        ``search_batch``, which sheds work down the degradation ladder
        (core/faults.py) instead of blowing it.  Each response reports its
        ``outcome`` ("ok" / "degraded" / "missed") plus the shed counters.
        """
        if not len(queries):
            return []
        t0 = time.perf_counter()
        query_embs = np.atleast_2d(np.asarray(query_embs, np.float32))
        nq = len(queries)
        kw = {}
        prefetch = prefetch and hasattr(self.index, "plan_batch")
        retrieval_deadlines = None
        if deadlines is not None:
            assert len(deadlines) == nq, \
                f"{len(deadlines)} deadlines for {nq} queries"
            policy = policy or DegradationPolicy()
            retrieval_deadlines = [
                None if d is None else d * (1.0 - policy.prefill_reserve_frac)
                for d in deadlines]
            kw["deadlines"] = retrieval_deadlines
            kw["policy"] = policy
        if prefetch:
            kw["plan"] = self.index.plan_batch(
                query_embs, self.nprobe, prefetch_storage=True,
                deadlines=retrieval_deadlines, policy=policy,
                query_chars=[len(q) for q in queries])
            kw.pop("deadlines", None)    # the plan carries them already
            kw.pop("policy", None)
        ids, _, lats = self.index.search_batch(
            query_embs, self.k, self.nprobe,
            query_chars=[len(q) for q in queries], **kw)
        id_lists = [[int(i) for i in ids[qi] if i >= 0] for qi in range(nq)]
        contexts = [get_chunks(idl) for idl in id_lists]
        prompts = [" ".join(ctx + [q]) for ctx, q in zip(contexts, queries)]
        retrieval_wall = time.perf_counter() - t0

        out_tokens: List[List[int]] = [[] for _ in range(nq)]
        decode_wall = 0.0
        if batcher is not None:
            tokenizer = (self.generator.tokenizer if self.generator
                         is not None else HashingTokenizer(
                             vocab_size=batcher.cfg.vocab_size))
            t1 = time.perf_counter()
            completed = batcher.run(
                [{"id": qi,
                  "prompt_tokens": tokenizer.encode(p, batcher.max_len),
                  "max_new_tokens": self.max_new_tokens}
                 for qi, p in enumerate(prompts)])
            decode_wall = (time.perf_counter() - t1) / nq
            for qi in range(nq):
                out_tokens[qi] = completed.get(qi, [])
        elif self.generator is not None:
            t1 = time.perf_counter()
            for qi, p in enumerate(prompts):
                out_tokens[qi] = self.generator.generate(
                    p, self.max_new_tokens)
            decode_wall = (time.perf_counter() - t1) / nq

        # deferred index maintenance drains AFTER decode — split / merge /
        # restore work queued by online inserts/removes runs between serving
        # steps instead of inside a query's TTFT window
        maintenance_s = 0.0
        sched = getattr(self.index, "maintenance", None)
        if sched is not None and len(sched):
            maintenance_s = sched.drain(self.maintenance_budget_s).edge_s

        responses = []
        for qi in range(nq):
            n_prompt_tokens = max(1, len(prompts[qi]) // 3)
            prefill_edge = self.cost.prefill_latency(n_prompt_tokens)
            retrieval_edge = lats[qi].retrieval_s
            saved = 0.0
            if prefetch:
                # storage I/O was issued at plan time: it runs under the
                # rest of this query's retrieval work instead of before it
                # (an injected stall is I/O-side, so it overlaps too)
                io = lats[qi].l2_storage_load_s + lats[qi].l2_stall_s
                saved = min(io, retrieval_edge - io)
            ttft_edge = retrieval_edge - saved + prefill_edge
            deadline = None if deadlines is None else deadlines[qi]
            degraded = bool(lats[qi].degraded_clusters
                            or lats[qi].stale_served)
            outcome = "ok"
            if deadline is not None and ttft_edge > deadline:
                outcome = "missed"
            elif degraded:
                outcome = "degraded"
            responses.append(RAGResponse(
                query=queries[qi], chunk_ids=id_lists[qi],
                context=contexts[qi], output_tokens=out_tokens[qi],
                retrieval=lats[qi], prefill_edge_s=prefill_edge,
                ttft_edge_s=ttft_edge,
                ttft_wall_s=retrieval_wall / nq,
                decode_wall_s=decode_wall,
                prefetch_saved_s=saved,
                maintenance_s=maintenance_s / nq,
                deadline_s=deadline, outcome=outcome,
                retries=lats[qi].retries,
                degraded_clusters=lats[qi].degraded_clusters,
                stale_served=lats[qi].stale_served))
        return responses

    def answer(self, query: str, query_emb: np.ndarray,
               get_chunks: Callable[[Sequence[int]], List[str]],
               *, prefetch: bool = False,
               deadline_s: Optional[float] = None,
               policy: Optional[DegradationPolicy] = None) -> RAGResponse:
        """Single query — a batch of one through :meth:`answer_batch`
        (mirroring ``EdgeRAGIndex.search`` → ``search_batch``)."""
        query_embs = np.atleast_2d(np.asarray(query_emb, np.float32))
        assert query_embs.shape[0] == 1
        return self.answer_batch(
            [query], query_embs, get_chunks, prefetch=prefetch,
            deadlines=None if deadline_s is None else [deadline_s],
            policy=policy)[0]


class GeneratorModel:
    """The generation model (Sheared-LLaMA stand-in) on the JAX substrate."""

    def __init__(self, cfg=None, params=None, *, seed: int = 0,
                 reduced: bool = True, max_prompt: int = 128):
        import jax
        from repro.configs import get_config
        from repro.models import decode_step, init_cache, init_params, prefill
        if cfg is None:
            cfg = get_config("sheared-llama-2.7b")
            if reduced:
                cfg = cfg.reduced(num_layers=2, d_model=256)
        self.cfg = cfg
        if params is None:
            params = init_params(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.tokenizer = HashingTokenizer(vocab_size=cfg.vocab_size)
        self.max_prompt = max_prompt
        self._prefill = jax.jit(
            lambda p, b, c: prefill(p, self.cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, c, n: decode_step(p, self.cfg, t, c, n))
        self._init_cache = init_cache

    def generate(self, prompt: str, max_new_tokens: int = 16) -> List[int]:
        import jax.numpy as jnp
        ids = self.tokenizer.encode(prompt, self.max_prompt)
        pad = self.max_prompt - len(ids)
        toks = jnp.asarray([[0] * pad + ids], jnp.int32)  # left-pad
        caches = self._init_cache(self.cfg, 1, self.max_prompt
                                  + max_new_tokens)
        logits, caches = self._prefill(self.params, {"tokens": toks}, caches)
        out = []
        cache_len = self.max_prompt
        tok = logits.argmax(-1).astype(jnp.int32)[:, None]
        for _ in range(max_new_tokens):
            out.append(int(tok[0, 0]))
            logits, caches = self._decode(self.params, tok, caches,
                                          cache_len)
            tok = logits.argmax(-1).astype(jnp.int32)[:, None]
            cache_len += 1
        return out
