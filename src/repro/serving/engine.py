"""RAG serving engine: retrieve → assemble context → prefill → decode.

Ties the EdgeRAG index to the generation model.  TTFT = retrieval latency +
prefill latency (paper §3.1); decode is measured but excluded from the
paper's headline metric (it is not optimized by EdgeRAG).

The engine runs the REAL pipeline end to end on this machine (reduced model
configs, synthetic corpora) while accounting edge latency through the cost
model — both are reported on every response.

STAGED SERVING (serving/pipeline.py): ``answer_batch`` is internally four
explicit stages over a :class:`BatchJob` —

  ``stage_plan``    S1  probe + plan           (``index.search_begin``)
  ``stage_fetch``   S2  storage fetch / regen  (``index.search_fetch``)
  ``stage_score``   S3  slab pack + score + prompt assembly
                        (``index.search_finish``)
  ``stage_decode``  S4  prefill + decode ticks (batcher / generator)

Run back-to-back they reproduce the sequential path exactly (bit-identical
ids / charges); the :class:`~repro.serving.pipeline.StagedPipeline` instead
fires them as independent stage resources on the modeled clock so batch
N+1's retrieval hides under batch N's decode.  Each stage records its
modeled service time in ``BatchJob.stage_edge_s`` — the occupancy the
pipeline schedules with.

Deferred-maintenance drain ownership is explicit: with
``maintenance_owner="engine"`` (default) ``answer_batch`` drains the
index's queue after decode; ``"external"`` means some other component (a
``RequestScheduler`` idle-gap hook, or the pipeline's bubble-filler) owns
draining and the engine never touches the queue — previously both could
run in the same configuration and double-drain.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.costs import EdgeCostModel, LatencyBreakdown
from repro.core.faults import DegradationPolicy
from repro.data.tokenizer import HashingTokenizer


@dataclasses.dataclass
class RAGResponse:
    query: str
    chunk_ids: List[int]
    context: List[str]
    output_tokens: List[int]
    retrieval: LatencyBreakdown
    prefill_edge_s: float
    ttft_edge_s: float
    ttft_wall_s: float
    decode_wall_s: float = 0.0
    decode_edge_s: float = 0.0       # modeled decode ticks for the batch
    prefetch_saved_s: float = 0.0    # edge seconds hidden by prefetch overlap
    maintenance_s: float = 0.0       # deferred-maintenance edge seconds the
    #                                  batch drained after decode (amortized;
    #                                  off the TTFT critical path)
    queue_wait_s: float = 0.0        # modeled wait in stage queues before S1
    #                                  fired (staged pipeline only)
    # failure model / degradation ladder (core/faults.py):
    deadline_s: Optional[float] = None   # TTFT deadline this request carried
    #                                  (queue wait already subtracted when it
    #                                  came through the staged pipeline)
    outcome: str = "ok"              # "ok" | "degraded" | "missed"
    retries: int = 0                 # storage read attempts retried
    degraded_clusters: int = 0       # probes / regens shed under deadline
    stale_served: int = 0            # stale payloads scored, flagged


@dataclasses.dataclass
class BatchJob:
    """One batch of queries moving through the staged serving pipeline.

    Created by :meth:`RAGEngine.make_job`; each ``stage_*`` method consumes
    the fields of the previous stage and fills its own.  ``stage_edge_s``
    maps stage name ("s1".."s4") to that stage's modeled service time for
    this batch — unique work, not per-query accounting: the fused centroid
    top-k counts once per batch, shared-cluster resolutions once per owner
    (per-query ``LatencyBreakdown`` attribution is unchanged).
    """
    queries: List[str]
    query_embs: np.ndarray
    get_chunks: Optional[Callable[[Sequence[int]], List[str]]]
    deadlines: Optional[List[Optional[float]]] = None
    policy: Optional[DegradationPolicy] = None
    prefetch: bool = False
    tenants: Optional[List[str]] = None     # per-query tenant ids when the
    #                                         engine fronts a TenantRouter
    # stage products:
    state: Any = None                       # BatchSearchState (S1 → S3)
    ids: Optional[np.ndarray] = None        # (Q, k) chunk ids (S3)
    lats: Optional[List[LatencyBreakdown]] = None
    id_lists: Optional[List[List[int]]] = None
    contexts: Optional[List[List[str]]] = None
    prompts: Optional[List[str]] = None
    prefill_edge: Optional[List[float]] = None
    out_tokens: Optional[List[List[int]]] = None
    decode_wall: float = 0.0
    retrieval_wall: float = 0.0
    maintenance_s: float = 0.0
    queue_wait_s: float = 0.0               # set by the pipeline at S1 fire
    replans: int = 0                        # stale-plan S1 re-entries
    stage_edge_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def nq(self) -> int:
        return len(self.queries)


class RAGEngine:
    """index + generator behind one ``answer()`` call."""

    def __init__(self, index, generator=None, *,
                 cost_model: Optional[EdgeCostModel] = None,
                 k: int = 10, nprobe: int = 8, max_new_tokens: int = 16,
                 maintenance_budget_s: Optional[float] = None,
                 maintenance_owner: str = "engine"):
        assert maintenance_owner in ("engine", "external"), maintenance_owner
        self.index = index
        self.generator = generator        # GeneratorModel or None (sim-only)
        self.cost = cost_model or EdgeCostModel()
        self.k = k
        self.nprobe = nprobe
        self.max_new_tokens = max_new_tokens
        # per-step budget for draining the index's deferred-maintenance
        # queue after decode (None = the scheduler's own default)
        self.maintenance_budget_s = maintenance_budget_s
        # who drains the index's deferred-maintenance queue: "engine" =
        # answer_batch drains after decode (the default); "external" = a
        # scheduler hook or the staged pipeline owns draining and the
        # engine never touches the queue.  Exactly one component drains.
        self.maintenance_owner = maintenance_owner

    def answer_batch(self, queries: Sequence[str], query_embs: np.ndarray,
                     get_chunks: Optional[Callable[[Sequence[int]],
                                                   List[str]]] = None,
                     *, batcher=None, prefetch: bool = False,
                     deadlines: Optional[Sequence[Optional[float]]] = None,
                     policy: Optional[DegradationPolicy] = None,
                     tenants: Optional[Sequence[str]] = None
                     ) -> List[RAGResponse]:
        """Batched serving path: one ``search_batch`` drives retrieval for
        the whole batch (cross-query cluster dedup + a single coalesced
        embed call), then decode either goes through a
        :class:`~repro.serving.batching.ContinuousBatcher` (``batcher=``,
        prompts admitted into decode slots so retrieval batching and decode
        batching compose) or falls back to the per-query generator.
        Wall-clock figures are amortized uniformly over the batch.

        ``prefetch=True``: plan the batch first (``index.plan_batch``) and
        issue the plan's storage loads ahead of execution, so in edge
        accounting the storage I/O overlaps the rest of retrieval — each
        query's effective retrieval time is ``max(io, compute)`` instead of
        their sum (``prefetch_saved_s`` reports the hidden seconds).
        Retrieved ids/contexts are identical either way.

        ``deadlines``: per-request TTFT deadline budgets (edge seconds,
        None entries = no deadline).  A fraction of each deadline
        (``DegradationPolicy.prefill_reserve_frac``) is reserved for
        prefill; the rest becomes the retrieval budget handed to
        ``search_batch``, which sheds work down the degradation ladder
        (core/faults.py) instead of blowing it.  Each response reports its
        ``outcome`` ("ok" / "degraded" / "missed") plus the shed counters.

        ``tenants``: one tenant id per query (or a single id broadcast)
        when ``index`` is a :class:`~repro.core.tenant.TenantRouter` —
        retrieval fuses the mixed batch through the router's shared slab
        engine and ``get_chunks`` may be omitted (contexts route to each
        query's own tenant corpus).
        """
        if not len(queries):
            return []
        job = self.make_job(queries, query_embs, get_chunks,
                            deadlines=deadlines, policy=policy,
                            prefetch=prefetch, tenants=tenants)
        self.stage_plan(job)
        self.stage_fetch(job)
        self.stage_score(job)
        self.stage_decode(job, batcher=batcher)
        # deferred index maintenance drains AFTER decode — split / merge /
        # restore work queued by online inserts/removes runs between serving
        # steps instead of inside a query's TTFT window.  Only when the
        # engine OWNS draining: with maintenance_owner="external" a
        # scheduler hook / the staged pipeline drains instead (never both).
        sched = getattr(self.index, "maintenance", None)
        if (self.maintenance_owner == "engine" and sched is not None
                and len(sched)):
            job.maintenance_s = sched.drain(self.maintenance_budget_s).edge_s
        return self.finalize(job)

    # ------------------------------------------------------------------
    # the staged path: make_job + stage_plan/fetch/score/decode + finalize
    # ------------------------------------------------------------------
    def make_job(self, queries: Sequence[str], query_embs: np.ndarray,
                 get_chunks: Optional[Callable[[Sequence[int]],
                                               List[str]]] = None,
                 *, deadlines: Optional[Sequence[Optional[float]]] = None,
                 policy: Optional[DegradationPolicy] = None,
                 prefetch: bool = False,
                 tenants: Optional[Sequence[str]] = None) -> BatchJob:
        """Wrap one batch as a :class:`BatchJob` for the staged path."""
        query_embs = np.atleast_2d(np.asarray(query_embs, np.float32))
        if deadlines is not None:
            assert len(deadlines) == len(queries), \
                f"{len(deadlines)} deadlines for {len(queries)} queries"
            policy = policy or DegradationPolicy()
        if tenants is not None:
            if isinstance(tenants, str):
                tenants = [tenants] * len(queries)
            tenants = [str(t) for t in tenants]
            assert len(tenants) == len(queries), \
                f"{len(tenants)} tenant ids for {len(queries)} queries"
        else:
            assert get_chunks is not None, \
                "get_chunks is required without tenants"
        return BatchJob(queries=list(queries), query_embs=query_embs,
                        get_chunks=get_chunks,
                        deadlines=None if deadlines is None
                        else list(deadlines),
                        policy=policy,
                        prefetch=prefetch
                        and (tenants is not None
                             or hasattr(self.index, "plan_batch")),
                        tenants=tenants)

    def stage_plan(self, job: BatchJob) -> BatchJob:
        """S1 — probe + plan: fused centroid top-k, tier planning, rung-1
        probe trimming under the job's (queue-wait-adjusted) deadlines.
        Service time: per-query embed charges + ONE fused centroid search
        (it runs once per batch, not once per query)."""
        t0 = time.perf_counter()
        kw = {}
        retrieval_deadlines = None
        if job.deadlines is not None:
            retrieval_deadlines = [
                None if d is None
                else d * (1.0 - job.policy.prefill_reserve_frac)
                for d in job.deadlines]
            kw["deadlines"] = retrieval_deadlines
            kw["policy"] = job.policy
        if job.tenants is not None:
            # TenantRouter path: the router plans per tenant (handling
            # prefetch internally) and merges into one cross-tenant plan
            job.state = self.index.search_begin(
                job.query_embs, self.k, self.nprobe,
                query_chars=[len(q) for q in job.queries],
                tenants=job.tenants, deadlines=retrieval_deadlines,
                policy=job.policy, prefetch=job.prefetch)
        else:
            if job.prefetch:
                kw["plan"] = self.index.plan_batch(
                    job.query_embs, self.nprobe, prefetch_storage=True,
                    deadlines=retrieval_deadlines, policy=job.policy,
                    query_chars=[len(q) for q in job.queries])
                kw.pop("deadlines", None)    # the plan carries them already
                kw.pop("policy", None)
            job.state = self.index.search_begin(
                job.query_embs, self.k, self.nprobe,
                query_chars=[len(q) for q in job.queries], **kw)
        job.retrieval_wall += time.perf_counter() - t0
        lats = job.state.lats
        # one fused centroid launch per index in the batch: one for a
        # standalone index, one PER TENANT through a router
        job.stage_edge_s["s1"] = (
            sum(lat.embed_query_s for lat in lats)
            + job.state.centroid_total_s)
        return job

    def stage_fetch(self, job: BatchJob, *,
                    extra_wait_s: float = 0.0) -> BatchJob:
        """S2 — storage fetch / regen: raw payload resolution (batched
        ``get_many_raw``, cache, coalesced regeneration, fault retries /
        stalls) with degradation rungs 2-3 against the plan's budgets.
        ``extra_wait_s``: modeled seconds this batch sat in the S2 queue —
        shrinks the plan's remaining retrieval budgets so the ladder sees
        queue wait, not just execution time.  Service time: the owner
        charges (each unique cluster is resolved exactly once)."""
        t0 = time.perf_counter()
        job.state.shrink_deadlines(extra_wait_s)
        self.index.search_fetch(job.state)
        job.retrieval_wall += time.perf_counter() - t0
        job.stage_edge_s["s2"] = sum(lat.stage_s("fetch")
                                     for lat in job.state.lats)
        return job

    def stage_score(self, job: BatchJob) -> BatchJob:
        """S3 — slab pack + multi-query top-k scoring, then context fetch
        and prompt assembly.  Service time: the score-group charges (pack
        copies, fused dequant, shared-hit DRAM re-reads, fused top-k)."""
        t0 = time.perf_counter()
        job.ids, _, job.lats = self.index.search_finish(job.state)
        nq = job.nq
        job.id_lists = [[int(i) for i in job.ids[qi] if i >= 0]
                        for qi in range(nq)]
        if job.tenants is not None:
            job.contexts = [self.index.get_chunks(t, idl)
                            for t, idl in zip(job.tenants, job.id_lists)]
        else:
            job.contexts = [job.get_chunks(idl) for idl in job.id_lists]
        job.prompts = [" ".join(ctx + [q])
                       for ctx, q in zip(job.contexts, job.queries)]
        job.prefill_edge = [
            self.cost.prefill_latency(max(1, len(p) // 3))
            for p in job.prompts]
        job.retrieval_wall += time.perf_counter() - t0
        job.stage_edge_s["s3"] = sum(lat.stage_s("score")
                                     for lat in job.lats)
        return job

    def stage_decode(self, job: BatchJob, *, batcher=None) -> BatchJob:
        """S4 — prefill + decode ticks, through a
        :class:`~repro.serving.batching.ContinuousBatcher` (``batcher=``)
        or the per-query generator.  Service time: summed per-query prefill
        + ONE decode pass (continuous-batching ticks advance every live
        slot, so batch decode is per-token, not per-(token, slot))."""
        nq = job.nq
        job.out_tokens = [[] for _ in range(nq)]
        job.decode_wall = 0.0
        if batcher is not None:
            tokenizer = (self.generator.tokenizer if self.generator
                         is not None else HashingTokenizer(
                             vocab_size=batcher.cfg.vocab_size))
            t1 = time.perf_counter()
            completed = batcher.run(
                [{"id": qi,
                  "prompt_tokens": tokenizer.encode(p, batcher.max_len),
                  "max_new_tokens": self.max_new_tokens}
                 for qi, p in enumerate(job.prompts)])
            job.decode_wall = (time.perf_counter() - t1) / nq
            for qi in range(nq):
                job.out_tokens[qi] = completed.get(qi, [])
        elif self.generator is not None:
            t1 = time.perf_counter()
            for qi, p in enumerate(job.prompts):
                job.out_tokens[qi] = self.generator.generate(
                    p, self.max_new_tokens)
            job.decode_wall = (time.perf_counter() - t1) / nq
        job.stage_edge_s["s4"] = (
            sum(job.prefill_edge)
            + self.cost.decode_latency(self.max_new_tokens))
        return job

    def finalize(self, job: BatchJob) -> List[RAGResponse]:
        """Assemble one :class:`RAGResponse` per query from the finished
        job (pure accounting — no index or model work)."""
        nq = job.nq
        decode_edge = self.cost.decode_latency(self.max_new_tokens)
        responses = []
        for qi in range(nq):
            prefill_edge = job.prefill_edge[qi]
            lat = job.lats[qi]
            retrieval_edge = lat.retrieval_s
            saved = 0.0
            if job.prefetch:
                # storage I/O was issued at plan time: it runs under the
                # rest of this query's retrieval work instead of before it
                # (an injected stall is I/O-side, so it overlaps too)
                io = lat.l2_storage_load_s + lat.l2_stall_s
                saved = min(io, retrieval_edge - io)
            ttft_edge = retrieval_edge - saved + prefill_edge
            deadline = (None if job.deadlines is None
                        else job.deadlines[qi])
            degraded = bool(lat.degraded_clusters or lat.stale_served)
            outcome = "ok"
            if deadline is not None and ttft_edge > deadline:
                outcome = "missed"
            elif degraded:
                outcome = "degraded"
            responses.append(RAGResponse(
                query=job.queries[qi], chunk_ids=job.id_lists[qi],
                context=job.contexts[qi], output_tokens=job.out_tokens[qi],
                retrieval=lat, prefill_edge_s=prefill_edge,
                ttft_edge_s=ttft_edge,
                ttft_wall_s=job.retrieval_wall / nq,
                decode_wall_s=job.decode_wall,
                decode_edge_s=decode_edge,
                prefetch_saved_s=saved,
                maintenance_s=job.maintenance_s / nq,
                queue_wait_s=job.queue_wait_s,
                deadline_s=deadline, outcome=outcome,
                retries=lat.retries,
                degraded_clusters=lat.degraded_clusters,
                stale_served=lat.stale_served))
        return responses

    def answer(self, query: str, query_emb: np.ndarray,
               get_chunks: Optional[Callable[[Sequence[int]],
                                             List[str]]] = None,
               *, prefetch: bool = False,
               deadline_s: Optional[float] = None,
               policy: Optional[DegradationPolicy] = None,
               tenant: Optional[str] = None) -> RAGResponse:
        """Single query — a batch of one through :meth:`answer_batch`
        (mirroring ``EdgeRAGIndex.search`` → ``search_batch``)."""
        query_embs = np.atleast_2d(np.asarray(query_emb, np.float32))
        assert query_embs.shape[0] == 1
        return self.answer_batch(
            [query], query_embs, get_chunks, prefetch=prefetch,
            deadlines=None if deadline_s is None else [deadline_s],
            policy=policy,
            tenants=None if tenant is None else [tenant])[0]


class GeneratorModel:
    """The generation model (Sheared-LLaMA stand-in) on the JAX substrate."""

    def __init__(self, cfg=None, params=None, *, seed: int = 0,
                 reduced: bool = True, max_prompt: int = 128):
        import jax
        from repro.configs import get_config
        from repro.models import decode_step, init_cache, init_params, prefill
        if cfg is None:
            cfg = get_config("sheared-llama-2.7b")
            if reduced:
                cfg = cfg.reduced(num_layers=2, d_model=256)
        self.cfg = cfg
        if params is None:
            params = init_params(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.tokenizer = HashingTokenizer(vocab_size=cfg.vocab_size)
        self.max_prompt = max_prompt
        self._prefill = jax.jit(
            lambda p, b, c: prefill(p, self.cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, c, n: decode_step(p, self.cfg, t, c, n))
        self._init_cache = init_cache

    def generate(self, prompt: str, max_new_tokens: int = 16) -> List[int]:
        import jax.numpy as jnp
        ids = self.tokenizer.encode(prompt, self.max_prompt)
        pad = self.max_prompt - len(ids)
        toks = jnp.asarray([[0] * pad + ids], jnp.int32)  # left-pad
        caches = self._init_cache(self.cfg, 1, self.max_prompt
                                  + max_new_tokens)
        logits, caches = self._prefill(self.params, {"tokens": toks}, caches)
        out = []
        cache_len = self.max_prompt
        tok = logits.argmax(-1).astype(jnp.int32)[:, None]
        for _ in range(max_new_tokens):
            out.append(int(tok[0, 0]))
            logits, caches = self._decode(self.params, tok, caches,
                                          cache_len)
            tok = logits.argmax(-1).astype(jnp.int32)[:, None]
            cache_len += 1
        return out
