"""Request scheduler: FIFO admission with SLO tracking and batch grouping.

EdgeRAG is a single-user edge system, so the paper's serving loop is one
query at a time; the scheduler still models arrival queues and SLO misses so
the benchmarks can report tail latencies under load, and groups decode
requests into fixed-size batches (what serve_step lowers for on the pod).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional


@dataclasses.dataclass(order=True)
class Request:
    arrival_s: float
    rid: int = dataclasses.field(compare=False)
    query: str = dataclasses.field(compare=False, default="")
    query_emb: Optional[object] = dataclasses.field(compare=False,
                                                    default=None)
    query_chars: int = dataclasses.field(compare=False, default=0)
    slo_s: float = dataclasses.field(compare=False, default=1.0)
    # filled on completion
    start_s: float = dataclasses.field(compare=False, default=0.0)
    finish_s: float = dataclasses.field(compare=False, default=0.0)

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def slo_met(self) -> bool:
        return self.latency_s <= self.slo_s


class RequestScheduler:
    def __init__(self):
        self._queue: List[Request] = []
        self.completed: List[Request] = []
        self._next_rid = 0
        self.maintenance_s = 0.0     # total deferred-maintenance seconds

    def submit(self, arrival_s: float, query: str = "", query_emb=None,
               query_chars: int = 0, slo_s: float = 1.0) -> Request:
        req = Request(arrival_s=arrival_s, rid=self._next_rid, query=query,
                      query_emb=query_emb, query_chars=query_chars,
                      slo_s=slo_s)
        self._next_rid += 1
        heapq.heappush(self._queue, req)
        return req

    def run(self, serve_fn: Callable[[Request], float],
            maintenance_fn: Optional[Callable[[Optional[float]], float]]
            = None) -> List[Request]:
        """Drain the queue; serve_fn returns the service time in seconds.

        The device is serially occupied (edge device: one query at a time);
        queueing delay accrues when arrivals outpace service.

        ``maintenance_fn`` (deferred index maintenance, wrapping
        ``MaintenanceScheduler.drain``) models background work that YIELDS
        to foreground requests: it only runs when the device goes idle — no
        request waiting at the current clock — and receives the idle gap
        until the next known arrival (None when the queue is empty) so it
        can size its work to fit (a strict-budget drain).  It returns the
        modeled seconds it occupied the device; work that fits the gap is
        free, overrun delays the next request by the overrun only.  Under
        sustained backlog maintenance keeps deferring — exactly the
        sync-vs-deferred trade-off the online-churn benchmark measures.
        """
        clock = 0.0
        while self._queue:
            req = heapq.heappop(self._queue)
            clock = max(clock, req.arrival_s)
            req.start_s = clock
            service_s = serve_fn(req)
            clock += service_s
            req.finish_s = clock
            self.completed.append(req)
            if maintenance_fn is not None:
                nxt = self._queue[0].arrival_s if self._queue else None
                if nxt is None or nxt > clock:       # device idle: drain
                    gap = None if nxt is None else nxt - clock
                    m = float(maintenance_fn(gap))
                    self.maintenance_s += m
                    clock += m
        return self.completed

    def slo_hit_rate(self) -> float:
        if not self.completed:
            return 1.0
        return sum(r.slo_met for r in self.completed) / len(self.completed)
