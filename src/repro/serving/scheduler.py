"""Request scheduler: FIFO admission with SLO tracking and batch grouping.

EdgeRAG is a single-user edge system, so the paper's serving loop is one
query at a time; the scheduler still models arrival queues and SLO misses so
the benchmarks can report tail latencies under load, and groups decode
requests into fixed-size batches (what serve_step lowers for on the pod).

MULTI-TENANT ADMISSION: when many tenants share the device, a bursty tenant
can queue enough work that everyone else's deadlines blow before service
even starts (the noisy-neighbor problem).  :class:`TokenBucketAdmission`
gives each tenant a refill rate (its fair share of device throughput) and
decides per request at dequeue time: a request whose realized queue wait
already exceeds its SLO is rejected outright (serving it would burn device
time on a guaranteed miss — load-shedding THOSE requests is what protects
everyone else's tail), a request with a token is admitted, and a request
with neither is admitted anyway if the device is idle (the bucket is
work-conserving: fair-share limits only bind under contention) or
rejected/pre-degraded otherwise.  Rejected requests complete immediately
with ``outcome == "rejected"`` and zero service time.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Union


@dataclasses.dataclass(order=True)
class Request:
    arrival_s: float
    rid: int = dataclasses.field(compare=False)
    query: str = dataclasses.field(compare=False, default="")
    query_emb: Optional[object] = dataclasses.field(compare=False,
                                                    default=None)
    query_chars: int = dataclasses.field(compare=False, default=0)
    slo_s: float = dataclasses.field(compare=False, default=1.0)
    tenant: str = dataclasses.field(compare=False, default="")
    # filled on completion
    start_s: float = dataclasses.field(compare=False, default=0.0)
    finish_s: float = dataclasses.field(compare=False, default=0.0)
    degraded: bool = dataclasses.field(compare=False, default=False)
    # ^ served, but the degradation ladder shed work to make the deadline
    pre_degraded: bool = dataclasses.field(compare=False, default=False)
    # ^ admission flagged this request for maximal degradation before
    #   service started (TokenBucketAdmission mode="degrade")
    rejected: bool = dataclasses.field(compare=False, default=False)
    # ^ admission control shed the request: never served
    failed: bool = dataclasses.field(compare=False, default=False)
    # ^ serve_fn raised: the request produced no answer (run() keeps going)
    error: str = dataclasses.field(compare=False, default="")

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def slo_met(self) -> bool:
        return (not self.failed and not self.rejected
                and self.latency_s <= self.slo_s)

    @property
    def outcome(self) -> str:
        """How the request ended: "met" (deadline met cleanly),
        "degraded" (met, but only by shedding work), "missed" (served
        past its deadline), "rejected" (admission control shed it),
        "failed" (serve_fn raised)."""
        if self.rejected:
            return "rejected"
        if self.failed:
            return "failed"
        if self.latency_s > self.slo_s:
            return "missed"
        return "degraded" if self.degraded else "met"


class TokenBucketAdmission:
    """Per-tenant token-bucket admission control (module docstring).

    ``rate_per_s`` is each tenant's refill rate in requests/second — a
    single float (uniform fair share) or a ``{tenant: rate}`` dict;
    ``burst`` is the bucket depth (how far a tenant may burst past its
    rate).  ``mode="reject"`` sheds over-share requests; ``"degrade"``
    admits them flagged ``pre_degraded`` so the serving path applies the
    degradation ladder's floor instead of full-quality work.  Decisions at
    dequeue: a request whose realized queue wait already blew its SLO is
    always shed (mode notwithstanding, serving it is pure waste) and an
    idle device always admits (work-conserving).
    """

    def __init__(self, rate_per_s: Union[float, Dict[str, float]],
                 burst: float = 4.0, *, mode: str = "reject"):
        assert mode in ("reject", "degrade"), mode
        self.rate_per_s = rate_per_s
        self.burst = float(burst)
        self.mode = mode
        self._tokens: Dict[str, float] = {}
        self._last: Dict[str, float] = {}
        self.admitted: Dict[str, int] = {}
        self.shed: Dict[str, int] = {}       # rejected or pre-degraded
        self.blown: Dict[str, int] = {}      # shed for already-blown SLO

    def _rate(self, tenant: str) -> float:
        if isinstance(self.rate_per_s, dict):
            return float(self.rate_per_s.get(tenant, 0.0))
        return float(self.rate_per_s)

    def decide(self, req: Request, clock: float) -> str:
        """"admit" | "reject" | "degrade" for ``req`` dequeued at
        ``clock`` (modeled seconds; ``clock - arrival_s`` is the queue
        wait the request has already paid)."""
        t = req.tenant
        now = req.arrival_s
        tokens = self._tokens.get(t, self.burst)
        last = self._last.get(t, now)
        tokens = min(self.burst,
                     tokens + max(0.0, now - last) * self._rate(t))
        self._last[t] = now
        wait = max(0.0, clock - req.arrival_s)
        if wait >= req.slo_s:
            # the queue alone already blew the deadline — shed
            self.blown[t] = self.blown.get(t, 0) + 1
            decision = "reject" if self.mode == "reject" else "degrade"
        elif tokens >= 1.0:
            tokens -= 1.0
            decision = "admit"
        elif wait <= 0.0:
            decision = "admit"      # idle device: fair share doesn't bind
        else:
            decision = "reject" if self.mode == "reject" else "degrade"
        self._tokens[t] = tokens
        bucket = self.admitted if decision == "admit" else self.shed
        bucket[t] = bucket.get(t, 0) + 1
        return decision

    def stats(self) -> Dict[str, Dict[str, int]]:
        tenants = set(self.admitted) | set(self.shed)
        return {t: {"admitted": self.admitted.get(t, 0),
                    "shed": self.shed.get(t, 0),
                    "blown_slo": self.blown.get(t, 0)}
                for t in sorted(tenants)}


class RequestScheduler:
    def __init__(self, admission: Optional[TokenBucketAdmission] = None):
        self._queue: List[Request] = []
        self.completed: List[Request] = []
        self._next_rid = 0
        self.maintenance_s = 0.0     # total deferred-maintenance seconds
        self.errors: List[str] = []  # serve_fn exceptions (failed requests)
        self.pipeline_trace = None   # PipelineTrace from run_pipelined
        self.pipeline_responses = []  # flat RAGResponses from run_pipelined
        self.admission = admission   # per-tenant SLO-aware admission

    def submit(self, arrival_s: float, query: str = "", query_emb=None,
               query_chars: int = 0, slo_s: float = 1.0,
               tenant: str = "") -> Request:
        req = Request(arrival_s=arrival_s, rid=self._next_rid, query=query,
                      query_emb=query_emb, query_chars=query_chars,
                      slo_s=slo_s, tenant=tenant)
        self._next_rid += 1
        heapq.heappush(self._queue, req)
        return req

    def run(self, serve_fn: Callable[[Request], float],
            maintenance_fn: Optional[Callable[[Optional[float]], float]]
            = None) -> List[Request]:
        """Drain the queue; serve_fn returns the service time in seconds.

        The device is serially occupied (edge device: one query at a time);
        queueing delay accrues when arrivals outpace service.

        Each request carries its OWN deadline (``slo_s``, set at submit);
        ``serve_fn`` may set ``req.degraded`` to flag that the degradation
        ladder shed work for this request — its ``outcome`` then reports
        "met" / "degraded" / "missed" / "failed" per request.  A
        ``serve_fn`` that RAISES marks the request failed (error recorded
        on the request and in ``self.errors``) and the loop keeps serving:
        one bad request can no longer wedge the queue.

        ``maintenance_fn`` (deferred index maintenance, wrapping
        ``MaintenanceScheduler.drain``) models background work that YIELDS
        to foreground requests: it only runs when the device goes idle — no
        request waiting at the current clock — and receives the idle gap
        until the next known arrival (None when the queue is empty) so it
        can size its work to fit (a strict-budget drain).  It returns the
        modeled seconds it occupied the device; work that fits the gap is
        free, overrun delays the next request by the overrun only.  Under
        sustained backlog maintenance keeps deferring — exactly the
        sync-vs-deferred trade-off the online-churn benchmark measures.
        """
        clock = 0.0
        while self._queue:
            req = heapq.heappop(self._queue)
            clock = max(clock, req.arrival_s)
            if self.admission is not None:
                decision = self.admission.decide(req, clock)
                if decision == "reject":
                    # shed without occupying the device: the clock does
                    # not advance, so the backlog behind this request
                    # drains sooner — that is the point
                    req.rejected = True
                    req.start_s = req.finish_s = clock
                    self.completed.append(req)
                    continue
                if decision == "degrade":
                    req.pre_degraded = True
            req.start_s = clock
            try:
                service_s = float(serve_fn(req))
            except Exception as e:     # noqa: BLE001 — isolate the request
                service_s = 0.0
                req.failed = True
                req.error = f"{type(e).__name__}: {e}"
                self.errors.append(req.error)
            clock += service_s
            req.finish_s = clock
            self.completed.append(req)
            if maintenance_fn is not None:
                nxt = self._queue[0].arrival_s if self._queue else None
                if nxt is None or nxt > clock:       # device idle: drain
                    gap = None if nxt is None else nxt - clock
                    m = float(maintenance_fn(gap))
                    self.maintenance_s += m
                    clock += m
        return self.completed

    def run_pipelined(self, pipeline, *, batch_size: int = 8,
                      policy=None) -> List[Request]:
        """Drain the queue through a
        :class:`~repro.serving.pipeline.StagedPipeline` instead of the
        serial ``serve_fn`` loop: requests are grouped into arrival-order
        batches of ``batch_size`` and the pipeline overlaps each batch's
        retrieval with its predecessors' decode on the modeled clock.

        A batch is admitted when its LAST member has arrived (the batch's
        ``arrival_s``); each member's queue wait — admission wait plus any
        stage-queue wait — is charged against its deadline by the
        pipeline, so the degradation ladder sees the time actually left.
        Request ``start_s`` / ``finish_s`` are stamped by the pipeline
        (decode-stage entry / first token out) and the run's
        :class:`~repro.serving.pipeline.PipelineTrace` lands on
        ``self.pipeline_trace``.
        """
        from repro.serving.pipeline import PipelineBatch

        reqs = []
        while self._queue:
            req = heapq.heappop(self._queue)
            if self.admission is not None:
                # batch admission: token-bucket fair share only (stage
                # queue waits are the pipeline's to degrade against)
                decision = self.admission.decide(req, req.arrival_s)
                if decision == "reject":
                    req.rejected = True
                    req.start_s = req.finish_s = req.arrival_s
                    self.completed.append(req)
                    continue
                if decision == "degrade":
                    req.pre_degraded = True
            reqs.append(req)
        batches = []
        any_tenant = any(r.tenant for r in reqs)
        for i in range(0, len(reqs), batch_size):
            group = reqs[i:i + batch_size]
            batches.append(PipelineBatch(
                queries=[r.query for r in group],
                query_embs=[r.query_emb for r in group],
                arrival_s=max(r.arrival_s for r in group),
                slos=[r.slo_s for r in group],
                policy=policy,
                requests=group,
                tenants=[r.tenant for r in group] if any_tenant else None))
        responses, trace = pipeline.run(batches)
        self.pipeline_trace = trace
        self.maintenance_s += (trace.maintenance_in_bubbles_s
                               + trace.final_drain_s)
        self.completed.extend(reqs)
        self.pipeline_responses = [r for batch in responses for r in batch]
        return self.completed

    def slo_hit_rate(self) -> float:
        if not self.completed:
            return 1.0
        return sum(r.slo_met for r in self.completed) / len(self.completed)

    def outcome_counts(self) -> dict:
        """Per-outcome request counts: met / degraded / missed / rejected /
        failed."""
        counts = {"met": 0, "degraded": 0, "missed": 0, "rejected": 0,
                  "failed": 0}
        for r in self.completed:
            counts[r.outcome] += 1
        return counts
