"""Request scheduler: FIFO admission with SLO tracking and batch grouping.

EdgeRAG is a single-user edge system, so the paper's serving loop is one
query at a time; the scheduler still models arrival queues and SLO misses so
the benchmarks can report tail latencies under load, and groups decode
requests into fixed-size batches (what serve_step lowers for on the pod).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional


@dataclasses.dataclass(order=True)
class Request:
    arrival_s: float
    rid: int = dataclasses.field(compare=False)
    query: str = dataclasses.field(compare=False, default="")
    query_emb: Optional[object] = dataclasses.field(compare=False,
                                                    default=None)
    query_chars: int = dataclasses.field(compare=False, default=0)
    slo_s: float = dataclasses.field(compare=False, default=1.0)
    # filled on completion
    start_s: float = dataclasses.field(compare=False, default=0.0)
    finish_s: float = dataclasses.field(compare=False, default=0.0)
    degraded: bool = dataclasses.field(compare=False, default=False)
    # ^ served, but the degradation ladder shed work to make the deadline
    failed: bool = dataclasses.field(compare=False, default=False)
    # ^ serve_fn raised: the request produced no answer (run() keeps going)
    error: str = dataclasses.field(compare=False, default="")

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def slo_met(self) -> bool:
        return not self.failed and self.latency_s <= self.slo_s

    @property
    def outcome(self) -> str:
        """How the request ended: "met" (deadline met cleanly),
        "degraded" (met, but only by shedding work), "missed" (served
        past its deadline), "failed" (serve_fn raised)."""
        if self.failed:
            return "failed"
        if self.latency_s > self.slo_s:
            return "missed"
        return "degraded" if self.degraded else "met"


class RequestScheduler:
    def __init__(self):
        self._queue: List[Request] = []
        self.completed: List[Request] = []
        self._next_rid = 0
        self.maintenance_s = 0.0     # total deferred-maintenance seconds
        self.errors: List[str] = []  # serve_fn exceptions (failed requests)
        self.pipeline_trace = None   # PipelineTrace from run_pipelined
        self.pipeline_responses = []  # flat RAGResponses from run_pipelined

    def submit(self, arrival_s: float, query: str = "", query_emb=None,
               query_chars: int = 0, slo_s: float = 1.0) -> Request:
        req = Request(arrival_s=arrival_s, rid=self._next_rid, query=query,
                      query_emb=query_emb, query_chars=query_chars,
                      slo_s=slo_s)
        self._next_rid += 1
        heapq.heappush(self._queue, req)
        return req

    def run(self, serve_fn: Callable[[Request], float],
            maintenance_fn: Optional[Callable[[Optional[float]], float]]
            = None) -> List[Request]:
        """Drain the queue; serve_fn returns the service time in seconds.

        The device is serially occupied (edge device: one query at a time);
        queueing delay accrues when arrivals outpace service.

        Each request carries its OWN deadline (``slo_s``, set at submit);
        ``serve_fn`` may set ``req.degraded`` to flag that the degradation
        ladder shed work for this request — its ``outcome`` then reports
        "met" / "degraded" / "missed" / "failed" per request.  A
        ``serve_fn`` that RAISES marks the request failed (error recorded
        on the request and in ``self.errors``) and the loop keeps serving:
        one bad request can no longer wedge the queue.

        ``maintenance_fn`` (deferred index maintenance, wrapping
        ``MaintenanceScheduler.drain``) models background work that YIELDS
        to foreground requests: it only runs when the device goes idle — no
        request waiting at the current clock — and receives the idle gap
        until the next known arrival (None when the queue is empty) so it
        can size its work to fit (a strict-budget drain).  It returns the
        modeled seconds it occupied the device; work that fits the gap is
        free, overrun delays the next request by the overrun only.  Under
        sustained backlog maintenance keeps deferring — exactly the
        sync-vs-deferred trade-off the online-churn benchmark measures.
        """
        clock = 0.0
        while self._queue:
            req = heapq.heappop(self._queue)
            clock = max(clock, req.arrival_s)
            req.start_s = clock
            try:
                service_s = float(serve_fn(req))
            except Exception as e:     # noqa: BLE001 — isolate the request
                service_s = 0.0
                req.failed = True
                req.error = f"{type(e).__name__}: {e}"
                self.errors.append(req.error)
            clock += service_s
            req.finish_s = clock
            self.completed.append(req)
            if maintenance_fn is not None:
                nxt = self._queue[0].arrival_s if self._queue else None
                if nxt is None or nxt > clock:       # device idle: drain
                    gap = None if nxt is None else nxt - clock
                    m = float(maintenance_fn(gap))
                    self.maintenance_s += m
                    clock += m
        return self.completed

    def run_pipelined(self, pipeline, *, batch_size: int = 8,
                      policy=None) -> List[Request]:
        """Drain the queue through a
        :class:`~repro.serving.pipeline.StagedPipeline` instead of the
        serial ``serve_fn`` loop: requests are grouped into arrival-order
        batches of ``batch_size`` and the pipeline overlaps each batch's
        retrieval with its predecessors' decode on the modeled clock.

        A batch is admitted when its LAST member has arrived (the batch's
        ``arrival_s``); each member's queue wait — admission wait plus any
        stage-queue wait — is charged against its deadline by the
        pipeline, so the degradation ladder sees the time actually left.
        Request ``start_s`` / ``finish_s`` are stamped by the pipeline
        (decode-stage entry / first token out) and the run's
        :class:`~repro.serving.pipeline.PipelineTrace` lands on
        ``self.pipeline_trace``.
        """
        from repro.serving.pipeline import PipelineBatch

        reqs = []
        while self._queue:
            reqs.append(heapq.heappop(self._queue))
        batches = []
        for i in range(0, len(reqs), batch_size):
            group = reqs[i:i + batch_size]
            batches.append(PipelineBatch(
                queries=[r.query for r in group],
                query_embs=[r.query_emb for r in group],
                arrival_s=max(r.arrival_s for r in group),
                slos=[r.slo_s for r in group],
                policy=policy,
                requests=group))
        responses, trace = pipeline.run(batches)
        self.pipeline_trace = trace
        self.maintenance_s += (trace.maintenance_in_bubbles_s
                               + trace.final_drain_s)
        self.completed.extend(reqs)
        self.pipeline_responses = [r for batch in responses for r in batch]
        return self.completed

    def slo_hit_rate(self) -> float:
        if not self.completed:
            return 1.0
        return sum(r.slo_met for r in self.completed) / len(self.completed)

    def outcome_counts(self) -> dict:
        """Per-outcome request counts: met / degraded / missed / failed."""
        counts = {"met": 0, "degraded": 0, "missed": 0, "failed": 0}
        for r in self.completed:
            counts[r.outcome] += 1
        return counts
