"""Staged serving pipeline: hide retrieval + maintenance under decode.

The sequential ``RAGEngine.answer_batch`` runs retrieve-then-decode strictly
in order, so the accelerator sits idle during storage I/O and the storage
path sits idle during decode.  On one resource-constrained device that
serialization is where online-RAG throughput goes to die (RAGDoll, arXiv
2504.15302; MobileRAG, arXiv 2507.01079): retrieval and token generation
use DIFFERENT resources and can overlap almost entirely.

:class:`StagedPipeline` runs the engine's four stages as independent
resources on the shared modeled clock (``EdgeCostModel`` seconds):

    S1 probe/plan      fused centroid top-k + ClusterResolver plan
    S2 fetch/regen     raw storage loads + coalesced embed regeneration
                       (fault retries / stalls / degradation rungs 2-3)
    S3 pack + score    slab pack → multi-query fused top-k + prompts
    S4 prefill/decode  ContinuousBatcher ticks (or per-query generator)

While batch N occupies S4, batches N+1 / N+2 advance through S1-S3.  The
executor is a discrete-event loop: each stage resource has a ``free_at``
clock, each in-flight batch a ready time; the earliest-firing (stage,
batch) pair executes its REAL work at its modeled fire time, so anything
that happens "during a bubble" (maintenance, another batch's regen) is
physically ordered exactly as the modeled clock says.  Ties fire the later
stage first, draining downstream work ahead of admitting more upstream.

MAINTENANCE IN BUBBLES: when S2 / S3 sat idle before firing, the gap is a
bubble — ``MaintenanceScheduler.drain(gap, strict=True)`` fills it with
deferred split / merge / restore work instead of the sequential path's
post-decode drain.  Gaps before the first S4 fire are ramp-up, not
bubbles — there is no decode to hide under yet, so drains wait until the
decode stage is occupied.  The pipeline OWNS draining (construct the
engine with
``maintenance_owner="external"``); a final drain after the last decode
finishes whatever the bubbles didn't fit.

STALENESS: bubble maintenance (and any concurrent mutation) can move a
planned cluster's generation while its batch sits between stages.  A
mutation in the S1→S2 window is already safe — ``ClusterResolver.execute``
regenerates stamped-stale clusters over their current membership (PR 3's
invariant).  A CONTENT move (insert / update / remove / split / merge) in
the S2→S3 window is caught at S3 fire time by
``ClusterResolver.stale_cids``: the batch RE-ENTERS S1 (fresh plan + fetch,
counted in ``PipelineTrace.replans``) instead of packing payloads that no
longer row-align.  Storage-tier flips (a bubble-drain restore / drop) bump
``generation`` but not ``content_generation`` and do NOT trigger a replan —
payloads already fetched stay row-aligned and value-identical, and treating
tier flips as staleness would re-plan every in-flight batch each time
maintenance ran.  While a replanned batch is in flight, bubble-filling is
suppressed so it cannot be re-staled — replans converge.

DEADLINES THROUGH QUEUES: a batch's effective TTFT deadline is set when S1
fires, as ``slo - queue_wait`` — the degradation ladder budgets against the
time the request actually has LEFT, not the time it had at submission.
Additional wait in the S2 queue shrinks the plan's remaining retrieval
budgets the same way (``RAGEngine.stage_fetch(extra_wait_s=...)``).

Results are bit-identical to the sequential path: the same stage functions
run with the same inputs, only WHEN they run moves.  Payloads roundtrip
storage exactly, regeneration is deterministic, and the generation stamps
force regen over current membership whenever timing differences change
cache / storage state — so ids and scores cannot drift, only latency
attribution can.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import DegradationPolicy
from repro.core.maintenance import OP_CHECKPOINT
from repro.serving.engine import BatchJob, RAGEngine, RAGResponse

STAGES = ("s1", "s2", "s3", "s4")
# stages whose idle gaps maintenance may fill: S2 (storage/embed path) and
# S3 (pack/score path) — S1 is tiny and S4 is the resource being hidden
FILL_STAGES = ("s2", "s3")
# floor for a queue-wait-adjusted deadline: an already-blown SLO degrades
# maximally (min_nprobe, all regens shed) instead of going negative
DEADLINE_FLOOR_S = 1e-6


@dataclasses.dataclass
class PipelineBatch:
    """One admission unit: a batch of queries entering the pipeline."""
    queries: List[str]
    query_embs: np.ndarray
    arrival_s: float = 0.0
    slos: Optional[List[Optional[float]]] = None   # per-query TTFT SLOs
    policy: Optional[DegradationPolicy] = None
    requests: Optional[List[object]] = None        # scheduler Requests
    tenants: Optional[List[str]] = None            # per-query tenant ids
    #                                      (engine fronting a TenantRouter)


@dataclasses.dataclass
class StageTrace:
    """Occupancy record of one stage resource across a pipeline run."""
    name: str
    busy_s: float = 0.0            # modeled seconds executing batch work
    n_fired: int = 0               # batch firings (incl. replanned passes)
    maintenance_s: float = 0.0     # bubble seconds filled with drain work
    maintenance_ops: int = 0       # maintenance ops executed in bubbles
    checkpoints: int = 0           # durability OP_CHECKPOINT ops among them
    max_queue_depth: int = 0       # most batches ever waiting on this stage
    intervals: List[Tuple[float, float]] = \
        dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict[str, float]:
        return {"busy_s": self.busy_s, "n_fired": self.n_fired,
                "maintenance_s": self.maintenance_s,
                "maintenance_ops": self.maintenance_ops,
                "checkpoints": self.checkpoints,
                "max_queue_depth": self.max_queue_depth}


def _union(intervals: List[Tuple[float, float]]
           ) -> List[Tuple[float, float]]:
    """Merge possibly-overlapping (start, end) intervals."""
    out: List[Tuple[float, float]] = []
    for s, e in sorted(i for i in intervals if i[1] > i[0]):
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _intersect_s(a: List[Tuple[float, float]],
                 b: List[Tuple[float, float]]) -> float:
    """Total overlap between two DISJOINT-SORTED interval lists."""
    total, i, j = 0.0, 0, 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return total


@dataclasses.dataclass
class PipelineTrace:
    """What the staged executor did, on the modeled clock — the proof
    object for "retrieval is hidden under decode" (per-stage busy
    intervals, queue depths, bubbles filled, replans)."""
    stages: Dict[str, StageTrace]
    n_batches: int = 0
    n_queries: int = 0
    makespan_s: float = 0.0        # first arrival → last S4 completion
    replans: int = 0               # stale-plan S1 re-entries
    final_drain_s: float = 0.0     # post-run drain of leftover maintenance

    @property
    def retrieval_busy_s(self) -> float:
        """Union time ANY retrieval stage (S1-S3) was executing."""
        ivs = [iv for s in ("s1", "s2", "s3")
               for iv in self.stages[s].intervals]
        return sum(e - s for s, e in _union(ivs))

    @property
    def decode_busy_s(self) -> float:
        return sum(e - s for s, e in _union(self.stages["s4"].intervals))

    @property
    def hidden_retrieval_s(self) -> float:
        """Retrieval-busy time that ran UNDER decode (interval overlap of
        the S1-S3 union with the S4 union)."""
        retr = _union([iv for s in ("s1", "s2", "s3")
                       for iv in self.stages[s].intervals])
        return _intersect_s(retr, _union(self.stages["s4"].intervals))

    @property
    def hidden_retrieval_fraction(self) -> float:
        """Fraction of retrieval time hidden under decode (1.0 = every
        retrieval second overlapped a decode second)."""
        busy = self.retrieval_busy_s
        return 1.0 if busy <= 0.0 else self.hidden_retrieval_s / busy

    @property
    def bubble_fraction(self) -> float:
        """Fraction of retrieval time EXPOSED (not under decode) — the
        complement of ``hidden_retrieval_fraction``."""
        return 1.0 - self.hidden_retrieval_fraction

    @property
    def maintenance_in_bubbles_s(self) -> float:
        return sum(st.maintenance_s for st in self.stages.values())

    def as_dict(self) -> Dict[str, object]:
        return {
            "n_batches": self.n_batches,
            "n_queries": self.n_queries,
            "makespan_s": self.makespan_s,
            "replans": self.replans,
            "final_drain_s": self.final_drain_s,
            "retrieval_busy_s": self.retrieval_busy_s,
            "decode_busy_s": self.decode_busy_s,
            "hidden_retrieval_s": self.hidden_retrieval_s,
            "hidden_retrieval_fraction": self.hidden_retrieval_fraction,
            "bubble_fraction": self.bubble_fraction,
            "maintenance_in_bubbles_s": self.maintenance_in_bubbles_s,
            "stages": {s: st.as_dict() for s, st in self.stages.items()},
        }


@dataclasses.dataclass
class _InFlight:
    """Executor-side state of one batch moving through the stages."""
    batch: PipelineBatch
    job: BatchJob
    stage_idx: int = 0             # next stage to fire (index into STAGES)
    ready_at: float = 0.0          # modeled time the next stage may start
    s4_start: float = 0.0
    finish_at: float = 0.0
    no_fill: bool = False          # replanned: suppress bubble maintenance


class StagedPipeline:
    """Discrete-event executor for the engine's staged serving path.

    ``engine`` should be constructed with ``maintenance_owner="external"``
    when deferred maintenance is in play — the pipeline drains bubbles and
    runs the final drain itself (it never calls ``answer_batch``, so an
    engine-owned post-decode drain simply never happens here, but other
    callers of the same engine would double-drain).

    ``fill_bubbles=False`` disables bubble maintenance (the final drain
    still runs); ``max_replans`` caps stale-plan S1 re-entries per batch
    before the batch proceeds on PR 3's regen-over-current-membership
    fallback (which is correct but may do redundant fetch work).
    """

    def __init__(self, engine: RAGEngine, get_chunks, *, batcher=None,
                 fill_bubbles: bool = True, max_replans: int = 2,
                 final_drain: bool = True):
        self.engine = engine
        self.get_chunks = get_chunks
        self.batcher = batcher
        self.fill_bubbles = fill_bubbles
        self.max_replans = max_replans
        self.final_drain = final_drain

    # ------------------------------------------------------------------
    def run(self, batches: Sequence[PipelineBatch]
            ) -> Tuple[List[List[RAGResponse]], PipelineTrace]:
        """Serve ``batches`` through the staged pipeline.  Returns one
        response list per input batch (same order) plus the trace."""
        eng = self.engine
        trace = PipelineTrace(
            stages={s: StageTrace(name=s) for s in STAGES},
            n_batches=len(batches),
            n_queries=sum(len(b.queries) for b in batches))
        if not batches:
            return [], trace
        flights = [
            _InFlight(batch=b,
                      job=eng.make_job(b.queries, b.query_embs,
                                       self.get_chunks,
                                       deadlines=b.slos, policy=b.policy,
                                       tenants=b.tenants),
                      ready_at=b.arrival_s)
            for b in batches]
        stage_free = {s: 0.0 for s in STAGES}
        sched = getattr(eng.index, "maintenance", None)
        responses: List[Optional[List[RAGResponse]]] = [None] * len(batches)
        n_done = 0
        decode_started = False
        t_start = min(b.arrival_s for b in batches)

        while n_done < len(flights):
            # earliest-firing (batch, stage) pair; ties fire the LATER
            # stage first so downstream work drains ahead of admission
            best = None
            for bi, fl in enumerate(flights):
                if fl.stage_idx >= len(STAGES):
                    continue
                stage = STAGES[fl.stage_idx]
                fire = max(fl.ready_at, stage_free[stage])
                key = (fire, -fl.stage_idx, fl.ready_at, bi)
                if best is None or key < best[0]:
                    best = (key, bi, fl, stage, fire)
            _, bi, fl, stage, fire = best
            st = trace.stages[stage]
            # queue depth: batches ready for this stage at fire time
            depth = sum(1 for o in flights
                        if o.stage_idx < len(STAGES)
                        and STAGES[o.stage_idx] == stage
                        and o.ready_at <= fire)
            st.max_queue_depth = max(st.max_queue_depth, depth)
            # bubble-fill: the stage sat idle from free_at to fire — spend
            # the gap on deferred maintenance (strict budget: never
            # overruns into the batch's start).  A gap only counts as a
            # bubble once decode has started: before the first S4 fire
            # there is nothing to hide under, and a drain during ramp-up
            # lands on the critical path (and can stale the very first
            # plan, forcing a replan nothing amortizes).  Also suppressed
            # while any replanned batch is in flight, so replans converge.
            gap = fire - stage_free[stage]
            if (self.fill_bubbles and stage in FILL_STAGES and gap > 0.0
                    and decode_started
                    and sched is not None and len(sched)
                    and not any(o.no_fill for o in flights)):
                rep = sched.drain(gap, strict=True)
                st.maintenance_s += rep.edge_s
                st.maintenance_ops += rep.n_executed
                # durability checkpoints ride the same bubbles; they bump
                # no generation stamp, so in-flight plans never go stale
                # behind one (the S3 replan gate compares
                # content_generation, which a snapshot leaves untouched)
                st.checkpoints += sum(
                    1 for kind, _ in rep.executed if kind == OP_CHECKPOINT)

            if stage == "s1":
                wait = fire - fl.batch.arrival_s
                fl.job.queue_wait_s = wait
                if fl.batch.slos is not None:
                    fl.job.deadlines = [
                        None if slo is None
                        else max(DEADLINE_FLOOR_S, slo - wait)
                        for slo in fl.batch.slos]
                eng.stage_plan(fl.job)
            elif stage == "s2":
                eng.stage_fetch(fl.job,
                                extra_wait_s=max(0.0, fire - fl.ready_at))
            elif stage == "s3":
                stale = eng.index.resolver.stale_cids(fl.job.state.plan)
                if stale and fl.job.replans < self.max_replans:
                    # plan went stale in the S2→S3 window: re-enter S1
                    # (fresh plan + fetch over current membership) rather
                    # than packing payloads that no longer row-align
                    fl.job.replans += 1
                    trace.replans += 1
                    fl.no_fill = True
                    fl.stage_idx = 0
                    fl.ready_at = fire
                    continue
                eng.stage_score(fl.job)
                fl.no_fill = False
            else:  # s4
                fl.s4_start = fire
                decode_started = True
                eng.stage_decode(fl.job, batcher=self.batcher)

            svc = fl.job.stage_edge_s[stage]
            stage_free[stage] = fire + svc
            fl.ready_at = fire + svc
            fl.stage_idx += 1
            st.busy_s += svc
            st.n_fired += 1
            st.intervals.append((fire, fire + svc))
            if fl.stage_idx >= len(STAGES):
                fl.finish_at = fire + svc
                responses[bi] = eng.finalize(fl.job)
                n_done += 1

        trace.makespan_s = max(fl.finish_at for fl in flights) - t_start
        if self.final_drain and sched is not None and len(sched):
            trace.final_drain_s = sched.drain(None).edge_s
        self._fill_request_times(flights)
        return list(responses), trace

    # ------------------------------------------------------------------
    @staticmethod
    def _fill_request_times(flights: List[_InFlight]):
        """Stamp scheduler Requests (when attached): start = decode-stage
        entry, finish = first token out — S4 start + this query's place in
        the batch's cumulative prefill (slots prefill in admission
        order)."""
        for fl in flights:
            if fl.batch.requests is None:
                continue
            prefill_cum = 0.0
            for qi, req in enumerate(fl.batch.requests):
                prefill_cum += fl.job.prefill_edge[qi]
                req.start_s = fl.s4_start
                req.finish_s = fl.s4_start + prefill_cum
                req.degraded = bool(
                    fl.job.lats[qi].degraded_clusters
                    or fl.job.lats[qi].stale_served)
