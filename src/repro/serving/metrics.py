"""Lightweight Prometheus-style serving metrics (no dependencies).

The multi-tenant service needs per-tenant observability — request
outcomes, TTFT tails, cache behaviour, admission sheds, stage occupancy —
in a form an operator's scraper understands.  This module is a minimal
text-exposition implementation: :class:`Counter` / :class:`Gauge` /
:class:`Histogram` with label sets, a :class:`MetricsRegistry` that
renders the standard ``# HELP`` / ``# TYPE`` / sample-line format, and
collectors that populate a registry from the serving objects this repo
already produces (:class:`~repro.serving.scheduler.RequestScheduler`,
:class:`~repro.serving.pipeline.PipelineTrace`,
:class:`~repro.core.tenant.TenantRouter`).

Metric names follow Prometheus conventions (``_total`` counters, base-unit
``_seconds``); histograms expose cumulative ``_bucket`` samples with an
``le`` label plus ``_sum`` / ``_count``.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

LabelKV = Tuple[Tuple[str, str], ...]

# TTFT-oriented default buckets: 1 ms .. 60 s, roughly log-spaced
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

_ESCAPES = str.maketrans({"\\": r"\\", "\n": r"\n", '"': r'\"'})


def _labels_kv(labels: Optional[Dict[str, str]]) -> LabelKV:
    return tuple(sorted((k, str(v)) for k, v in (labels or {}).items()))


def _fmt_labels(kv: LabelKV) -> str:
    if not kv:
        return ""
    inner = ",".join(f'{k}="{v.translate(_ESCAPES)}"' for k, v in kv)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help_text: str):
        self.name = name
        self.help = help_text

    def samples(self) -> Iterable[Tuple[str, LabelKV, float]]:
        raise NotImplementedError

    def render(self) -> str:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for suffix, kv, value in self.samples():
            lines.append(
                f"{self.name}{suffix}{_fmt_labels(kv)} {_fmt_value(value)}")
        return "\n".join(lines)


class Counter(_Metric):
    """Monotonic counter with label sets (``inc`` only)."""
    kind = "counter"

    def __init__(self, name: str, help_text: str):
        super().__init__(name, help_text)
        self._values: Dict[LabelKV, float] = {}

    def inc(self, amount: float = 1.0,
            labels: Optional[Dict[str, str]] = None):
        assert amount >= 0, f"counter {self.name} cannot decrease"
        kv = _labels_kv(labels)
        self._values[kv] = self._values.get(kv, 0.0) + amount

    def value(self, labels: Optional[Dict[str, str]] = None) -> float:
        return self._values.get(_labels_kv(labels), 0.0)

    def samples(self):
        for kv in sorted(self._values):
            yield "", kv, self._values[kv]


class Gauge(_Metric):
    """Point-in-time value with label sets (``set`` / ``inc``)."""
    kind = "gauge"

    def __init__(self, name: str, help_text: str):
        super().__init__(name, help_text)
        self._values: Dict[LabelKV, float] = {}

    def set(self, value: float, labels: Optional[Dict[str, str]] = None):
        self._values[_labels_kv(labels)] = float(value)

    def inc(self, amount: float = 1.0,
            labels: Optional[Dict[str, str]] = None):
        kv = _labels_kv(labels)
        self._values[kv] = self._values.get(kv, 0.0) + amount

    def value(self, labels: Optional[Dict[str, str]] = None) -> float:
        return self._values.get(_labels_kv(labels), 0.0)

    def samples(self):
        for kv in sorted(self._values):
            yield "", kv, self._values[kv]


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus exposition semantics)."""
    kind = "histogram"

    def __init__(self, name: str, help_text: str,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help_text)
        self.buckets = tuple(sorted(buckets))
        assert self.buckets, "histogram needs at least one bucket"
        self._counts: Dict[LabelKV, List[int]] = {}
        self._sum: Dict[LabelKV, float] = {}
        self._count: Dict[LabelKV, int] = {}

    def observe(self, value: float,
                labels: Optional[Dict[str, str]] = None):
        kv = _labels_kv(labels)
        counts = self._counts.setdefault(kv, [0] * len(self.buckets))
        for i, le in enumerate(self.buckets):
            if value <= le:
                counts[i] += 1
        self._sum[kv] = self._sum.get(kv, 0.0) + float(value)
        self._count[kv] = self._count.get(kv, 0) + 1

    def count(self, labels: Optional[Dict[str, str]] = None) -> int:
        return self._count.get(_labels_kv(labels), 0)

    def sum(self, labels: Optional[Dict[str, str]] = None) -> float:
        return self._sum.get(_labels_kv(labels), 0.0)

    def quantile(self, q: float,
                 labels: Optional[Dict[str, str]] = None) -> float:
        """Bucket-interpolated quantile (what a PromQL
        ``histogram_quantile`` would report for this exposition)."""
        kv = _labels_kv(labels)
        counts = self._counts.get(kv)
        total = self._count.get(kv, 0)
        if not counts or not total:
            return 0.0
        target = q * total
        prev_le, prev_c = 0.0, 0
        for le, c in zip(self.buckets, counts):
            if c >= target:
                if c == prev_c:
                    return le
                frac = (target - prev_c) / (c - prev_c)
                return prev_le + frac * (le - prev_le)
            prev_le, prev_c = le, c
        return self.buckets[-1]

    def samples(self):
        for kv in sorted(self._counts):
            counts = self._counts[kv]
            for le, c in zip(self.buckets, counts):
                yield "_bucket", kv + (("le", _fmt_value(le)),), float(c)
            yield ("_bucket", kv + (("le", "+Inf"),),
                   float(self._count[kv]))
            yield "_sum", kv, self._sum[kv]
            yield "_count", kv, float(self._count[kv])


class MetricsRegistry:
    """Holds metrics by name; ``render()`` is the scrape payload."""

    def __init__(self):
        self._metrics: "Dict[str, _Metric]" = {}

    def _register(self, metric: _Metric) -> _Metric:
        existing = self._metrics.get(metric.name)
        if existing is not None:
            assert type(existing) is type(metric), \
                f"metric {metric.name} re-registered with a different type"
            return existing
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help_text: str = "") -> Counter:
        return self._register(Counter(name, help_text))

    def gauge(self, name: str, help_text: str = "") -> Gauge:
        return self._register(Gauge(name, help_text))

    def histogram(self, name: str, help_text: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram(name, help_text, buckets))

    def get(self, name: str) -> _Metric:
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def render(self) -> str:
        """Prometheus text exposition format, trailing newline included."""
        blocks = [self._metrics[n].render()
                  for n in sorted(self._metrics)]
        return "\n".join(blocks) + ("\n" if blocks else "")


# ----------------------------------------------------------------------
# collectors: serving objects -> registry
# ----------------------------------------------------------------------
def collect_scheduler(reg: MetricsRegistry, sched) -> MetricsRegistry:
    """Per-tenant request outcomes, TTFT histograms, queue waits, and
    admission counters from a :class:`RequestScheduler` run."""
    outcomes = reg.counter("edgerag_requests_total",
                           "Completed requests by tenant and outcome")
    ttft = reg.histogram("edgerag_request_ttft_seconds",
                         "Arrival-to-first-token latency")
    wait = reg.histogram("edgerag_request_queue_wait_seconds",
                         "Arrival-to-service-start queue wait")
    for r in sched.completed:
        labels = {"tenant": r.tenant or "default"}
        outcomes.inc(labels={**labels, "outcome": r.outcome})
        if not r.rejected and not r.failed:
            ttft.observe(r.latency_s, labels=labels)
            wait.observe(max(0.0, r.start_s - r.arrival_s), labels=labels)
    reg.gauge("edgerag_maintenance_drained_seconds",
              "Deferred-maintenance edge seconds drained by the scheduler"
              ).set(sched.maintenance_s)
    if getattr(sched, "admission", None) is not None:
        adm = reg.counter("edgerag_admission_decisions_total",
                          "Admission decisions by tenant and decision")
        for t, st in sched.admission.stats().items():
            labels = {"tenant": t or "default"}
            adm.inc(st["admitted"],
                    labels={**labels, "decision": "admitted"})
            adm.inc(st["shed"], labels={**labels, "decision": "shed"})
            adm.inc(st["blown_slo"],
                    labels={**labels, "decision": "blown_slo"})
    return reg


def collect_pipeline_trace(reg: MetricsRegistry, trace) -> MetricsRegistry:
    """Stage occupancy / overlap figures from a
    :class:`~repro.serving.pipeline.PipelineTrace`."""
    busy = reg.gauge("edgerag_stage_busy_seconds",
                     "Modeled busy seconds per pipeline stage")
    fired = reg.gauge("edgerag_stage_fired_total",
                      "Batch firings per pipeline stage")
    depth = reg.gauge("edgerag_stage_max_queue_depth",
                      "Deepest queue observed per pipeline stage")
    maint = reg.gauge("edgerag_stage_maintenance_seconds",
                      "Bubble seconds filled with maintenance per stage")
    for name, st in trace.stages.items():
        labels = {"stage": name}
        busy.set(st.busy_s, labels=labels)
        fired.set(st.n_fired, labels=labels)
        depth.set(st.max_queue_depth, labels=labels)
        maint.set(st.maintenance_s, labels=labels)
    reg.gauge("edgerag_pipeline_makespan_seconds",
              "First arrival to last decode completion").set(trace.makespan_s)
    reg.gauge("edgerag_pipeline_hidden_retrieval_fraction",
              "Fraction of retrieval time hidden under decode"
              ).set(trace.hidden_retrieval_fraction)
    reg.gauge("edgerag_pipeline_replans_total",
              "Stale-plan S1 re-entries").set(trace.replans)
    return reg


def collect_durability(reg: MetricsRegistry, durability,
                       labels: Optional[Dict[str, str]] = None
                       ) -> MetricsRegistry:
    """Durability-subsystem state from one
    :class:`~repro.core.durability.Durability` handle: WAL record/byte
    counters, snapshot + compaction counters, and the last recovery's
    wall seconds (0 until a recovery ran)."""
    labels = labels or {}
    st = durability.stats()
    reg.counter("edgerag_wal_records_total",
                "WAL records appended").inc(st["wal_records_total"],
                                            labels=labels)
    reg.gauge("edgerag_wal_bytes",
              "Current WAL file bytes (post-compaction)"
              ).set(st["wal_bytes"], labels=labels)
    reg.counter("edgerag_snapshots_total",
                "Index snapshots taken").inc(st["snapshots_total"],
                                             labels=labels)
    reg.counter("edgerag_wal_compactions_total",
                "WAL compactions after snapshots"
                ).inc(st["wal_compactions_total"], labels=labels)
    reg.gauge("edgerag_wal_fsync_edge_seconds_total",
              "Modeled edge seconds charged to WAL fsyncs + snapshots"
              ).set(st["fsync_edge_s_total"], labels=labels)
    reg.gauge("edgerag_recovery_seconds",
              "Wall seconds of the last recovery (0 = none ran)"
              ).set(st["last_recovery_s"] or 0.0, labels=labels)
    return reg


def collect_router(reg: MetricsRegistry, router) -> MetricsRegistry:
    """Shared-substrate state from a :class:`TenantRouter`: per-tenant
    cache hits/misses/bytes, storage bytes, maintenance backlog."""
    hits = reg.counter("edgerag_cache_hits_total",
                       "Shared-cache hits by tenant")
    misses = reg.counter("edgerag_cache_misses_total",
                         "Shared-cache misses by tenant")
    evics = reg.counter("edgerag_cache_evictions_total",
                        "Shared-cache evictions by tenant")
    cbytes = reg.gauge("edgerag_cache_bytes",
                       "Resident shared-cache bytes by tenant")
    sbytes = reg.gauge("edgerag_storage_bytes",
                       "Stored bytes by tenant")
    pend = reg.gauge("edgerag_maintenance_pending",
                     "Deferred-maintenance ops queued by tenant")
    medge = reg.gauge("edgerag_maintenance_edge_seconds_total",
                      "Fair-share maintenance edge seconds by tenant")
    for t, ix in router.tenants.items():
        labels = {"tenant": t}
        st = router.cache.per_tenant.get(t)
        if st is not None:
            hits.inc(st["hits"], labels=labels)
            misses.inc(st["misses"], labels=labels)
            evics.inc(st["evictions"], labels=labels)
            cbytes.set(st["bytes"], labels=labels)
        sbytes.set(router.storage.tenant_bytes(t), labels=labels)
        pend.set(len(ix.maintenance), labels=labels)
        medge.set(router.maintenance.per_tenant_edge_s.get(t, 0.0),
                  labels=labels)
        if ix.durability is not None:
            collect_durability(reg, ix.durability, labels=labels)
    reg.gauge("edgerag_cache_capacity_bytes",
              "Shared cache byte budget").set(router.cache.capacity_bytes)
    reg.gauge("edgerag_memory_bytes",
              "Device-resident index bytes (centroids + shared cache)"
              ).set(router.memory_bytes())
    return reg
