"""Paper-scale edge simulator.

The algorithms in ``repro.core`` always run for real; this module answers
"what would Fig. 3 / Fig. 13 look like at the PAPER's dataset sizes on the
PAPER's hardware" by replaying the cost model at Table 2 scale without
allocating 18.5 GB of embeddings.

It simulates the five Table 4 configurations over a query trace:
cluster-size distributions (log-normal tail) and Zipf access skew are drawn
to match the synthetic generator, scaled to the full record counts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.cache_policy import (CostAwareLFUCache,
                                     MinLatencyThresholdController)
from repro.core.costs import BYTES_PER_EMBEDDING_F32, EdgeCostModel
from repro.data.synthetic import BEIR_SPECS


@dataclasses.dataclass
class SimResult:
    config: str
    dataset: str
    mean_retrieval_s: float
    p50_s: float
    p95_s: float
    p99_s: float
    mean_ttft_s: float
    resident_bytes: float
    cache_hit_rate: float = 0.0
    slo_hit_rate: float = 1.0


class EdgeSimulator:
    """Replays a query trace through each index configuration's cost model."""

    def __init__(self, dataset: str, *, nlist: Optional[int] = None,
                 nprobe: int = 8, n_queries: int = 500, seed: int = 0,
                 cost: Optional[EdgeCostModel] = None,
                 mean_chunk_chars: int = 300,
                 prompt_tokens: int = 1200,
                 model_bytes: float = 5.4e9,       # Sheared-LLaMA-2.7B bf16
                 model_evict_frac: float = 0.05):
        spec = BEIR_SPECS[dataset]
        self.spec = spec
        self.cost = cost or EdgeCostModel()
        self.nprobe = nprobe
        self.prompt_tokens = prompt_tokens
        self.model_bytes = model_bytes
        self.model_evict_frac = model_evict_frac
        # cluster granularity calibrated to Fig. 5: median generation cost a
        # few hundred ms => ~30 chunks (~10 kchars) per cluster
        if nlist is None:
            nlist = max(256, spec.n_records // 32)
        rng = np.random.default_rng(seed)
        # cluster sizes (records): log-normal tail, matched to Fig. 5
        raw = rng.lognormal(0.0, 1.0, nlist)
        self.cluster_records = np.maximum(
            1, raw / raw.sum() * spec.n_records).astype(np.int64)
        self.cluster_chars = self.cluster_records * mean_chunk_chars
        self.cluster_bytes = self.cluster_records * BYTES_PER_EMBEDDING_F32
        self.dim = 768
        # query trace: Zipf reuse skew (Table 2) over a random cluster
        # permutation — access frequency is topical, not size-correlated
        zipf_a = {"scidocs": 1.5, "fiqa": 2.2, "quora": 1.6, "nq": 1.25,
                  "hotpotqa": 1.35, "fever": 1.8}[dataset]
        rank = rng.permutation(nlist)
        draws = rng.zipf(zipf_a, size=(n_queries, nprobe))
        self.trace = rank[np.minimum(draws - 1, nlist - 1)]
        self.query_chars = rng.integers(40, 160, size=n_queries)

    # ------------------------------------------------------------------
    def _ttft(self, retrieval_s: float, resident_bytes: float = 0.0) -> float:
        prefill = self.cost.prefill_latency(self.prompt_tokens)
        if resident_bytes > self.cost.index_memory_budget:
            # the index working set evicted part of the generation model
            # (paper §6.3.4: "eviction of the generation model from memory")
            prefill += (self.model_evict_frac * self.model_bytes
                        / self.cost.storage_seq_bw_bytes_per_sec)
        return retrieval_s + prefill

    def run(self, config: str, *, cache_frac: float = 0.07,
            slo_s: Optional[float] = None) -> SimResult:
        """config ∈ {flat, ivf, ivf_gen, ivf_gen_load, edgerag} (Table 4)."""
        c = self.cost
        spec = self.spec
        slo_s = slo_s if slo_s is not None else spec.slo_s
        nlist = len(self.cluster_records)
        centroid_bytes = nlist * self.dim * 4
        total_emb_bytes = float(self.cluster_bytes.sum())
        lat_centroid = (c.mem_load_latency(centroid_bytes)
                        + c.search_latency(nlist, self.dim))
        lats: List[float] = []
        cache = None
        thr = None
        stored = np.zeros(nlist, bool)
        if config in ("ivf_gen_load", "edgerag"):
            gen_lat = c.embed_latency(0) + self.cluster_chars / c.embed_chars_per_sec
            stored = gen_lat > slo_s                 # Alg. 1 at index time
        if config == "edgerag":
            cache = CostAwareLFUCache(int(cache_frac * c.device_memory_bytes))
            thr = MinLatencyThresholdController()
        resident = {
            "flat": total_emb_bytes,
            "ivf": centroid_bytes + total_emb_bytes,
            "ivf_gen": centroid_bytes,
            "ivf_gen_load": centroid_bytes,
            "edgerag": centroid_bytes,               # + cache, counted below
        }[config]

        # OS page cache over cluster pages for over-memory in-memory configs:
        # hot (Zipf head) clusters stay resident; cold accesses page in as
        # scattered reads.  Budget = what's left after model + centroids.
        from collections import OrderedDict
        page_cache: "OrderedDict[int, float]" = OrderedDict()
        page_budget = max(0.0, c.index_memory_budget - centroid_bytes)
        page_used = 0.0

        def paged_load(cl: int, nb: float) -> float:
            nonlocal page_used
            if resident <= c.index_memory_budget:
                return c.mem_load_latency(nb)
            if cl in page_cache:
                page_cache.move_to_end(cl)
                return nb / c.dram_bw_bytes_per_sec
            while page_used + nb > page_budget and page_cache:
                _, old_nb = page_cache.popitem(last=False)
                page_used -= old_nb
            if nb <= page_budget:
                page_cache[cl] = nb
                page_used += nb
            return c.storage_seek_s + nb / c.storage_rand_bw_bytes_per_sec

        for qi, probed in enumerate(self.trace):
            q_embed = c.embed_latency(int(self.query_chars[qi]))
            if config == "flat":
                lat = q_embed + c.mem_load_latency(
                    total_emb_bytes, resident_bytes=resident) \
                    + c.search_latency(int(spec.n_records), self.dim)
                lats.append(self._ttft(lat, resident))
                continue
            lat = q_embed + lat_centroid
            scanned = 0
            missed = False
            for cl in probed:
                nb = float(self.cluster_bytes[cl])
                scanned += int(self.cluster_records[cl])
                if config == "ivf":
                    lat += paged_load(int(cl), nb)
                    continue
                if stored[cl]:
                    lat += c.storage_load_latency(nb)
                    continue
                gen_s = c.embed_latency(int(self.cluster_chars[cl]))
                if cache is not None:
                    hit = cache.access(int(cl)) is not None
                    if hit:
                        lat += c.mem_load_latency(nb)
                        continue
                    missed = True
                    lat += gen_s
                    # cache stores a byte-sized dummy (policy is what matters)
                    cache.insert(int(cl), np.empty(int(nb), np.uint8),
                                 gen_s, thr.threshold)
                else:
                    lat += gen_s
            lat += c.search_latency(scanned, self.dim)
            if thr is not None:
                new_thr = thr.observe(missed, lat)
                if missed:
                    cache.drop_below_threshold(new_thr)
            lats.append(self._ttft(lat, resident))

        lats_np = np.asarray(lats)
        retr = lats_np - c.prefill_latency(self.prompt_tokens)
        retr = np.maximum(retr, 0.0)
        if config == "edgerag" and cache is not None:
            resident += cache.total_bytes()
        return SimResult(
            config=config, dataset=spec.name,
            mean_retrieval_s=float(retr.mean()),
            p50_s=float(np.percentile(retr, 50)),
            p95_s=float(np.percentile(retr, 95)),
            p99_s=float(np.percentile(retr, 99)),
            mean_ttft_s=float(lats_np.mean()),
            resident_bytes=float(resident),
            cache_hit_rate=cache.hit_rate if cache else 0.0,
            slo_hit_rate=float((retr <= slo_s).mean()))


@dataclasses.dataclass
class TenantTrace:
    """A multi-tenant request arrival trace: who asks, and when.

    ``tenant_ids[i]`` is the tenant issuing request ``i`` at
    ``arrival_s[i]``.  Produced by :func:`zipf_over_tenants`; consumed by
    the multi-tenant benchmark and any :class:`RequestScheduler` setup.
    """
    arrival_s: np.ndarray        # (N,) f64, nondecreasing
    tenant_ids: np.ndarray       # (N,) int64, rank 0 = hottest tenant
    n_tenants: int
    zipf_a: float

    def __len__(self) -> int:
        return len(self.arrival_s)

    def counts(self) -> Dict[int, int]:
        """Requests per tenant rank (ranks with zero draws included)."""
        out = {t: 0 for t in range(self.n_tenants)}
        for t in self.tenant_ids:
            out[int(t)] += 1
        return out


def zipf_over_tenants(n_tenants: int, n_requests: int, *,
                      zipf_a: float = 1.2, gap_mean_s: float = 0.05,
                      seed: int = 0) -> TenantTrace:
    """Zipf-skewed tenant mix with Poisson arrivals.

    Real multi-tenant request streams are head-heavy: one or two tenants
    dominate while the tail trickles.  Tenant rank for each request is a
    TRUNCATED Zipf(``zipf_a``) draw over exactly ``n_tenants`` ranks
    (rank 0 hottest; probabilities ∝ 1/(rank+1)^a — clipping an unbounded
    Zipf would dump the whole tail's mass onto the last rank instead);
    inter-arrival gaps are exponential with mean ``gap_mean_s``, so the
    trace is a Poisson process over a Zipf tenant marginal.
    """
    assert n_tenants >= 1 and n_requests >= 1
    rng = np.random.default_rng(seed)
    weights = 1.0 / np.arange(1, n_tenants + 1, dtype=np.float64) ** zipf_a
    tenant_ids = rng.choice(n_tenants, size=n_requests,
                            p=weights / weights.sum()).astype(np.int64)
    arrival_s = np.cumsum(rng.exponential(gap_mean_s, size=n_requests))
    return TenantTrace(arrival_s=arrival_s, tenant_ids=tenant_ids,
                       n_tenants=n_tenants, zipf_a=zipf_a)


def simulate_ttft(datasets: Optional[List[str]] = None,
                  configs: Optional[List[str]] = None,
                  **kw) -> Dict[str, Dict[str, SimResult]]:
    """Fig. 13 analogue: TTFT for all five Table 4 configs × datasets."""
    datasets = datasets or list(BEIR_SPECS)
    configs = configs or ["flat", "ivf", "ivf_gen", "ivf_gen_load", "edgerag"]
    out: Dict[str, Dict[str, SimResult]] = {}
    for ds in datasets:
        sim = EdgeSimulator(ds, **kw)
        out[ds] = {cfg: sim.run(cfg) for cfg in configs}
    return out
