"""Continuous batching for the generation model (vLLM/JetStream-style,
adapted to this substrate).

A fixed pool of ``num_slots`` decode slots shares one batched KV cache.
Requests are admitted into free slots (their prompt is prefilled
single-request, then its KV prefix is copied into the slot), the decode
step advances ALL active slots one token per tick with PER-SLOT cache
lengths (models.cache.KVCache.insert's vector path), and finished slots
(max tokens here; an EOS id in production) are freed immediately for the
next waiting request — no batch-wide barrier.

This is the host-side orchestration layer that the decode_32k serve_step
(and its §Perf sharded variant) executes per tick on the pod.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.cache import init_cache
from repro.models.model import decode_step, forward


@dataclasses.dataclass
class SlotState:
    request_id: int = -1
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0

    @property
    def free(self) -> bool:
        return self.request_id < 0


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 4,
                 max_len: int = 256, compute_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.compute_dtype = compute_dtype
        self.caches = init_cache(cfg, num_slots, max_len)
        self.lens = np.zeros(num_slots, np.int32)       # per-slot cache len
        self.next_tok = np.zeros(num_slots, np.int32)
        self.slots = [SlotState() for _ in range(num_slots)]
        self.completed: Dict[int, List[int]] = {}

        self._prefill1 = jax.jit(self._prefill_one)
        self._step = jax.jit(self._decode_all)

    # ---- jitted kernels -------------------------------------------------
    def _prefill_one(self, params, tokens):
        """Prefill ONE request (1, L) against a fresh single-row cache."""
        caches1 = init_cache(self.cfg, 1, self.max_len)
        logits, new_caches, _ = forward(
            params, self.cfg, {"tokens": tokens}, mode="prefill",
            caches=caches1, cache_len=0, compute_dtype=self.compute_dtype,
            remat=False)
        return logits[:, -1], new_caches

    def _decode_all(self, params, caches, toks, lens):
        logits, new_caches = decode_step(
            params, self.cfg, toks[:, None], caches, lens,
            compute_dtype=self.compute_dtype)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches

    # ---- slot management -------------------------------------------------
    def _copy_prefix_into_slot(self, slot: int, caches1, length: int):
        def put(dst, src):
            # dst: (R, num_slots, ...); src: (R, 1, ...)
            return dst.at[:, slot].set(src[:, 0])
        self.caches = jax.tree.map(put, self.caches, caches1)
        self.lens[slot] = length

    def admit(self, request_id: int, prompt_tokens: List[int],
              max_new_tokens: int) -> Optional[int]:
        """Prefill into a free slot; returns the slot or None if full."""
        free = [i for i, s in enumerate(self.slots) if s.free]
        if not free:
            return None
        slot = free[0]
        L = min(len(prompt_tokens), self.max_len - max_new_tokens - 1)
        toks = jnp.asarray([prompt_tokens[:L]], jnp.int32)
        last_logits, caches1 = self._prefill1(self.params, toks)
        self._copy_prefix_into_slot(slot, caches1, L)
        self.next_tok[slot] = int(jnp.argmax(last_logits[0]))
        self.slots[slot] = SlotState(request_id=request_id,
                                     budget=max_new_tokens)
        return slot

    def tick(self) -> int:
        """One decode step for all active slots; returns #active."""
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return 0
        toks = jnp.asarray(self.next_tok, jnp.int32)
        lens = jnp.asarray(self.lens, jnp.int32)
        nxt, self.caches = self._step(self.params, self.caches, toks, lens)
        nxt = np.asarray(nxt)
        for i in active:
            s = self.slots[i]
            s.tokens_out.append(int(self.next_tok[i]))
            self.lens[i] += 1
            self.next_tok[i] = nxt[i]
            if len(s.tokens_out) >= s.budget or self.lens[i] >= self.max_len - 1:
                self.completed[s.request_id] = s.tokens_out
                self.slots[i] = SlotState()     # free immediately
        return len(active)

    def run(self, requests: List[Dict], tick_limit: int = 10_000
            ) -> Dict[int, List[int]]:
        """requests: [{id, prompt_tokens, max_new_tokens}] -> outputs."""
        pending = list(requests)
        ticks = 0
        while (pending or any(not s.free for s in self.slots)) \
                and ticks < tick_limit:
            while pending:
                r = pending[0]
                if self.admit(r["id"], r["prompt_tokens"],
                              r["max_new_tokens"]) is None:
                    break
                pending.pop(0)
            self.tick()
            ticks += 1
        return self.completed
