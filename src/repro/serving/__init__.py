from repro.serving.engine import RAGEngine, RAGResponse  # noqa
from repro.serving.scheduler import Request, RequestScheduler  # noqa
from repro.serving.simulator import EdgeSimulator, simulate_ttft  # noqa
from repro.serving.batching import ContinuousBatcher  # noqa
