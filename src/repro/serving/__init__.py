from repro.serving.engine import BatchJob, RAGEngine, RAGResponse  # noqa
from repro.serving.scheduler import (Request, RequestScheduler,  # noqa
                                     TokenBucketAdmission)
from repro.serving.simulator import (EdgeSimulator, TenantTrace,  # noqa
                                     simulate_ttft, zipf_over_tenants)
from repro.serving.batching import ContinuousBatcher  # noqa
from repro.serving.pipeline import (PipelineBatch, PipelineTrace,  # noqa
                                    StagedPipeline)
from repro.serving.metrics import (Counter, Gauge, Histogram,  # noqa
                                   MetricsRegistry, collect_pipeline_trace,
                                   collect_router, collect_scheduler)
