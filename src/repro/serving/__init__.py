from repro.serving.engine import BatchJob, RAGEngine, RAGResponse  # noqa
from repro.serving.scheduler import Request, RequestScheduler  # noqa
from repro.serving.simulator import EdgeSimulator, simulate_ttft  # noqa
from repro.serving.batching import ContinuousBatcher  # noqa
from repro.serving.pipeline import (PipelineBatch, PipelineTrace,  # noqa
                                    StagedPipeline)
