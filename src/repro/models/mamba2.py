"""Mamba2 (SSD) mixer block — chunked-scan implementation.

State-space recurrence per head h (scalar decay, matrix state):

    S_t = exp(A_h * dt_t) * S_{t-1} + dt_t * (x_t ⊗ B_t)     S: (head_dim, N)
    y_t = S_t · C_t + D_h * x_t

The chunked algorithm (Mamba2 paper §6, "SSD") splits the sequence into
chunks of Q tokens.  Intra-chunk contributions form a (Q, Q) decay-masked
attention-like matrix (cheap: decay is scalar per head); inter-chunk state is
propagated with a single ``lax.scan`` over chunks, which also yields the
final state for decode handoff.  Memory is O(S·Q), never O(S²).

Hardware note (DESIGN.md §2): on GPU Mamba2 fuses this into a warp-level
kernel; on TPU the chunk einsums map straight onto the MXU and the chunk
scan onto XLA's while-loop, so a pure-jnp formulation is already near the
hardware — the Pallas opportunity is in attention/top-k, not here.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rms_norm, rms_norm


class MambaCache(NamedTuple):
    ssm: jax.Array      # (B, nh, head_dim, N)
    conv: jax.Array     # (B, conv_width-1, conv_dim)


def conv_dim(cfg):
    return cfg.ssm_inner_dim + 2 * cfg.ssm_state_size


def init_mamba2(key, cfg):
    """Projections are SPLIT per destination (z / x / B / C / dt) rather than
    fused: slicing a model-sharded fused output forces SPMD halo exchanges
    (collective-permute) on every use — 121 GB/step in the zamba2 train_4k
    baseline (§Perf).  Depthwise conv splits exactly, so three convs replace
    the fused one with identical math."""
    d_in = cfg.ssm_inner_dim
    n = cfg.ssm_state_size
    nh = cfg.ssm_num_heads
    ks = jax.random.split(key, 10)
    return {
        "in_z": dense_init(ks[0], (cfg.d_model, d_in)),
        "in_x": dense_init(ks[1], (cfg.d_model, d_in)),
        "in_b": dense_init(ks[2], (cfg.d_model, n)),
        "in_c": dense_init(ks[3], (cfg.d_model, n)),
        "in_dt": dense_init(ks[4], (cfg.d_model, nh)),
        "conv_x": dense_init(ks[5], (cfg.ssm_conv_width, d_in), scale=0.5),
        "conv_b": dense_init(ks[6], (cfg.ssm_conv_width, n), scale=0.5),
        "conv_c": dense_init(ks[7], (cfg.ssm_conv_width, n), scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "D": jnp.ones((nh,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[8], (nh,)) * 3.5 - 4.6))),
        "gate_norm": init_rms_norm(d_in),
        "out_proj": dense_init(ks[9], (d_in, cfg.d_model)),
    }


def _causal_conv(x, w, carry=None):
    """Depthwise causal conv.  x: (B,S,C), w: (W,C).  carry: (B,W-1,C)."""
    width = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(width))
    new_carry = xp[:, -(width - 1):]
    return out, new_carry


def ssd_chunked(x, log_a, b, c, state0, *, chunk: int = 64):
    """Chunked SSD scan.

    x: (B,S,nh,hd) — already dt-scaled input; log_a: (B,S,nh) — log decay
    (= A*dt, <= 0); b, c: (B,S,N) shared across heads (ngroups=1);
    state0: (B,nh,hd,N).  Returns (y (B,S,nh,hd), final state).
    """
    B, S, nh, hd = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    # chunk-major layout for scan: (nc, B, Q, ...)
    xq = x.reshape(B, nc, chunk, nh, hd).transpose(1, 0, 2, 3, 4)
    aq = log_a.reshape(B, nc, chunk, nh).transpose(1, 0, 2, 3)
    bq = b.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    cq = c.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))            # s <= t

    def body(state, inp):
        xk, ak, bk, ck = inp                                   # (B,Q,...)
        cs = jnp.cumsum(ak, axis=1)                            # (B,Q,nh) incl.
        # intra-chunk: y_t += sum_{s<=t} exp(cs_t - cs_s) (c_t.b_s) x_s
        # mask BEFORE exp: the s>t half has positive exponents that overflow
        # to inf and poison gradients through the where
        diff = cs[:, :, None, :] - cs[:, None, :, :]           # (B,t,s,nh)
        diff = jnp.where(tri[None, :, :, None], diff, -jnp.inf)
        decay = jnp.exp(diff)
        scores = jnp.einsum("btn,bsn->bts", ck, bk)            # (B,t,s)
        y = jnp.einsum("bts,btsh,bshd->bthd", scores, decay, xk)
        # inter-chunk: y_t += c_t . (exp(cs_t) * state)
        y = y + jnp.einsum("btn,bth,bhdn->bthd", ck, jnp.exp(cs), state)
        # state update: S' = exp(cs_last)*S + sum_s exp(cs_last - cs_s) x_s b_s
        wlast = jnp.exp(cs[:, -1:, :] - cs)                    # (B,Q,nh)
        new_state = (state * jnp.exp(cs[:, -1])[:, :, None, None]
                     + jnp.einsum("bsh,bshd,bsn->bhdn", wlast, xk, bk))
        return new_state, y

    state_f, yq = jax.lax.scan(body, state0.astype(jnp.float32),
                               (xq.astype(jnp.float32),
                                aq.astype(jnp.float32),
                                bq.astype(jnp.float32),
                                cq.astype(jnp.float32)))
    y = yq.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, nh, hd)
    return y[:, :S].astype(x.dtype), state_f


def ssd_reference(x, log_a, b, c, state0):
    """Token-by-token oracle for tests."""
    B, S, nh, hd = x.shape

    def step(state, inp):
        xt, at, bt, ct = inp
        state = (state * jnp.exp(at)[:, :, None, None]
                 + jnp.einsum("bhd,bn->bhdn", xt, bt))
        y = jnp.einsum("bhdn,bn->bhd", state, ct)
        return state, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          log_a.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32),
          c.transpose(1, 0, 2).astype(jnp.float32))
    state_f, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state_f


def mamba2_mixer(params, x, cfg, cache: Optional[MambaCache] = None,
                 *, chunk: int = 64) -> Tuple[jax.Array, MambaCache]:
    """Full mixer: in_proj -> conv -> SSD -> gated norm -> out_proj.

    Works for any S >= 1; decode is S == 1 with a cache.
    """
    B, S, _ = x.shape
    d_in, n, nh, hd = (cfg.ssm_inner_dim, cfg.ssm_state_size,
                       cfg.ssm_num_heads, cfg.ssm_head_dim)
    z = x @ params["in_z"].astype(x.dtype)
    xs_ = x @ params["in_x"].astype(x.dtype)
    b_ = x @ params["in_b"].astype(x.dtype)
    c_ = x @ params["in_c"].astype(x.dtype)
    dt = x @ params["in_dt"].astype(x.dtype)
    cw = cfg.ssm_conv_width - 1
    conv_carry = cache.conv if cache is not None else None
    cx = conv_carry[..., :d_in] if conv_carry is not None else None
    cb = (conv_carry[..., d_in:d_in + n]
          if conv_carry is not None else None)
    cc = conv_carry[..., d_in + n:] if conv_carry is not None else None
    xs_, ncx = _causal_conv(xs_, params["conv_x"], cx)
    b_, ncb = _causal_conv(b_, params["conv_b"], cb)
    c_, ncc = _causal_conv(c_, params["conv_c"], cc)
    new_conv = jnp.concatenate([ncx, ncb, ncc], axis=-1)
    xs = jax.nn.silu(xs_).reshape(B, S, nh, hd)
    b = jax.nn.silu(b_)
    c = jax.nn.silu(c_)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])                  # (B,S,nh)
    log_a = -jnp.exp(params["A_log"]) * dt                     # <= 0
    x_dt = xs.astype(jnp.float32) * dt[..., None]
    state0 = (cache.ssm if cache is not None
              else jnp.zeros((B, nh, hd, n), jnp.float32))
    if S == 1:
        y, state_f = ssd_reference(x_dt, log_a, b, c, state0)
    else:
        y, state_f = ssd_chunked(x_dt, log_a, b, c, state0, chunk=chunk)
    y = y + xs.astype(y.dtype) * params["D"][:, None].astype(y.dtype)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, MambaCache(ssm=state_f, conv=new_conv)


def init_mamba_cache(cfg, batch, dtype=jnp.float32) -> MambaCache:
    return MambaCache(
        ssm=jnp.zeros((batch, cfg.ssm_num_heads, cfg.ssm_head_dim,
                       cfg.ssm_state_size), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim(cfg)), dtype),
    )
