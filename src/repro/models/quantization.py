"""Int8 KV-cache quantization (per-token-per-head scales).

The decode shapes are memory-bound on cache streaming (§Roofline): halving
cache bytes halves the dominant term.  Scheme: symmetric int8 with a f32
scale per (token, kv-head) — the standard serving quantization (vLLM /
JetStream fp8/int8 caches use the same granularity).

``decode_attention`` consumers dequantize ON THE FLY: on TPU the Pallas
kernel loads int8 blocks HBM→VMEM and dequantizes in registers
(kernels/decode_attention supports int8 inputs + scales); the jnp path
mirrors it for CPU validation.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QuantKV(NamedTuple):
    q: jax.Array          # int8 (B, S, KH, D)
    scale: jax.Array      # f32  (B, S, KH, 1)


def quantize_kv(x: jax.Array) -> QuantKV:
    """x (..., D) -> int8 values + per-(...,) scale over the last dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return QuantKV(q.astype(jnp.int8), scale)


def dequantize_kv(qkv: QuantKV, dtype=jnp.float32) -> jax.Array:
    return (qkv.q.astype(jnp.float32) * qkv.scale).astype(dtype)


def quant_insert(cache: QuantKV, new: jax.Array, pos) -> QuantKV:
    """Insert (B, 1, KH, D) at per-slot or scalar pos (non-ring)."""
    qnew = quantize_kv(new)
    if jnp.ndim(pos) == 1:
        rows = jnp.arange(cache.q.shape[0])
        return QuantKV(cache.q.at[rows, pos].set(qnew.q[:, 0]),
                       cache.scale.at[rows, pos].set(qnew.scale[:, 0]))
    q = jax.lax.dynamic_update_slice_in_dim(cache.q, qnew.q, pos, 1)
    s = jax.lax.dynamic_update_slice_in_dim(cache.scale, qnew.scale, pos, 1)
    return QuantKV(q, s)


def init_quant_cache(batch: int, smax: int, kh: int, d: int) -> QuantKV:
    return QuantKV(jnp.zeros((batch, smax, kh, d), jnp.int8),
                   jnp.zeros((batch, smax, kh, 1), jnp.float32))
