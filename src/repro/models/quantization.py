"""Int8 KV-cache quantization (per-token-per-head scales).

The decode shapes are memory-bound on cache streaming (§Roofline): halving
cache bytes halves the dominant term.  Scheme: symmetric int8 with a f32
scale per (token, kv-head) — the standard serving quantization (vLLM /
JetStream fp8/int8 caches use the same granularity).

``decode_attention`` consumers dequantize ON THE FLY: on TPU the Pallas
kernel loads int8 blocks HBM→VMEM and dequantizes in registers
(kernels/decode_attention supports int8 inputs + scales); the jnp path
mirrors it for CPU validation.

The same per-row symmetric scheme backs the EdgeRAG *quantized storage
tier* (core/storage.py codec="int8"): cluster embedding matrices are
(n, d) row-quantized with :func:`quantize_rows` before persisting, and
dequantized on load with :func:`dequantize_rows`.  Scales are narrowed to
fp16 on the storage side (2 B/row payload overhead vs. 4·d B of fp32
embeddings).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class QuantKV(NamedTuple):
    q: jax.Array          # int8 (B, S, KH, D)
    scale: jax.Array      # f32  (B, S, KH, 1)


def quantize_kv(x: jax.Array) -> QuantKV:
    """x (..., D) -> int8 values + per-(...,) scale over the last dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return QuantKV(q.astype(jnp.int8), scale)


def dequantize_kv(qkv: QuantKV, dtype=jnp.float32) -> jax.Array:
    return (qkv.q.astype(jnp.float32) * qkv.scale).astype(dtype)


def quant_insert(cache: QuantKV, new: jax.Array, pos) -> QuantKV:
    """Insert (B, 1, KH, D) at per-slot or scalar pos (non-ring)."""
    qnew = quantize_kv(new)
    if jnp.ndim(pos) == 1:
        rows = jnp.arange(cache.q.shape[0])
        return QuantKV(cache.q.at[rows, pos].set(qnew.q[:, 0]),
                       cache.scale.at[rows, pos].set(qnew.scale[:, 0]))
    q = jax.lax.dynamic_update_slice_in_dim(cache.q, qnew.q, pos, 1)
    s = jax.lax.dynamic_update_slice_in_dim(cache.scale, qnew.scale, pos, 1)
    return QuantKV(q, s)


def init_quant_cache(batch: int, smax: int, kh: int, d: int) -> QuantKV:
    return QuantKV(jnp.zeros((batch, smax, kh, d), jnp.int8),
                   jnp.zeros((batch, smax, kh, 1), jnp.float32))


# ---------------------------------------------------------------------------
# Embedding-matrix row quantization (EdgeRAG quantized storage tier)
# ---------------------------------------------------------------------------
def quantize_rows(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(n, d) f32 -> (int8 (n, d), fp16 scales (n, 1)).

    Same symmetric per-row scheme as :func:`quantize_kv` (one scale per
    embedding row instead of per (token, head)), in numpy for the storage
    path.  The scale is snapped to its STORED fp16 value — clamped to the
    fp16 minimum normal so tiny-magnitude rows quantize with bounded error
    instead of decoding to zeros off an underflowed scale — and the int8
    values are computed against that snapped scale.
    """
    x = np.ascontiguousarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1, keepdims=True)
    f16 = np.finfo(np.float16)
    # clamp both ways: an underflowed scale decodes rows to zero, an
    # overflowed one (inf) decodes them to NaN
    scale = np.clip(amax / 127.0, f16.tiny, f16.max).astype(np.float16)
    q = np.clip(np.round(x / scale.astype(np.float32)), -127, 127)
    return q.astype(np.int8), scale


def dequantize_rows(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """Inverse of :func:`quantize_rows`; returns contiguous f32 (n, d)."""
    return np.ascontiguousarray(
        q.astype(np.float32) * scale.astype(np.float32))
