"""GQA attention: reference, memory-efficient chunked (flash-style), decode.

Three implementations with one contract:

* ``attend_reference``  — materializes (B,H,S,S) scores.  Tests / tiny inputs.
* ``attend_chunked``    — lax.scan over KV blocks with online softmax;
                          O(S * block) memory, what prefill_32k lowers.
* ``attend_decode``     — one query token against a KV cache (full or
                          circular sliding-window).
* the Pallas TPU kernels in ``repro.kernels.flash_attention`` /
  ``decode_attention`` implement the same contract for the MXU; ops.py there
  dispatches to these jnp versions as the interpret/CPU fallback oracle.

All functions take q:(B,Sq,H,D), k/v:(B,Skv,KH,D) with H % KH == 0 and return
(B,Sq,H,D).  Masks: ``causal`` plus optional ``window`` (sliding, in tokens).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import softcap

NEG_INF = -1e30


def _expand_kv(k, num_q_heads):
    """(B,S,KH,D) -> (B,S,H,D) by repeating each kv head."""
    b, s, kh, d = k.shape
    rep = num_q_heads // kh
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(Sq, Skv) additive bias from positions."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


def attend_reference(q, k, v, *, causal=True, window=0, logit_cap=0.0,
                     q_offset=0):
    """Quadratic reference.  q_offset: absolute position of q[0] vs k[0]."""
    b, sq, h, d = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = d ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, logit_cap)
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    scores = scores + _mask_bias(q_pos, k_pos, causal, window)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attend_chunked(q, k, v, *, causal=True, window=0, logit_cap=0.0,
                   block_kv=512, q_offset=0):
    """Flash-style online-softmax over KV blocks.

    Memory is O(Sq * block_kv) instead of O(Sq * Skv); this is the jnp
    analogue of the Pallas kernel and is what the 32k-prefill dry-run lowers.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kh = k.shape[2]
    rep = h // kh
    if skv % block_kv:
        pad = block_kv - skv % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = skv
        skv = k.shape[1]
    else:
        kv_valid = skv
    nblocks = skv // block_kv
    scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(sq) + q_offset

    # reshape kv into blocks for scan
    kb = k.reshape(b, nblocks, block_kv, kh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblocks, block_kv, kh, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc = carry
        blk_idx, kblk, vblk = inp
        kblk = _expand_kv(kblk, h).astype(jnp.float32)
        vblk = _expand_kv(vblk, h).astype(jnp.float32)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk)
        scores = softcap(scores, logit_cap)
        k_pos = blk_idx * block_kv + jnp.arange(block_kv)
        bias = _mask_bias(q_pos, k_pos, causal, window)
        bias = jnp.where(k_pos[None, :] < kv_valid, bias, NEG_INF)
        scores = scores + bias[None, None]
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd",
                                                      p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(nblocks), kb, vb))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attend_decode(q, k_cache, v_cache, cache_len, *, window=0, logit_cap=0.0,
                  circular=False):
    """One-token decode: q (B,1,H,D) vs cache (B,Smax,KH,D).

    ``cache_len``: number of valid tokens already in the cache INCLUDING the
    current token (caller inserts k/v of the current token before attending).
    ``circular``: the cache is a ring buffer of size Smax = window; validity
    is simply cache_len clamped to the window (positions are untracked —
    RoPE was applied before insertion).
    """
    b, sq, h, d = q.shape
    assert sq == 1
    kh = k_cache.shape[2]
    k = _expand_kv(k_cache, h).astype(jnp.float32)
    v = _expand_kv(v_cache, h).astype(jnp.float32)
    scale = d ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, k)
    scores = softcap(scores, logit_cap)
    smax = k_cache.shape[1]
    idx = jnp.arange(smax)
    clen = jnp.asarray(cache_len)
    if clen.ndim == 0:
        clen = clen[None]                      # broadcast over batch
    clen = clen[:, None]                       # (B|1, 1)
    if circular:
        valid = idx[None, :] < jnp.minimum(clen, smax)
    else:
        valid = idx[None, :] < clen
        if window and window > 0:
            valid &= idx[None, :] > (clen - 1 - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.astype(q.dtype)
