"""Composable decoder/encoder model over the block zoo.

One code path serves all 12 configs (10 assigned + the paper's embedder and
generator).  The layer stack is ``lax.scan`` over ``depth_repeat`` groups of
``cfg.block_pattern`` blocks — HLO size stays flat in depth, which keeps the
512-way SPMD dry-run compile tractable and matches MaxText's scanned-layers
design.  ``shared_attn`` blocks (zamba2) close over a single unstacked param
set reused at every application.

Public entry points:
  init_params / forward / loss_fn       (training & encoding)
  prefill  / decode_step                (serving; see launch/ and serving/)
  encode                                (the embedding model used by EdgeRAG)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.cache import KVCache, init_cache, kv_cache_spec
from repro.models.layers import (apply_mrope, apply_rope, dense_init,
                                 init_mlp, init_rms_norm, mlp, rms_norm)
from repro.models.mamba2 import init_mamba2, mamba2_mixer
from repro.models.moe import init_moe, moe_block
from repro.models.rwkv6 import init_rwkv6, rwkv6_block

ATTN_KINDS = ("attn", "swa", "shared_attn", "moe", "swa_moe")
# KV-block chunked-attention threshold: sequences longer than this lower the
# online-softmax scan instead of the quadratic reference.
CHUNKED_ATTN_MIN_SEQ = 2048


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_attn_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 6)
    p = {
        "norm1": init_rms_norm(cfg.d_model),
        "wq": dense_init(ks[0], (cfg.d_model, cfg.q_dim)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.kv_dim)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.kv_dim)),
        "wo": dense_init(ks[3], (cfg.q_dim, cfg.d_model)),
        "norm2": init_rms_norm(cfg.d_model),
    }
    if kind in ("moe", "swa_moe"):
        p["moe"] = init_moe(ks[4], cfg.d_model, cfg.d_ff, cfg.num_experts)
    else:
        p["mlp"] = init_mlp(ks[4], cfg.d_model, cfg.d_ff)
    return p


def _init_block(key, cfg: ModelConfig, kind: str):
    if kind in ATTN_KINDS:
        return _init_attn_block(key, cfg, kind)
    if kind == "mamba2":
        return {"norm1": init_rms_norm(cfg.d_model),
                "mixer": init_mamba2(key, cfg)}
    if kind == "rwkv6":
        return init_rwkv6(key, cfg)
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.block_pattern) + 3)
    params: Dict[str, Any] = {}
    params["embed"] = dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                 scale=0.02)
    blocks = []
    shared = None
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "shared_attn":
            shared = _init_block(keys[i + 1], cfg, kind)
            blocks.append(None)  # placeholder; closed over, not scanned
            continue
        layer_keys = jax.random.split(keys[i + 1], cfg.depth_repeat)
        blocks.append(jax.vmap(lambda k: _init_block(k, cfg, kind))(layer_keys))
    params["blocks"] = tuple(blocks)
    if shared is not None:
        params["shared"] = shared
    params["final_norm"] = init_rms_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-1], (cfg.d_model, cfg.vocab_size))
    if dtype != jnp.float32:
        params = jax.tree.map(lambda a: a.astype(dtype), params)
    return params


def param_count(params) -> int:
    # shared blocks appear once in the tree, so this is exact
    return sum(a.size for a in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _attention_sub_block(p, x, cfg: ModelConfig, kind: str, *, positions,
                         causal, mode, cache: Optional[KVCache], cache_len,
                         window_mode: bool, attn_impl: str, dist=None):
    b, s, _ = x.shape
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    q = (h @ p["wq"].astype(x.dtype)).reshape(b, s, cfg.num_heads,
                                              cfg.head_dim)
    k = (h @ p["wk"].astype(x.dtype)).reshape(b, s, cfg.num_kv_heads,
                                              cfg.head_dim)
    v = (h @ p["wv"].astype(x.dtype)).reshape(b, s, cfg.num_kv_heads,
                                              cfg.head_dim)
    if cfg.use_mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window if kind in ("swa", "swa_moe") else 0
    new_cache = cache
    if mode == "decode":
        assert cache is not None and s == 1
        _, circular = kv_cache_spec(cfg, kind, cache.k.shape[1],
                                    window_mode=window_mode)
        # window_mode rings every attention layer (DESIGN.md §4)
        circular = circular or window_mode
        if dist is not None and dist.decode_attn_impl == "sharded":
            from repro.models.distributed import decode_attention_sharded
            out, nk, nv = decode_attention_sharded(
                dist, q, cache.k, cache.v, k, v, cache_len,
                circular=circular, window=window,
                logit_cap=cfg.attn_logit_softcap)
            new_cache = KVCache(nk, nv)
        else:
            new_cache = cache.insert(k, v, cache_len, circular=circular)
            out = attn_lib.attend_decode(
                q, new_cache.k, new_cache.v, jnp.asarray(cache_len) + 1,
                window=window, logit_cap=cfg.attn_logit_softcap,
                circular=circular)
    else:
        if mode == "prefill" and cache is not None:
            _, circular = kv_cache_spec(cfg, kind, cache.k.shape[1],
                                        window_mode=window_mode)
            if circular:
                # ring invariant: token p lives at slot p % size.  Scatter
                # the last `size` tokens to their ring slots (static idx).
                size = cache.k.shape[1]
                if s <= size:
                    new_cache = cache.insert(k, v, 0, circular=False)
                else:
                    pos = jnp.arange(s - size, s) % size
                    new_cache = KVCache(
                        cache.k.at[:, pos].set(k[:, -size:].astype(cache.k.dtype)),
                        cache.v.at[:, pos].set(v[:, -size:].astype(cache.v.dtype)))
            else:
                new_cache = cache.insert(k, v, cache_len, circular=False)
        use_chunked = (attn_impl == "chunked"
                       or (attn_impl == "auto" and s >= CHUNKED_ATTN_MIN_SEQ))
        if use_chunked:
            out = attn_lib.attend_chunked(
                q, k, v, causal=causal, window=window,
                logit_cap=cfg.attn_logit_softcap)
        else:
            out = attn_lib.attend_reference(
                q, k, v, causal=causal, window=window,
                logit_cap=cfg.attn_logit_softcap)
    out = out.reshape(b, s, cfg.q_dim)
    return x + out @ p["wo"].astype(x.dtype), new_cache


def apply_block(kind: str, p, x, cfg: ModelConfig, *, positions, causal,
                mode, cache, cache_len, window_mode, attn_impl, dist=None):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        x, new_cache = _attention_sub_block(
            p, x, cfg, kind, positions=positions, causal=causal, mode=mode,
            cache=cache, cache_len=cache_len, window_mode=window_mode,
            attn_impl=attn_impl, dist=dist)
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind in ("moe", "swa_moe"):
            # decode is dropless: capacity = T covers the all-to-one worst case
            cap = x.shape[0] * x.shape[1] if mode == "decode" else 0
            if dist is not None and dist.moe_impl == "ep":
                if cfg.num_experts % dist.model_size == 0:
                    from repro.models.distributed import moe_block_ep as _moe
                else:
                    # non-divisible expert count: TP-experts (ff-sharded)
                    from repro.models.distributed import moe_block_tp as _moe
                y, aux = _moe(
                    dist, p["moe"], h, num_experts=cfg.num_experts,
                    top_k=cfg.num_experts_per_tok,
                    capacity_factor=cfg.expert_capacity_factor, capacity=cap)
            else:
                y, aux = moe_block(p["moe"], h, num_experts=cfg.num_experts,
                                   top_k=cfg.num_experts_per_tok,
                                   capacity_factor=cfg.expert_capacity_factor,
                                   capacity=cap)
        else:
            y = mlp(p["mlp"], h)
        return x + y, new_cache, aux
    if kind == "mamba2":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        y, new_cache = mamba2_mixer(p["mixer"], h, cfg, cache)
        return x + y, new_cache, aux
    if kind == "rwkv6":
        x, new_cache = rwkv6_block(p, x, cfg, cache)
        return x, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------
def _run_stack(params, x, cfg: ModelConfig, *, positions, causal, mode,
               caches, cache_len, window_mode, attn_impl, remat,
               unroll_layers: bool = False, dist=None):
    shared = params.get("shared")
    pattern = cfg.block_pattern

    sp_sharding = None
    if (dist is not None and dist.seq_parallel
            and mode in ("train", "prefill")):
        # Megatron-style sequence parallelism: the residual stream lives
        # sequence-sharded over the model axis between blocks, turning the
        # TP all-reduces into reduce-scatter + all-gather pairs (half the
        # ring payload) and sharding block-boundary elementwise work
        from jax.sharding import NamedSharding, PartitionSpec as P
        sp_sharding = NamedSharding(
            dist.mesh, P(dist.data_axes, dist.model_axis, None))

    def group(x, group_params, group_caches):
        new_caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            p = shared if kind == "shared_attn" else group_params[i]
            c = group_caches[i] if group_caches is not None else None
            x, nc, aux = apply_block(
                kind, p, x, cfg, positions=positions, causal=causal,
                mode=mode, cache=c, cache_len=cache_len,
                window_mode=window_mode, attn_impl=attn_impl, dist=dist)
            if sp_sharding is not None:
                x = jax.lax.with_sharding_constraint(x, sp_sharding)
            new_caches.append(nc)
            aux_total = aux_total + aux
        return x, tuple(new_caches), aux_total

    if remat:
        group = jax.checkpoint(group)

    # xs: stacked params per pattern position (None for shared slots)
    stacked = tuple(p for p in params["blocks"])

    def body(x, xs):
        gp, gc = xs
        x, ncs, aux = group(x, gp, gc)
        return x, (ncs, aux)

    xs = (stacked, caches)
    if unroll_layers:
        # dry-run accounting mode: XLA's cost_analysis counts a while body
        # ONCE, so the roofline run unrolls the layer loop to get true
        # per-step FLOPs/bytes/collectives.  Real runs keep the scan.
        aux_total = jnp.zeros((), jnp.float32)
        ys = []
        for r in range(cfg.depth_repeat):
            xr = jax.tree.map(lambda a: a[r], xs)
            x, (ncs, aux) = body(x, xr)
            ys.append(ncs)
            aux_total = aux_total + aux
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        return x, new_caches, aux_total
    x, (new_caches, auxs) = jax.lax.scan(body, x, xs,
                                         length=cfg.depth_repeat)
    return x, new_caches, jnp.sum(auxs)


def _embed_inputs(params, cfg: ModelConfig, batch, compute_dtype):
    if batch.get("embeds") is not None:
        x = batch["embeds"].astype(compute_dtype)
    else:
        x = params["embed"][batch["tokens"]].astype(compute_dtype)
    if "vision_embeds" in batch and batch["vision_embeds"] is not None:
        ve = batch["vision_embeds"].astype(compute_dtype)  # (B, P, d)
        x = jax.lax.dynamic_update_slice(x, ve, (0, 0, 0))  # image prefix
    return x


def _default_positions(cfg: ModelConfig, b, s, offset=0):
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim == 1:
        off = off[:, None]                     # per-slot offsets (B, 1)
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + off
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.use_mrope:
        pos = jnp.broadcast_to(pos[None], (3, b, s))  # text: t=h=w
    return pos


def _logits(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"].astype(x.dtype).T
    return x @ params["lm_head"].astype(x.dtype)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, batch, *, mode: str = "train",
            caches=None, cache_len=0, causal: bool = True,
            window_mode: bool = False, attn_impl: str = "auto",
            compute_dtype=jnp.float32, remat: Optional[bool] = None,
            unroll_layers: bool = False, dist=None):
    """Returns (logits, new_caches, aux_loss)."""
    x = _embed_inputs(params, cfg, batch, compute_dtype)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        offset = cache_len if mode == "decode" else 0
        positions = _default_positions(cfg, b, s, offset)
    if remat is None:
        remat = mode == "train"
    x, new_caches, aux = _run_stack(
        params, x, cfg, positions=positions, causal=causal, mode=mode,
        caches=caches, cache_len=cache_len, window_mode=window_mode,
        attn_impl=attn_impl, remat=remat, unroll_layers=unroll_layers,
        dist=dist)
    logits = _logits(params, cfg, x)
    return logits, new_caches, aux


def loss_fn(params, cfg: ModelConfig, batch, *, compute_dtype=jnp.float32,
            attn_impl: str = "auto", dist=None):
    """Next-token cross-entropy + MoE load-balance aux."""
    logits, _, aux = forward(params, cfg, batch, mode="train",
                             compute_dtype=compute_dtype,
                             attn_impl=attn_impl, dist=dist)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = ce + cfg.router_aux_loss_coef * aux
    return total, {"ce": ce, "aux": aux}


def prefill(params, cfg: ModelConfig, batch, caches, *,
            window_mode: bool = False, compute_dtype=jnp.float32,
            attn_impl: str = "auto"):
    """Run the full prompt; fills caches.  Returns (last_logits, caches)."""
    logits, new_caches, _ = forward(
        params, cfg, batch, mode="prefill", caches=caches, cache_len=0,
        window_mode=window_mode, compute_dtype=compute_dtype,
        attn_impl=attn_impl, remat=False)
    return logits[:, -1], new_caches


def decode_step(params, cfg: ModelConfig, tokens_or_embeds, caches,
                cache_len, *, window_mode: bool = False,
                compute_dtype=jnp.float32):
    """One-token serve step.  tokens: (B, 1) int32 (or (B,1,d) embeds).

    Returns (logits (B, vocab), new_caches).
    """
    if tokens_or_embeds.ndim == 2:
        batch = {"tokens": tokens_or_embeds}          # audio decodes codec ids
    else:
        batch = {"embeds": tokens_or_embeds.astype(compute_dtype)}
    logits, new_caches, _ = forward(
        params, cfg, batch, mode="decode", caches=caches,
        cache_len=cache_len, window_mode=window_mode,
        compute_dtype=compute_dtype, remat=False)
    return logits[:, 0], new_caches


def encode(params, cfg: ModelConfig, batch, *, compute_dtype=jnp.float32,
           attn_impl: str = "auto"):
    """Bidirectional mean-pooled sentence embedding (the gte model)."""
    x = _embed_inputs(params, cfg, batch, compute_dtype)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, b, s)
    x, _, _ = _run_stack(params, x, cfg, positions=positions, causal=False,
                         mode="train", caches=None, cache_len=0,
                         window_mode=False, attn_impl=attn_impl, remat=False)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    mask = batch.get("attn_mask")
    if mask is None:
        emb = x.mean(axis=1)
    else:
        m = mask.astype(x.dtype)[..., None]
        emb = (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    emb = emb / jnp.linalg.norm(emb, axis=-1, keepdims=True).clip(1e-9)
    return emb
