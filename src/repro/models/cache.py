"""Decode-time state: KV caches (full / circular sliding-window) and the
SSM states defined by the mixer modules.

All caches are stacked over ``depth_repeat`` (leading axis R) per pattern
position so the layer scan can thread them.  ``cache_len`` is a scalar —
the framework decodes synchronized batches (continuous batching tracks
per-slot lengths one level up, in serving/engine.py).

Whether a KV cache is a ring buffer is STATIC information derived from
(block kind, window_mode) via :func:`kv_cache_spec` — it is intentionally
not stored on the pytree so caches stay pure arrays for pjit.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import LONG_CONTEXT_WINDOW


class KVCache(NamedTuple):
    k: jax.Array          # (B, Smax, KH, D)
    v: jax.Array

    def insert(self, k_new, v_new, cache_len, *, circular: bool):
        """Insert (B, S_new, KH, D) at cache_len (mod size if ring buffer).

        cache_len may be a scalar (synchronized batch) or a (B,) vector of
        per-slot lengths (continuous batching, S_new must be 1)."""
        smax = self.k.shape[1]
        pos = cache_len % smax if circular else cache_len
        if jnp.ndim(pos) == 1:                 # per-slot scatter, S_new == 1
            b = self.k.shape[0]
            rows = jnp.arange(b)
            k = self.k.at[rows, pos].set(k_new[:, 0].astype(self.k.dtype))
            v = self.v.at[rows, pos].set(v_new[:, 0].astype(self.v.dtype))
            return KVCache(k, v)
        k = jax.lax.dynamic_update_slice_in_dim(
            self.k, k_new.astype(self.k.dtype), pos, 1)
        v = jax.lax.dynamic_update_slice_in_dim(
            self.v, v_new.astype(self.v.dtype), pos, 1)
        return KVCache(k, v)


def kv_cache_spec(cfg: ModelConfig, kind: str, max_len: int,
                  *, window_mode: bool) -> Tuple[int, bool]:
    """(cache_size, circular) for an attention block kind."""
    if kind in ("swa", "swa_moe") and cfg.sliding_window:
        return min(cfg.sliding_window, max_len), True
    if window_mode:
        # long-context serving mode: every attention layer gets a ring
        # buffer of the serving window (DESIGN.md §4)
        return min(LONG_CONTEXT_WINDOW, max_len), True
    return max_len, False


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     *, window_mode: bool, dtype=jnp.float32):
    from repro.models.mamba2 import init_mamba_cache
    from repro.models.rwkv6 import init_rwkv_cache
    if kind in ("attn", "swa", "shared_attn", "moe", "swa_moe"):
        size, _ = kv_cache_spec(cfg, kind, max_len, window_mode=window_mode)
        shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind == "mamba2":
        return init_mamba_cache(cfg, batch, dtype)
    if kind == "rwkv6":
        return init_rwkv_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               window_mode: bool = False, dtype=jnp.float32):
    """Tuple over pattern positions; each leaf stacked over depth_repeat."""
    caches = []
    for kind in cfg.block_pattern:
        single = init_layer_cache(cfg, kind, batch, max_len,
                                  window_mode=window_mode, dtype=dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.depth_repeat,) + a.shape),
            single)
        caches.append(stacked)
    return tuple(caches)


def cache_bytes(cache) -> int:
    return sum(a.size * a.dtype.itemsize
               for a in jax.tree.leaves(cache) if hasattr(a, "size"))
