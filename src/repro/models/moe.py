"""Mixture-of-Experts block: top-k router + sort-based capacity dispatch.

TPU-native dispatch (no (T,E,C) one-hot tensor, which is intractable at
T ≈ 1M tokens for train_4k):

  1. router logits -> top-k experts per token, softmax-renormalized gates
  2. flatten the (token, slot) assignments, sort by expert id
  3. position-within-expert via a cumsum over the sorted one-hot; assignments
     beyond the per-expert capacity C are DROPPED (standard capacity-factor
     semantics — dropped tokens pass through the residual only)
  4. gather tokens into an (E, C, d) buffer, batched einsum per expert,
     combine back with a segment-sum weighted by the gate

Sharding: experts shard over the "model" mesh axis, token buffers over
"data"; at baseline GSPMD inserts the all-to-all implied by (4)'s gathers.
The §Perf hillclimb may replace this with an explicit shard_map all-to-all.

Load-balance auxiliary loss follows Switch/OLMoE: E * mean(frac_tokens_e *
frac_router_prob_e), returned so train_step can add it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, d_model, d_ff, num_experts):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d_model, num_experts), scale=0.02),
        "gate": dense_init(k2, (num_experts, d_model, d_ff)),
        "up": dense_init(k3, (num_experts, d_model, d_ff)),
        "down": dense_init(k4, (num_experts, d_ff, d_model)),
    }


def moe_block(params, x, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, capacity: int = 0):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    ``capacity`` > 0 overrides the factor-derived per-expert capacity;
    serving passes capacity=T (dropless — worst case routes every token to
    one expert)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    dtype = x.dtype

    logits = (xf @ params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)        # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch eq. 4) ----
    me = jnp.mean(probs, axis=0)                               # router prob mass
    one_hot_top1 = jax.nn.one_hot(expert_ids, num_experts,
                                  dtype=jnp.float32)           # (T,K,E)
    ce = jnp.mean(one_hot_top1.sum(1), axis=0) / top_k         # token fraction
    aux = num_experts * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    if capacity <= 0:
        capacity = int(max(top_k, t * top_k / num_experts * capacity_factor))
    flat_expert = expert_ids.reshape(-1)                       # (T*K,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), top_k)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # position of each assignment within its expert's contiguous run
    seg_onehot_cum = jnp.cumsum(
        jax.nn.one_hot(sorted_expert, num_experts, dtype=jnp.int32), axis=0)
    pos_in_expert = jnp.take_along_axis(
        seg_onehot_cum, sorted_expert[:, None], axis=1)[:, 0] - 1
    keep = pos_in_expert < capacity

    slot = sorted_expert * capacity + pos_in_expert            # (T*K,)
    slot = jnp.where(keep, slot, num_experts * capacity)       # overflow bin

    # scatter tokens into (E*C+1, d); the +1 row swallows drops
    buf = jnp.zeros((num_experts * capacity + 1, d), dtype)
    buf = buf.at[slot].set(xf[sorted_token])
    buf = buf[:-1].reshape(num_experts, capacity, d)

    # ---- expert FFN (batched over experts) ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               params["gate"].astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["up"].astype(dtype))
    y = jnp.einsum("ecf,efd->ecd", h, params["down"].astype(dtype))
    y = y.reshape(num_experts * capacity, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), dtype)], axis=0)

    # ---- combine: out[token] += gate * y[slot] ----
    contrib = y[slot] * (sorted_gate[:, None].astype(dtype) *
                         keep[:, None].astype(dtype))
    out = jnp.zeros((t, d), dtype).at[sorted_token].add(contrib)
    return out.reshape(b, s, d), aux
