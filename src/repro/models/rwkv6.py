"""RWKV6 ("Finch") block — data-dependent per-channel decay WKV recurrence.

Per head (dk = dv = head_dim), matrix-valued state S: (dk, dv):

    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t          w_t ∈ (0,1)^{dk}, data-dep.
    o_t = r_t · (S_{t-1} + diag(u) (k_t ⊗ v_t))   u: learned "bonus"

w_t = exp(-exp(w0 + tanh(x̄_t W1) W2)) — the Finch low-rank data-dependent
decay.  Token shift (lerp with the previous token) feeds every projection.

Chunked evaluation: intra-chunk contributions need the PAIRWISE decay
exp(csl_t - cs_s) per channel (unlike Mamba2's scalar decay), which is only
numerically safe computed as a difference — never factorized into
exp(csl_t)·exp(-cs_s) (exp(-cs_s) overflows under strong decay).  We
therefore materialize a (B, Q, Q, dk)-per-head tensor for a small chunk
(Q=32 default) inside a lax.scan over chunks.  O(S·Q·dk) memory.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rms_norm, rms_norm

DECAY_LORA = 64


class RwkvCache(NamedTuple):
    wkv: jax.Array       # (B, H, dk, dv) f32
    shift_t: jax.Array   # (B, d_model) last token (time-mix)
    shift_c: jax.Array   # (B, d_model) last token (channel-mix)


def init_rwkv6(key, cfg):
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    H = d // hd
    ks = jax.random.split(key, 10)
    return {
        "norm_t": init_rms_norm(d),
        "mu": 0.5 * jnp.ones((5, d)),          # shift-mix for r,k,v,g,w
        "Wr": dense_init(ks[0], (d, d)),
        "Wk": dense_init(ks[1], (d, d)),
        "Wv": dense_init(ks[2], (d, d)),
        "Wg": dense_init(ks[3], (d, d)),
        "Wo": dense_init(ks[4], (d, d)),
        "w0": jnp.full((d,), -0.6),            # base decay ~ exp(-exp(-0.6))
        "w1": dense_init(ks[5], (d, DECAY_LORA), scale=0.02),
        "w2": dense_init(ks[6], (DECAY_LORA, d), scale=0.02),
        "u": 0.1 * jnp.ones((H, hd)),
        "norm_c": init_rms_norm(d),
        "mu_c": 0.5 * jnp.ones((d,)),
        "Wck": dense_init(ks[7], (d, cfg.d_ff)),
        "Wcv": dense_init(ks[8], (cfg.d_ff, d)),
    }


def _token_shift(x, carry):
    """x: (B,S,d); carry: (B,d) = last token of the previous segment."""
    prev = jnp.concatenate([carry[:, None, :], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def wkv6_recurrent(r, k, v, logw, u, state0):
    """Token-by-token oracle.  r/k/v: (B,S,H,K); logw: (B,S,H,K) (<=0);
    u: (H,K); state0: (B,H,K,V).  Returns (o: (B,S,H,V), final state)."""
    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,K) / (B,H,V)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = S * jnp.exp(wt)[..., None] + kv
        return S, o

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32)
               for a in (r, k, v, logw))
    S, o = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return o.transpose(1, 0, 2, 3), S


def wkv6_chunked(r, k, v, logw, u, state0, *, chunk: int = 32):
    """Chunked scan; exact (no approximation), stable pairwise decays."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    pad = (-S) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, zp) for a in (r, k, v))
        logw = jnp.pad(logw, zp)                   # pad decay 0 => w=1, k=0
    nc = r.shape[1] // chunk
    cm = lambda a: a.reshape(B, nc, chunk, H, -1).transpose(1, 0, 2, 3, 4)
    rq, kq, vq, wq = (cm(a.astype(jnp.float32)) for a in (r, k, v, logw))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)      # s < t

    def body(S, inp):
        rk, kk, vk, wk = inp                       # (B,Q,H,K|V)
        cs = jnp.cumsum(wk, axis=1)                # inclusive  (B,Q,H,K)
        csl = cs - wk                              # exclusive: sum_{i<t}
        # pairwise per-channel decay: D[t,s] = exp(csl_t - cs_s), s < t.
        # mask BEFORE exp — the s >= t half has positive exponents that can
        # overflow to inf and poison gradients through the where.
        diff = csl[:, :, None] - cs[:, None, :]    # (B,t,s,H,K)
        diff = jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)
        A = jnp.exp(diff)
        # intra: o_t = sum_{s<t} (r_t ⊙ D[t,s]) · k_s  v_s
        scores = jnp.einsum("bthk,btshk,bshk->bths", rk, A, kk)
        o = jnp.einsum("bths,bshv->bthv", scores, vk)
        # bonus (s == t): (r_t · (u ⊙ k_t)) v_t
        bonus = jnp.einsum("bthk,hk,bthk->bth", rk, u.astype(jnp.float32), kk)
        o = o + bonus[..., None] * vk
        # state-in: o_t += (r_t ⊙ exp(csl_t)) · S
        o = o + jnp.einsum("bthk,bhkv->bthv", rk * jnp.exp(csl), S)
        # state-out: S' = diag(exp(cs_last)) S + sum_s exp(cs_last - cs_s) k_s v_s
        wl = jnp.exp(cs[:, -1, None] - cs)         # (B,Q,H,K)
        S = (S * jnp.exp(cs[:, -1])[..., None]
             + jnp.einsum("bshk,bshv->bhkv", kk * wl, vk))
        return S, o

    S_f, oq = jax.lax.scan(body, state0.astype(jnp.float32), (rq, kq, vq, wq))
    o = oq.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, H, V)
    return o[:, :S], S_f


def rwkv6_time_mix(params, x, cfg, cache: Optional[RwkvCache],
                   *, chunk: int = 32):
    """x: (B,S,d) (already normed).  Returns (out, (wkv_state, shift_carry))."""
    B, S, d = x.shape
    hd = cfg.ssm_head_dim
    H = d // hd
    carry = (cache.shift_t if cache is not None
             else jnp.zeros((B, d), x.dtype))
    prev, new_carry = _token_shift(x, carry)
    mu = params["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + mu[i] * (prev - x) for i in range(5))
    r = (xr @ params["Wr"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xk @ params["Wk"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (xv @ params["Wv"].astype(x.dtype)).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ params["Wg"].astype(x.dtype))
    # data-dependent log decay (Finch): logw = -exp(w0 + tanh(xw W1) W2)
    ww = (params["w0"].astype(jnp.float32)
          + jnp.tanh(xw.astype(jnp.float32) @ params["w1"])
          @ params["w2"])                                     # (B,S,d)
    logw = -jnp.exp(jnp.clip(ww, -20.0, 10.0)).reshape(B, S, H, hd)
    state0 = (cache.wkv if cache is not None
              else jnp.zeros((B, H, hd, hd), jnp.float32))
    if S == 1:
        o, state_f = wkv6_recurrent(r, k, v, logw, params["u"], state0)
    else:
        o, state_f = wkv6_chunked(r, k, v, logw, params["u"], state0,
                                  chunk=chunk)
    o = o.reshape(B, S, d).astype(x.dtype) * g
    return o @ params["Wo"].astype(x.dtype), (state_f, new_carry)


def rwkv6_channel_mix(params, x, cfg, cache: Optional[RwkvCache]):
    B, S, d = x.shape
    carry = (cache.shift_c if cache is not None
             else jnp.zeros((B, d), x.dtype))
    prev, new_carry = _token_shift(x, carry)
    mu = params["mu_c"].astype(x.dtype)
    xk = x + mu * (prev - x)
    h = jnp.square(jax.nn.relu(xk @ params["Wck"].astype(x.dtype)))
    return h @ params["Wcv"].astype(x.dtype), new_carry


def rwkv6_block(params, x, cfg, cache: Optional[RwkvCache] = None,
                *, chunk: int = 32) -> Tuple[jax.Array, RwkvCache]:
    h = rms_norm(x, params["norm_t"], cfg.norm_eps)
    tm, (wkv_state, shift_t) = rwkv6_time_mix(params, h, cfg, cache,
                                              chunk=chunk)
    x = x + tm
    h = rms_norm(x, params["norm_c"], cfg.norm_eps)
    cmix, shift_c = rwkv6_channel_mix(params, h, cfg, cache)
    x = x + cmix
    return x, RwkvCache(wkv=wkv_state, shift_t=shift_t, shift_c=shift_c)


def init_rwkv_cache(cfg, batch, dtype=jnp.float32) -> RwkvCache:
    d, hd = cfg.d_model, cfg.ssm_head_dim
    return RwkvCache(
        wkv=jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
        shift_t=jnp.zeros((batch, d), dtype),
        shift_c=jnp.zeros((batch, d), dtype),
    )
