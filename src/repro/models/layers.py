"""Shared neural-net layers: RMSNorm, SwiGLU MLP, RoPE (standard + M-RoPE).

Everything is a pure function over explicit param pytrees — no framework
module system.  Param init mirrors llama-family conventions (truncated-normal
projections scaled by fan-in, ones for norms).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale)


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)
    # note: "1 + w" gemma-style so zero-init == identity; init stores zeros


def init_rms_norm(d):
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, (d_model, d_ff)),
        "up": dense_init(k2, (d_model, d_ff)),
        "down": dense_init(k3, (d_ff, d_model)),
    }


def mlp(params, x):
    h = jax.nn.silu(x @ params["gate"].astype(x.dtype))
    h = h * (x @ params["up"].astype(x.dtype))
    return h @ params["down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    """(head_dim // 2,) inverse frequencies."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """Standard rotary embedding.

    x: (B, S, H, D); positions: (B, S) int32.
    """
    inv_freq = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B,S,D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections):
    """Multimodal RoPE (qwen2-vl, arXiv:2409.12191).

    positions: (3, B, S) — temporal / height / width position streams.
    ``sections`` partitions the head_dim//2 frequency bands among the three
    streams; each band rotates by its assigned stream's position.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = rope_frequencies(x.shape[-1], theta)          # (half,)
    # (3, B, S, half)
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    # select the stream per frequency band via one-hot contraction
    sel = np.concatenate([np.full((s,), i) for i, s in enumerate(sections)])
    onehot = jax.nn.one_hot(jnp.asarray(sel), 3, dtype=angles.dtype)  # (half,3)
    angles = jnp.einsum("tbsf,ft->bsf", angles, onehot)      # (B, S, half)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(logits, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits
