"""Explicit shard_map collectives — the §Perf hillclimb implementations.

GSPMD gets the baselines right for dense matmuls but falls over on two
patterns this framework hits hard (evidence: analysis.hlo.collective_sites
on the compiled baselines, recorded in EXPERIMENTS.md §Perf):

1. DECODE ATTENTION over a sequence-sharded KV cache: the attention einsum
   prefers head sharding, so GSPMD involuntarily all-gathers the entire
   cache every step (gemma3-12b decode_32k: 4.7 GB/chip/token).
   -> ``decode_attention_sharded``: distributed flash-decoding.  Each model
   shard attends over its local cache slice, then one pmax (B,H) + two psum
   (B,H,D)/(B,H) merge the partial softmaxes.  Ring insert is shard-local.

2. MoE DISPATCH: the (E,C,d) scatter forces GSPMD to materialize the full
   expert buffer per shard and all-reduce it (olmoe prefill_32k:
   260 GB of all-reduce in the HLO, 150 GB temp per chip).
   -> ``moe_block_ep``: expert parallelism over the "model" axis.  Tokens
   stay replicated across the model axis (they are sharded over "data"),
   each shard routes/dispatches only to its E/16 local experts, and one
   psum of the (T_loc, d) partial outputs combines — the same wire cost as
   a dense tensor-parallel MLP, with no giant buffer.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:                                    # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
# keyed on the signature, not the import location: the public promotion of
# shard_map and the check_rep -> check_vma rename were separate changes
_REP_KW = ("check_vma" if "check_vma" in
           inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, *args, check_vma=False, **kwargs):
    """jax version shim: check_vma (>=0.5) == check_rep (0.4.x)."""
    return _shard_map(f, *args, **{_REP_KW: check_vma}, **kwargs)

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Distribution context threaded through forward() when explicit
    (beyond-GSPMD) collectives are requested."""
    mesh: object                          # jax Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    moe_impl: str = "gspmd"               # "gspmd" | "ep"
    decode_attn_impl: str = "gspmd"       # "gspmd" | "sharded"
    seq_parallel: bool = False            # Megatron-SP residual layout

    @property
    def model_size(self) -> int:
        return dict(zip(self.mesh.axis_names,
                        self.mesh.devices.shape))[self.model_axis]


# ---------------------------------------------------------------------------
# 1. distributed flash decoding + shard-local ring insert
# ---------------------------------------------------------------------------
def decode_attention_sharded(dist: DistConfig, q, k_cache, v_cache, k_new,
                             v_new, cache_len, *, circular: bool,
                             window: int = 0, logit_cap: float = 0.0):
    """q: (B,1,H,D); caches (B,S,KH,D) seq-sharded over the model axis.

    Inserts (k_new, v_new) at cache_len (ring if circular) LOCALLY on the
    owning shard, then flash-decodes across shards.  Returns
    (out (B,1,H,D), new_k, new_v).
    """
    mesh = dist.mesh
    ax = dist.model_axis
    dp = dist.data_axes
    b = q.shape[0]
    dp_spec = dp if b % _axes_size(mesh, dp) == 0 else None

    qspec = P(dp_spec, None, None, None)       # replicated over model
    cspec = P(dp_spec, ax, None, None)         # seq-sharded cache

    def local_fn(q, k_loc, v_loc, k_new, v_new, cache_len):
        n_shards = jax.lax.psum(1, ax)
        shard = jax.lax.axis_index(ax)
        s_loc = k_loc.shape[1]
        smax = s_loc * n_shards
        pos = cache_len % smax if circular else jnp.minimum(cache_len,
                                                            smax - 1)
        # ---- shard-local insert ----
        local_slot = pos - shard * s_loc
        in_range = (local_slot >= 0) & (local_slot < s_loc)
        slot = jnp.clip(local_slot, 0, s_loc - 1)
        old_k = jax.lax.dynamic_slice_in_dim(k_loc, slot, 1, 1)
        old_v = jax.lax.dynamic_slice_in_dim(v_loc, slot, 1, 1)
        ins_k = jnp.where(in_range, k_new.astype(k_loc.dtype), old_k)
        ins_v = jnp.where(in_range, v_new.astype(v_loc.dtype), old_v)
        k_loc = jax.lax.dynamic_update_slice_in_dim(k_loc, ins_k, slot, 1)
        v_loc = jax.lax.dynamic_update_slice_in_dim(v_loc, ins_v, slot, 1)

        # ---- local flash-decode ----
        # grouped-head einsum: never materialize the GQA-repeated or
        # f32-cast cache (PERF iter 2: cuts ~3 cache-sized copies/layer)
        bq, _, h, d = q.shape
        kh = k_loc.shape[2]
        g = h // kh
        qg = (q[:, 0].astype(jnp.float32) * (d ** -0.5)
              ).reshape(bq, kh, g, d)
        scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_loc,
                            preferred_element_type=jnp.float32)
        if logit_cap:
            scores = jnp.tanh(scores / logit_cap) * logit_cap
        gpos = shard * s_loc + jnp.arange(s_loc)
        n_valid = cache_len + 1
        if circular:
            valid = gpos < jnp.minimum(n_valid, smax)
        else:
            valid = gpos < n_valid
            if window:
                valid &= gpos > n_valid - 1 - window
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
        m_loc = scores.max(axis=-1)                              # (B,KH,G)
        m = jax.lax.pmax(m_loc, ax)
        p = jnp.exp(scores - m[..., None])
        l_loc = p.sum(axis=-1)
        acc_loc = jnp.einsum("bkgs,bskd->bkgd", p, v_loc,
                             preferred_element_type=jnp.float32)
        l = jax.lax.psum(l_loc, ax)
        acc = jax.lax.psum(acc_loc, ax)
        out = (acc / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)
        return out.reshape(bq, 1, h, d), k_loc, v_loc

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(qspec, cspec, cspec,
                  P(dp_spec, None, None, None), P(dp_spec, None, None, None),
                  P()),
        out_specs=(P(dp_spec, None, None, None), cspec, cspec),
        check_vma=False)
    out, new_k, new_v = fn(q, k_cache, v_cache, k_new, v_new,
                           jnp.asarray(cache_len, jnp.int32))
    # out from local_fn is (B,1,H,D) already
    return out.reshape(q.shape), new_k, new_v


def _axes_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in (axes if isinstance(axes, (tuple, list)) else [axes]):
        n *= sizes[a]
    return n


# ---------------------------------------------------------------------------
# 2. expert-parallel MoE
# ---------------------------------------------------------------------------
def moe_block_ep(dist: DistConfig, params, x, *, num_experts: int,
                 top_k: int, capacity_factor: float = 1.25,
                 capacity: int = 0):
    """Expert-parallel MoE: experts sharded over the model axis, tokens
    sharded over data / replicated over model.  Combine = one psum of the
    (B_loc,S,d) partial outputs (dense-TP wire cost).

    Requires num_experts % model_axis_size == 0 (olmoe 64/16 OK; granite 40
    falls back to the GSPMD path at the call site)."""
    mesh = dist.mesh
    ax = dist.model_axis
    dp = dist.data_axes
    b, s, d = x.shape
    n_model = dist.model_size
    assert num_experts % n_model == 0
    e_loc = num_experts // n_model
    dp_spec = dp if b % _axes_size(mesh, dp) == 0 else None

    def local_fn(router, gate, up, down, x):
        # x: (B_loc, S, d); router (d, E) replicated; expert tables local
        bl, sl, dl = x.shape
        t = bl * sl
        xf = x.reshape(t, dl)
        dtype = x.dtype
        logits = (xf @ router.astype(dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        # aux loss: identical across model shards, but token means must
        # average over the data axis (tokens are data-sharded)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids, num_experts,
                                     dtype=jnp.float32).sum(1), axis=0) / top_k
        if dp:
            me = jax.lax.pmean(me, dp)
            ce = jax.lax.pmean(ce, dp)
        aux = num_experts * jnp.sum(me * ce)

        cap = capacity if capacity > 0 else int(
            max(top_k, t * top_k / num_experts * capacity_factor))
        e0 = jax.lax.axis_index(ax) * e_loc
        flat_expert = expert_ids.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(t), top_k)
        local_eid = flat_expert - e0
        is_local = (local_eid >= 0) & (local_eid < e_loc)
        sort_key = jnp.where(is_local, local_eid, e_loc)   # non-local last
        order = jnp.argsort(sort_key, stable=True)
        sorted_eid = sort_key[order]
        sorted_token = flat_token[order]
        sorted_gate = flat_gate[order]
        seg_cum = jnp.cumsum(
            jax.nn.one_hot(sorted_eid, e_loc + 1, dtype=jnp.int32), axis=0)
        pos_in_e = jnp.take_along_axis(
            seg_cum, sorted_eid[:, None], axis=1)[:, 0] - 1
        keep = (sorted_eid < e_loc) & (pos_in_e < cap)
        slot = jnp.where(keep, sorted_eid * cap + pos_in_e, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, dl), dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], xf[sorted_token], 0))
        buf = buf[:-1].reshape(e_loc, cap, dl)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate.astype(dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, up.astype(dtype))
        y = jnp.einsum("ecf,efd->ecd", h, down.astype(dtype))
        y = jnp.concatenate([y.reshape(e_loc * cap, dl),
                             jnp.zeros((1, dl), dtype)], axis=0)
        contrib = y[slot] * (sorted_gate[:, None].astype(dtype)
                             * keep[:, None].astype(dtype))
        out = jnp.zeros((t, dl), dtype).at[sorted_token].add(contrib)
        out = jax.lax.psum(out, ax)            # combine expert partials
        return out.reshape(bl, sl, dl), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(ax, None, None), P(ax, None, None),
                  P(ax, None, None), P(dp_spec, None, None)),
        out_specs=(P(dp_spec, None, None), P()),
        check_vma=False)
    out, aux = fn(params["router"], params["gate"], params["up"],
                  params["down"], x)
    return out, aux


# ---------------------------------------------------------------------------
# 3. tensor-parallel experts (any expert count)
# ---------------------------------------------------------------------------
def moe_block_tp(dist: DistConfig, params, x, *, num_experts: int,
                 top_k: int, capacity_factor: float = 1.25,
                 capacity: int = 0):
    """TP-experts MoE for expert counts that do NOT divide the model axis
    (granite's 40e over 16): every model shard holds ALL experts but only
    ff/n_model columns of each expert's FFN.  Dispatch buffers are built
    from LOCAL tokens only (no GSPMD full-buffer all-reduce)
    and one psum of (T_loc, d) partial outputs combines, exactly like
    ``moe_block_ep``.  Wire cost identical to EP; compute identical to the
    reference (no replication waste)."""
    mesh = dist.mesh
    ax = dist.model_axis
    dp = dist.data_axes
    b, s, d = x.shape
    dp_spec = dp if b % _axes_size(mesh, dp) == 0 else None

    def local_fn(router, gate, up, down, x):
        bl, sl, dl = x.shape
        t = bl * sl
        xf = x.reshape(t, dl)
        dtype = x.dtype
        logits = (xf @ router.astype(dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids, num_experts,
                                     dtype=jnp.float32).sum(1), axis=0) / top_k
        if dp:
            me = jax.lax.pmean(me, dp)
            ce = jax.lax.pmean(ce, dp)
        aux = num_experts * jnp.sum(me * ce)

        cap = capacity if capacity > 0 else int(
            max(top_k, t * top_k / num_experts * capacity_factor))
        flat_expert = expert_ids.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(t), top_k)
        order = jnp.argsort(flat_expert, stable=True)
        sorted_eid = flat_expert[order]
        sorted_token = flat_token[order]
        sorted_gate = flat_gate[order]
        seg_cum = jnp.cumsum(
            jax.nn.one_hot(sorted_eid, num_experts, dtype=jnp.int32), axis=0)
        pos_in_e = jnp.take_along_axis(
            seg_cum, sorted_eid[:, None], axis=1)[:, 0] - 1
        keep = pos_in_e < cap
        slot = jnp.where(keep, sorted_eid * cap + pos_in_e,
                         num_experts * cap)
        buf = jnp.zeros((num_experts * cap + 1, dl), dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], xf[sorted_token], 0))
        buf = buf[:-1].reshape(num_experts, cap, dl)
        # ff-sharded expert FFN: local columns, full contraction on down
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate.astype(dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, up.astype(dtype))
        y = jnp.einsum("ecf,efd->ecd", h, down.astype(dtype))  # partial in d
        y = jnp.concatenate([y.reshape(num_experts * cap, dl),
                             jnp.zeros((1, dl), dtype)], axis=0)
        contrib = y[slot] * (sorted_gate[:, None].astype(dtype)
                             * keep[:, None].astype(dtype))
        out = jnp.zeros((t, dl), dtype).at[sorted_token].add(contrib)
        out = jax.lax.psum(out, ax)            # combine ff partials
        return out.reshape(bl, sl, dl), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(None, None, ax), P(None, None, ax),
                  P(None, ax, None), P(dp_spec, None, None)),
        out_specs=(P(dp_spec, None, None), P()),
        check_vma=False)
    out, aux = fn(params["router"], params["gate"], params["up"],
                  params["down"], x)
    return out, aux
