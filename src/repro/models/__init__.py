from repro.models.model import (decode_step, encode, forward, init_params,
                                loss_fn, param_count, prefill)  # noqa
from repro.models.cache import KVCache, init_cache, cache_bytes  # noqa
