import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^^ MUST precede every other import: jax locks the device count on first
# init.  512 host devices back both the 256-chip single-pod mesh and the
# 2x256 multi-pod mesh.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) combination this lowers and
compiles the real step function — ``train_step`` for train_4k,
``prefill`` for prefill_32k, ``serve_step`` (one token vs. a seq_len cache)
for decode shapes — against ShapeDtypeStruct inputs (no allocation), then
records:

  * memory_analysis()            — proves the layout fits per device
  * cost_analysis()              — per-chip FLOPs / bytes for §Roofline
  * collective bytes (HLO parse) — the third roofline term

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--archs a,b,c]
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import configs
from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import model_flops_estimate, roofline
from repro.configs.shapes import INPUT_SHAPES
from repro.launch import input_specs as specs
from repro.launch.mesh import make_production_mesh
from repro.launch import sharding as shd
from repro.models.model import decode_step, prefill
from repro.train.train_step import TrainState, make_train_step
from repro.train.optimizer import AdamWState

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../experiments/dryrun")


def _is_long_context_dense_skip(cfg, shape) -> bool:
    # DESIGN.md §4: no skips — attention archs serve long_500k through the
    # sliding-window mode.  Kept as a hook for pure full-attention runs.
    return False


def build_case(arch: str, shape_name: str, mesh, *, compute_dtype=jnp.bfloat16,
               param_dtype=None, overrides: Dict[str, Any] | None = None):
    """Returns (jitted_fn, kwargs_specs dict)."""
    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    overrides = overrides or {}
    dist = None
    if (overrides.get("moe_impl") or overrides.get("decode_attn_impl")
            or overrides.get("seq_parallel")):
        from repro.launch.mesh import data_axes
        from repro.models.distributed import DistConfig
        dist = DistConfig(
            mesh=mesh, data_axes=data_axes(mesh),
            moe_impl=overrides.get("moe_impl", "gspmd"),
            decode_attn_impl=overrides.get("decode_attn_impl", "gspmd"),
            seq_parallel=bool(overrides.get("seq_parallel", False)))

    if shape.kind == "train":
        param_dtype = param_dtype or jnp.float32
        params_s = specs.param_specs(cfg, param_dtype)
        state_s = TrainState(
            params=params_s,
            opt=AdamWState(mu=params_s, nu=params_s,
                           count=jax.ShapeDtypeStruct((), jnp.int32)),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        batch_s = specs.input_specs(cfg, shape, compute_dtype)["batch"]
        unroll = bool(overrides.get("unroll_layers", False))
        fsdp_gather = bool(overrides.get("fsdp_gather", False))
        gathered_sh = (shd.param_shardings(params_s, mesh, mode="serve")
                       if fsdp_gather else None)
        if unroll or fsdp_gather:
            from repro.models.model import forward as _fwd
            from repro.train.optimizer import adamw_update, cosine_schedule

            def _loss(p, batch):
                if fsdp_gather:
                    # FSDP proper: gather weights over the data axis ONCE per
                    # step instead of letting GSPMD all-reduce activations
                    p = jax.lax.with_sharding_constraint(p, gathered_sh)
                logits, _, aux = _fwd(p, cfg, batch, mode="train",
                                      compute_dtype=compute_dtype,
                                      unroll_layers=unroll, dist=dist)
                labels = batch["labels"]
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
                return ll.mean() * -1.0 + cfg.router_aux_loss_coef * aux

            def step(state, batch):
                loss, grads = jax.value_and_grad(_loss)(state.params, batch)
                lr = cosine_schedule(state.step)
                new_p, new_opt, gn = adamw_update(grads, state.opt,
                                                  state.params, lr=lr)
                return TrainState(new_p, new_opt, state.step + 1), {
                    "loss": loss, "grad_norm": gn}
        else:
            step = make_train_step(cfg, compute_dtype=compute_dtype,
                                   attn_impl=overrides.get("attn_impl", "auto"),
                                   dist=dist)
        fn = step
        args = (state_s, batch_s)
        in_sh = (TrainState(
                    params=shd.param_shardings(params_s, mesh),
                    opt=AdamWState(mu=shd.param_shardings(params_s, mesh),
                                   nu=shd.param_shardings(params_s, mesh),
                                   count=shd.replicated(mesh, state_s.step)),
                    step=shd.replicated(mesh, state_s.step)),
                 shd.batch_pspec(mesh, batch_s))
    elif shape.kind == "prefill":
        param_dtype = param_dtype or jnp.bfloat16
        params_s = specs.param_specs(cfg, param_dtype)
        sp = specs.input_specs(cfg, shape, compute_dtype)
        fn = functools.partial(
            prefill_step, cfg=cfg, compute_dtype=compute_dtype,
            window_mode=shape.sliding_window_mode,
            unroll_layers=bool(overrides.get("unroll_layers", False)),
            dist=dist)
        args = (params_s, sp["batch"], sp["caches"])
        in_sh = (shd.param_shardings(params_s, mesh, mode="serve"),
                 shd.batch_pspec(mesh, sp["batch"]),
                 shd.cache_pspec(cfg, mesh, sp["caches"]))
    else:  # decode
        param_dtype = param_dtype or jnp.bfloat16
        params_s = specs.param_specs(cfg, param_dtype)
        sp = specs.input_specs(cfg, shape, compute_dtype)
        fn = functools.partial(
            serve_step, cfg=cfg, compute_dtype=compute_dtype,
            window_mode=shape.sliding_window_mode,
            unroll_layers=bool(overrides.get("unroll_layers", False)),
            dist=dist)
        args = (params_s, sp["tokens"], sp["caches"], sp["cache_len"])
        in_sh = (shd.param_shardings(params_s, mesh, mode="serve"),
                 shd.batch_pspec(mesh, sp["tokens"]),
                 shd.cache_pspec(cfg, mesh, sp["caches"]),
                 shd.replicated(mesh, sp["cache_len"]))
    return cfg, shape, fn, args, in_sh


def prefill_step(params, batch, caches, *, cfg, compute_dtype, window_mode,
                 unroll_layers=False, dist=None):
    from repro.models.model import forward
    logits, new_caches, _ = forward(
        params, cfg, batch, mode="prefill", caches=caches, cache_len=0,
        window_mode=window_mode, compute_dtype=compute_dtype, remat=False,
        unroll_layers=unroll_layers, dist=dist)
    return logits[:, -1], new_caches


def serve_step(params, tokens, caches, cache_len, *, cfg, compute_dtype,
               window_mode, unroll_layers=False, dist=None):
    """ONE new token against a seq_len KV cache; returns greedy next ids."""
    from repro.models.model import forward
    batch = ({"tokens": tokens} if tokens.ndim == 2
             else {"embeds": tokens.astype(compute_dtype)})
    logits, new_caches, _ = forward(
        params, cfg, batch, mode="decode", caches=caches,
        cache_len=cache_len, window_mode=window_mode,
        compute_dtype=compute_dtype, remat=False,
        unroll_layers=unroll_layers, dist=dist)
    return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), new_caches


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True, overrides: Dict[str, Any] | None = None,
             tag: str = "") -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, shape, fn, args, in_sh = build_case(arch, shape_name, mesh,
                                             overrides=overrides)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception:
        mem_d = {}
    hlo = compiled.as_text()
    chips = 512 if multi_pod else 256
    coll_total, coll_by_op, coll_counts = collective_bytes(hlo)
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    rep = roofline(arch=arch, shape=shape_name, mesh_name=mesh_name,
                   chips=chips, hlo_flops=flops, hlo_bytes=byt,
                   collective_bytes=coll_total, collective_by_op=coll_by_op,
                   model_flops=model_flops_estimate(cfg, shape))
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "ok": True, "tag": tag,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_d,
        "collective_bytes_per_chip": coll_total,
        "collective_by_op": coll_by_op,
        "collective_counts": coll_counts,
        "roofline": rep.row(),
        "overrides": overrides or {},
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(RESULTS_DIR,
                            f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--archs", type=str, default=None,
                    help="comma-separated subset")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan for exact cost accounting")
    args = ap.parse_args()

    if args.all or args.archs:
        archs = (args.archs.split(",") if args.archs
                 else list(configs.ASSIGNED_ARCHS))
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    else:
        archs = [args.arch or "stablelm-1.6b"]
        shapes = [args.shape or "train_4k"]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            try:
                out = run_case(arch, shape_name, multi_pod=args.multi_pod,
                               save=not args.no_save,
                               overrides=({"unroll_layers": True}
                                          if args.unroll else None),
                               tag="unroll" if args.unroll else "")
                r = out["roofline"]
                print(f"[OK]   {arch:24s} {shape_name:12s} {out['mesh']:8s} "
                      f"compute={r['compute_ms']:9.3f}ms "
                      f"memory={r['memory_ms']:9.3f}ms "
                      f"coll={r['collective_ms']:9.3f}ms "
                      f"dom={r['dominant']:10s} "
                      f"compile={out['compile_s']:6.1f}s", flush=True)
            except Exception as e:
                failures.append((arch, shape_name, repr(e)))
                print(f"[FAIL] {arch:24s} {shape_name:12s}: {e!r}",
                      flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
