"""Sharding rules: param / activation / cache PartitionSpecs per config.

Baseline scheme (GSPMD-completed, §Perf hillclimbs override per-pair):

* 2D weight sharding ("fsdp" flavor): the contraction dim of every matmul
  weight shards over "data", the output-feature dim over "model".  GSPMD
  materializes the FSDP all-gathers during compute; optimizer state shards
  identically so per-chip state is params/256.
* batch shards over ("pod","data"); model-parallel math over "model".
* KV caches shard the SEQUENCE dim over "model" (uniformly legal — kv-head
  counts of the assigned archs are mostly < 16) and batch over "data";
  GSPMD turns decode softmax over the sharded seq dim into a partial-softmax
  + all-reduce.  SSM states shard their head dim over "model".
* MoE expert tables shard experts over "model" (GSPMD pads 40e over 16).

Rules key off leaf PATH NAMES; leading stacked-layer axes are padded with
None automatically (rank-aligned from the right).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes

# trailing-dims spec per leaf name (rank-aligned from the right)
_PARAM_RULES: Dict[str, Tuple] = {
    "embed": ("model", "data"),          # (V, d)
    "lm_head": ("data", "model"),        # (d, V)
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "gate": ("data", "model"),           # mlp + moe expert tables (see below)
    "up": ("data", "model"),
    "down": ("model", "data"),
    "router": ("data", None),
    "in_z": ("data", "model"),           # mamba (split projections)
    "in_x": ("data", "model"),
    "in_b": ("data", "model"),
    "in_c": ("data", "model"),
    "in_dt": ("data", "model"),
    "out_proj": ("model", "data"),
    "conv_x": (None, "model"),
    "conv_b": (None, "model"),
    "conv_c": (None, "model"),
    "Wr": ("data", "model"),
    "Wk": ("data", "model"),
    "Wv": ("data", "model"),
    "Wg": ("data", "model"),
    "Wo": ("model", "data"),
    "w1": ("data", None),
    "w2": (None, "model"),
    "Wck": ("data", "model"),
    "Wcv": ("model", "data"),
}
# MoE expert tables are (E, d, ff): experts over model, d over data
_MOE_EXPERT_RULES: Dict[str, Tuple] = {
    "gate": ("model", "data", None),
    "up": ("model", "data", None),
    "down": ("model", None, "data"),
}


def _leaf_path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


# fallback for MoE expert tables whose expert count doesn't divide the model
# axis (granite's 40e over 16): shard the FFN dim over model instead
_MOE_EXPERT_FALLBACK: Dict[str, Tuple] = {
    "gate": (None, "data", "model"),
    "up": (None, "data", "model"),
    "down": (None, "model", "data"),
}


def _legalize(rule: Tuple, shape, mesh) -> Tuple:
    """Drop axes that don't divide the corresponding dim (jit requires exact
    divisibility for argument shardings)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape[-len(rule):], rule):
        axes = (ax,) if isinstance(ax, str) else (ax or ())
        n = 1
        for a in axes:
            n *= sizes[a]
        out.append(ax if (n and dim % n == 0) else None)
    return tuple(out)


def param_pspec(path, leaf, mesh, mode: str = "train") -> P:
    """mode="train": 2D fsdp×tensor sharding (optimizer state scales).
    mode="serve": tensor-parallel only — weights replicate over "data" so
    decode never all-gathers weights across the batch axis."""
    names = _leaf_path_names(path)
    name = names[-1]
    in_moe = "moe" in names
    rule = None
    if in_moe and name in _MOE_EXPERT_RULES:
        rule = _MOE_EXPERT_RULES[name]
    elif name in _PARAM_RULES:
        rule = _PARAM_RULES[name]
    if rule is None:
        return P()                       # norms, scalars, biases: replicate
    rank = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    if rank < len(rule):
        return P()
    shape = leaf.shape
    legal = _legalize(rule, shape, mesh)
    if in_moe and name in _MOE_EXPERT_RULES and legal[0] is None:
        # expert dim not divisible -> shard the FFN dim over model instead
        legal = _legalize(_MOE_EXPERT_FALLBACK[name], shape, mesh)
    if mode == "serve":
        legal = tuple(None if r == "data" else r for r in legal)
    pad = (None,) * (rank - len(legal))
    return P(*(pad + tuple(legal)))


def param_shardings(params, mesh, mode: str = "train"):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh,
                                                           mode)),
        params)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------
def batch_pspec(mesh, batch_tree) -> Any:
    dp = data_axes(mesh)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_n = 1
    for a in dp:
        dp_n *= sizes[a]

    def spec(path, leaf) -> P:
        names = _leaf_path_names(path)
        name = names[-1] if names else ""
        rank = leaf.ndim
        if name == "positions" and rank == 3:      # mrope (3, B, S)
            b = leaf.shape[1]
            return P(None, dp if b % dp_n == 0 else None, None)
        if rank == 0:
            return P()
        # (B, ...) batch leading; replicate when B doesn't divide (batch=1)
        if leaf.shape[0] % dp_n:
            return P(*((None,) * rank))
        return P(*((dp,) + (None,) * (rank - 1)))

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec(path, leaf)), batch_tree)


def cache_pspec(cfg: ModelConfig, mesh, cache_tree):
    """Stacked caches: leaves are (R, B, ...).

    KVCache.k/v: (R, B, S, KH, D) -> seq over model.
    Mamba ssm (R, B, nh, hd, N) / rwkv wkv (R, B, H, dk, dv) -> heads over
    model.  conv (R, B, W, C) -> C over model.  shifts (R, B, d) -> d over
    model.
    """
    dp = data_axes(mesh)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_n = 1
    for a in dp:
        dp_n *= sizes[a]
    mp_n = sizes.get("model", 1)

    def spec(path, leaf) -> P:
        names = _leaf_path_names(path)
        name = names[-1] if names else ""
        rank = leaf.ndim
        b = lambda: dp if (rank >= 2 and leaf.shape[1] % dp_n == 0) else None
        m = lambda d: "model" if leaf.shape[d] % mp_n == 0 else None
        if name in ("k", "v") and rank == 5:
            return P(None, b(), m(2), None, None)     # seq over model
        if name in ("ssm", "wkv") and rank == 5:
            return P(None, b(), m(2), None, None)     # heads over model
        if name == "conv" and rank == 4:
            return P(None, b(), None, m(3))
        if name in ("shift_t", "shift_c") and rank == 3:
            return P(None, b(), m(2))
        if rank >= 2:
            return P(*((None, b()) + (None,) * (rank - 2)))
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec(path, leaf)), cache_tree)


def replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
