"""Serving launcher: the full EdgeRAG pipeline, end to end, for real.

Builds a synthetic BEIR-like corpus, indexes it with EdgeRAG (real k-means,
real pruning/storage/caching), embeds queries with the gte model on the JAX
substrate, retrieves, and generates with the chosen architecture — reporting
per-query TTFT (edge-simulated + wall).

  python -m repro.launch.serve --dataset fever --queries 40 --arch yi-9b
"""
from __future__ import annotations

import argparse

import numpy as np

from repro import configs
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data.synthetic import scaled_beir
from repro.serving.engine import GeneratorModel, RAGEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="fever",
                    choices=list(configs.__dict__.get("_", [])) or
                    ["scidocs", "fiqa", "quora", "nq", "hotpotqa", "fever"])
    ap.add_argument("--arch", default="sheared-llama-2.7b",
                    help="generator architecture (any assigned config id)")
    ap.add_argument("--records", type=int, default=2000)
    ap.add_argument("--queries", type=int, default=40)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--no-generator", action="store_true")
    args = ap.parse_args()

    ds = scaled_beir(args.dataset, n_records=args.records,
                     n_queries=args.queries)
    cost = EdgeCostModel()
    slo = ds.spec.slo_s if ds.spec else 1.0
    index = EdgeRAGIndex(ds.embeddings.shape[1], ds.embedder, ds.get_chunks,
                         cost, slo_s=slo)
    nlist = max(16, ds.n // 32)
    index.build(ds.chunk_ids, ds.texts, nlist=nlist,
                embeddings=ds.embeddings)
    print(f"indexed {ds.n} chunks into {nlist} clusters; "
          f"stats={index.stats()}")

    gen = None
    if not args.no_generator:
        gcfg = configs.get_config(args.arch).reduced()
        gen = GeneratorModel(gcfg)
    engine = RAGEngine(index, gen, cost_model=cost, k=args.k,
                       nprobe=args.nprobe)

    ttfts, walls = [], []
    for qi in range(args.queries):
        resp = engine.answer(f"query-{qi}", ds.query_embs[qi], ds.get_chunks)
        ttfts.append(resp.ttft_edge_s)
        walls.append(resp.ttft_wall_s)
        if qi < 3:
            print(f"q{qi}: retrieved {resp.chunk_ids[:5]}... "
                  f"edge_ttft={resp.ttft_edge_s:.3f}s "
                  f"wall={resp.ttft_wall_s:.3f}s "
                  f"gen_tokens={len(resp.output_tokens)}")
    ttfts = np.asarray(ttfts)
    print(f"\nTTFT edge-sim: mean={ttfts.mean():.3f}s "
          f"p50={np.percentile(ttfts, 50):.3f}s "
          f"p95={np.percentile(ttfts, 95):.3f}s; "
          f"wall mean={np.mean(walls):.3f}s")
    print(f"cache: {index.cache.hits} hits / {index.cache.misses} misses "
          f"(rate {index.cache.hit_rate:.2f}), "
          f"threshold={index.threshold.threshold*1e3:.0f}ms")
    print(f"resident index memory: {index.memory_bytes()/2**20:.1f} MiB; "
          f"storage: {index.storage_bytes()/2**20:.1f} MiB")


if __name__ == "__main__":
    main()
