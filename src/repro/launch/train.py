"""Training launcher.

On the pod this is the entry point behind the train_4k dry-run; on this CPU
container it runs REDUCED configs end to end (synthetic LM data) so the whole
loop — data, sharded train_step, checkpointing — is exercised for real.

  python -m repro.launch.train --arch yi-9b --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import batch_pspec, param_shardings
from repro.models.model import init_params
from repro.train.checkpoint import save_checkpoint
from repro.train.train_step import make_train_step, train_state_init


def synthetic_lm_batch(rng, cfg, batch, seq):
    """Markov-ish synthetic tokens: learnable structure, not pure noise."""
    base = rng.integers(0, cfg.vocab_size, size=(batch, 1))
    drift = rng.integers(-3, 4, size=(batch, seq)).cumsum(axis=1)
    toks = (base + np.abs(drift)) % cfg.vocab_size
    b = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
         "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    if cfg.use_mrope:
        pos = jnp.broadcast_to(jnp.arange(seq - 1)[None], (batch, seq - 1))
        b["positions"] = jnp.broadcast_to(pos[None], (3, batch, seq - 1))
    if cfg.embedding_inputs:
        emb = rng.standard_normal((batch, seq - 1, cfg.d_model)) * 0.02
        b["embeds"] = jnp.asarray(emb, jnp.float32)
        del b["tokens"]
    return b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (pod scale), not the smoke one")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = train_state_init(params)
    step_fn = make_train_step(cfg, peak_lr=args.lr, total_steps=args.steps)
    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        for i in range(args.steps):
            batch = synthetic_lm_batch(rng, cfg, args.batch, args.seq + 1)
            state, metrics = jit_step(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                m = jax.tree.map(float, metrics)
                print(f"step {i:4d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                      f"aux={m['aux']:.4f} |g|={m['grad_norm']:.3f} "
                      f"lr={m['lr']:.2e} ({time.time()-t0:.1f}s)", flush=True)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state.params)
        print(f"saved params -> {args.checkpoint}")
    final = float(metrics["loss"])
    print(f"final loss {final:.4f}")
    return final


if __name__ == "__main__":
    main()
