"""Production mesh definitions (TPU v5e).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the "pod"
axis is pure data parallelism across the DCN boundary.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 CPU).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh for CPU tests/examples (1 device)."""
    n = jax.device_count()
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh ('pod' folds into data)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


# TPU v5e hardware constants (per chip) — used by analysis/roofline.py
V5E_PEAK_BF16_FLOPS = 197e12        # 197 TFLOP/s
V5E_HBM_BW = 819e9                  # 819 GB/s
V5E_ICI_BW = 50e9                   # ~50 GB/s per link
V5E_HBM_BYTES = 16 * 1024**3
