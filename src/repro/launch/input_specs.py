"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation.  What the dry-run lowers against.

Modality carve-out (DESIGN.md §4): audio/vlm frontends are stubs, so
``input_specs`` supplies frame/patch embeddings of the right shape directly.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.cache import init_cache

SDS = jax.ShapeDtypeStruct


def n_vision_patches(shape: InputShape) -> int:
    return min(1024, shape.seq_len // 4)


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.embedding_inputs:                      # audio: codec embeddings
        batch["embeds"] = SDS((b, s, cfg.d_model), compute_dtype)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    batch["labels"] = SDS((b, s), jnp.int32)
    if cfg.use_mrope:
        batch["positions"] = SDS((3, b, s), jnp.int32)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = SDS((b, n_vision_patches(shape),
                                      cfg.d_model), compute_dtype)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape,
                        compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    batch = train_batch_specs(cfg, shape, compute_dtype)
    batch.pop("labels")
    return batch


def cache_specs(cfg: ModelConfig, shape: InputShape, cache_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           window_mode=shape.sliding_window_mode,
                           dtype=cache_dtype))


def decode_token_specs(cfg: ModelConfig, shape: InputShape,
                       compute_dtype=jnp.bfloat16):
    b = shape.global_batch
    if cfg.embedding_inputs:
        return SDS((b, 1, cfg.d_model), compute_dtype)
    return SDS((b, 1), jnp.int32)


def param_specs(cfg: ModelConfig, dtype=jnp.float32):
    from repro.models.model import init_params
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype=dtype), jax.random.PRNGKey(0))


def input_specs(cfg: ModelConfig, shape: InputShape,
                compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """The full kwargs pytree a step function is lowered against."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape, compute_dtype)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape, compute_dtype),
                "caches": cache_specs(cfg, shape)}
    return {"tokens": decode_token_specs(cfg, shape, compute_dtype),
            "caches": cache_specs(cfg, shape),
            "cache_len": SDS((), jnp.int32)}
