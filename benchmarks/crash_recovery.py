"""Crash-recovery benchmark: what snapshot + WAL durability costs at
steady state and what it buys at recovery time (core/durability.py
exercised end to end).

Three questions, one seeded run each:

  1. WAL overhead      the same mixed query/churn stream replayed on two
                       disk-backed indexes that differ ONLY in an attached
                       Durability handle (checkpoint_every=64).  Overhead
                       is the modeled edge seconds the WAL adds (fsyncs +
                       inline snapshots) as a fraction of the baseline
                       stream cost -> steady-state QPS ratio.
  2. recovery speedup  after the churn, the durable index "crashes" (the
                       process object is dropped).  ``recover()`` rebuilds
                       it from newest snapshot + WAL suffix; its modeled
                       edge seconds are compared against the cold path —
                       re-embedding every live chunk from text (the only
                       alternative on an edge device with no durable
                       index).  Cold cost is an UNDERestimate (no k-means,
                       no re-store), so the reported speedup is a floor.
  3. crashpoint arms   one small index per :data:`CRASH_POINTS` boundary,
                       killed at its 2nd occurrence mid-churn, recovered,
                       and checked against independently rebuilt reference
                       states: recovery must land on a clean op-sequence
                       prefix (pre-op or post-op), NEVER a hybrid.

Acceptance (criteria block): post-recovery answers BIT-IDENTICAL to
pre-crash (recall@10 ratio == 1.0 and identical result ids), recovery
>= 5x cheaper than the cold re-embed, WAL steady-state overhead <= 10%,
and zero hybrid states across every crashpoint arm.

``python -m benchmarks.crash_recovery [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import shutil
import tempfile
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import build_churn_ops, emit
from repro.core import (CRASH_POINTS, CrashInjector, Durability,
                        EdgeCostModel, EdgeRAGIndex, SimulatedCrash, recover)
from repro.data import generate_dataset

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_crash_recovery.json")

DIM = 48
K = 10
NPROBE = 6
CHECKPOINT_EVERY = 64


def _fresh_index(ds, cost, root, *, nlist: int, slo_s: float,
                 mode: str = "disk") -> EdgeRAGIndex:
    er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost, slo_s=slo_s,
                      storage_mode=mode, storage_root=root,
                      merge_min_size=2, maintenance="sync")
    er.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=ds.embeddings,
             seed=1)
    return er


def op_edge_s(er, ds, cost, op) -> float:
    """Apply one op; modeled edge seconds (same accounting as the
    fault-tolerance benchmark's serve_op)."""
    if op[0] == "query":
        _, _, lat = er.search(ds.query_embs[op[1]], K, NPROBE)
        return lat.retrieval_s
    if op[0] == "insert":
        er.insert(op[1], op[2])
        return (cost.embed_latency(len(op[2]))
                + cost.search_latency(er.nlist, DIM))
    if op[0] == "update":
        er.update(op[1], op[2])
        return cost.embed_latency(len(op[2]))
    er.remove(op[1])
    return cost.search_latency(er.nlist, DIM)


def recall_at_k(er, ds, live: set) -> float:
    ids, _, _ = er.search_batch(ds.query_embs, K, NPROBE)
    hits = 0
    for qi in range(len(ds.query_embs)):
        hits += len(set(int(i) for i in ids[qi] if i >= 0)
                    & (ds.relevant(qi) & live))
    return hits / (len(ds.query_embs) * K)


def cold_rebuild_edge_s(er, ds, cost) -> float:
    """Modeled edge cost of the durability-free alternative: re-embed
    every live chunk from its text.  Deliberately omits k-means and blob
    re-stores — an UNDERestimate, so speedup claims stay conservative."""
    live = sorted(set(er._chunk_cluster))
    return float(sum(cost.embed_latency(len(t))
                     for t in ds.get_chunks(live)))


# ---------------------------------------------------------------- arms
def run_overhead_and_recovery(ds, ops, cost, *, nlist: int, slo_s: float,
                              quick: bool) -> Dict:
    base_root = tempfile.mkdtemp(prefix="bench_crash_base_")
    wal_root = tempfile.mkdtemp(prefix="bench_crash_wal_")
    try:
        # --- baseline arm: identical stream, no durability
        er = _fresh_index(ds, cost, base_root, nlist=nlist, slo_s=slo_s)
        edge_base = sum(op_edge_s(er, ds, cost, op) for op in ops)
        del er
        gc.collect()

        # --- WAL arm: one Durability handle is the only difference
        er = _fresh_index(ds, cost, wal_root, nlist=nlist, slo_s=slo_s)
        dur = er.attach_durability(Durability(
            wal_root, cost_model=cost, checkpoint_every=CHECKPOINT_EVERY))
        fsync0 = dur.fsync_edge_s_total          # exclude the baseline snap
        edge_wal_ops = sum(op_edge_s(er, ds, cost, op) for op in ops)
        wal_edge_s = dur.fsync_edge_s_total - fsync0
        overhead = wal_edge_s / max(edge_base, 1e-12)
        wal_stats = dur.stats()

        # --- pre-crash ground truth, then the crash
        live = set(er._chunk_cluster)
        pre_ids, pre_vals, _ = er.search_batch(ds.query_embs, K, NPROBE)
        pre_recall = recall_at_k(er, ds, live)
        cold_edge = cold_rebuild_edge_s(er, ds, cost)
        del er, dur
        gc.collect()

        # --- recovery
        er2, report = recover(wal_root, ds.embedder, ds.get_chunks, cost,
                              storage_mode="disk", slo_s=slo_s,
                              maintenance="sync",
                              checkpoint_every=CHECKPOINT_EVERY)
        post_ids, post_vals, _ = er2.search_batch(ds.query_embs, K, NPROBE)
        post_recall = recall_at_k(er2, ds, set(er2._chunk_cluster))
        identical = (np.array_equal(post_ids, pre_ids)
                     and np.array_equal(post_vals, pre_vals))
        speedup = cold_edge / max(report.edge_s, 1e-12)
        del er2
        gc.collect()
        return {
            "n_ops": len(ops),
            "edge_s_baseline": edge_base,
            "edge_s_wal_stream": edge_wal_ops,
            "wal_edge_s": wal_edge_s,
            "wal_overhead_frac": overhead,
            "qps_baseline": len(ops) / edge_base,
            "qps_wal": len(ops) / (edge_base + wal_edge_s),
            "wal_stats": wal_stats,
            "recall_at10_pre_crash": pre_recall,
            "recall_at10_post_recovery": post_recall,
            "recall_ratio": post_recall / max(pre_recall, 1e-12),
            "results_identical": bool(identical),
            "recovery": report.as_dict(),
            "cold_rebuild_edge_s": cold_edge,
            "recovery_speedup_vs_cold": speedup,
        }
    finally:
        shutil.rmtree(base_root, ignore_errors=True)
        shutil.rmtree(wal_root, ignore_errors=True)


def _membership_sig(er) -> Tuple:
    return (
        tuple(sorted(int(i) for c in er.clusters if c.active
                     for i in c.ids)),
        tuple((tuple(int(i) for i in c.ids), c.char_count, c.active)
              for c in er.clusters),
    )


def run_crashpoint_arms(cost, quick: bool) -> Dict[str, Dict]:
    """Kill one small durable index at every crashpoint boundary; recovery
    must land on a clean prefix of the op sequence."""
    ds = generate_dataset(n_records=150, dim=DIM, n_topics=6, n_queries=4,
                          seed=29)
    rng = np.random.default_rng(31)
    ops = build_churn_ops(ds, rng, DIM, n_insert=4, n_remove=3, n_update=2,
                          n_query=0, first_new_id=2_000_000)
    mean_chars = sum(len(t) for t in ds.texts) / 6
    slo_s = cost.embed_latency(int(0.5 * mean_chars))

    # reference: the index after every prefix, rebuilt without crashes
    refs = []
    for j in range(len(ops) + 1):
        er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost, slo_s=slo_s,
                          merge_min_size=2, maintenance="sync")
        er.build(ds.chunk_ids, ds.texts, nlist=6,
                 embeddings=ds.embeddings, seed=1)
        for op in ops[:j]:
            op_edge_s(er, ds, cost, op)
        refs.append(_membership_sig(er))

    arms: Dict[str, Dict] = {}
    for point in CRASH_POINTS:
        root = tempfile.mkdtemp(prefix=f"bench_crash_{point}_")
        try:
            crash = CrashInjector(point, at=2, seed=13)
            er = _fresh_index(ds, cost, root, nlist=6, slo_s=slo_s)
            er.attach_durability(Durability(root, cost_model=cost,
                                            checkpoint_every=3,
                                            crash=crash))
            crashed_at = None
            for j, op in enumerate(ops):
                try:
                    op_edge_s(er, ds, cost, op)
                except SimulatedCrash:
                    crashed_at = j
                    break
            del er
            gc.collect()
            er2, report = recover(root, ds.embedder, ds.get_chunks, cost,
                                  storage_mode="disk", slo_s=slo_s,
                                  maintenance="sync")
            sig = _membership_sig(er2)
            landed = [j for j, s in enumerate(refs) if s == sig]
            hybrid = not landed or (
                crashed_at is not None
                and crashed_at not in landed
                and crashed_at + 1 not in landed)
            arms[point] = {
                "crashed_at_op": crashed_at,
                "landed_prefix": landed[0] if landed else None,
                "hybrid": bool(hybrid),
                "recovery": report.as_dict(),
            }
            del er2
            gc.collect()
        finally:
            shutil.rmtree(root, ignore_errors=True)
        a = arms[point]
        emit(f"crash_recovery.point.{point}",
             a["recovery"]["edge_s"] * 1e6,
             f"crashed_at={a['crashed_at_op']} "
             f"landed={a['landed_prefix']} hybrid={a['hybrid']}")
    return arms


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 400 if quick else 1200
    nq = 16 if quick else 48
    nlist = max(12, n_records // 30)
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(8, n_records // 60),
                          n_queries=nq, seed=19)
    cost = EdgeCostModel()
    mean_cluster_chars = sum(len(t) for t in ds.texts) / nlist
    slo_s = cost.embed_latency(int(0.5 * mean_cluster_chars))
    rng = np.random.default_rng(41)
    # the same ~70% query / 30% churn mix as the fault-tolerance benchmark
    # (only churn ops pay a WAL fsync, so the mix sets the overhead)
    n_churn = int(0.08 * n_records)
    ops = build_churn_ops(ds, rng, DIM, n_insert=n_churn, n_remove=n_churn,
                          n_update=n_churn, n_query=7 * n_churn)

    main = run_overhead_and_recovery(ds, ops, cost, nlist=nlist,
                                     slo_s=slo_s, quick=quick)
    emit("crash_recovery.wal_overhead", main["wal_edge_s"] * 1e6,
         f"overhead={main['wal_overhead_frac']*100:.2f}% "
         f"records={main['wal_stats']['wal_records_total']} "
         f"snaps={main['wal_stats']['snapshots_total']}")
    emit("crash_recovery.recovery", main["recovery"]["edge_s"] * 1e6,
         f"speedup_vs_cold={main['recovery_speedup_vs_cold']:.1f}x "
         f"replayed={main['recovery']['replayed_records']} "
         f"recall_ratio={main['recall_ratio']:.3f}")

    arms = run_crashpoint_arms(cost, quick)

    results = {
        "n_records": n_records, "n_queries": nq, "nlist": nlist,
        "k": K, "nprobe": NPROBE, "slo_s": slo_s,
        "checkpoint_every": CHECKPOINT_EVERY,
        "steady_state": main,
        "crashpoints": arms,
        "criteria": {
            "recall_ratio_one": (main["recall_ratio"] == 1.0
                                 and main["results_identical"]),
            "recovery_speedup_ok": main["recovery_speedup_vs_cold"] >= 5.0,
            "wal_overhead_ok": main["wal_overhead_frac"] <= 0.10,
            "no_hybrid_state": all(not a["hybrid"] for a in arms.values()),
            "all_crashpoints_fired": all(
                a["crashed_at_op"] is not None for a in arms.values()),
        },
    }
    ok = all(results["criteria"].values())
    print(f"# recall ratio 1.0, recovery >= 5x cold re-embed, WAL overhead "
          f"<= 10%, no hybrid crashpoint state: {'PASS' if ok else 'FAIL'}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
