"""Online-churn benchmark: recall + tail TTFT under a mixed
query / insert / remove stream (§5.4 exercised end to end).

One stream (~50% queries, ~25% inserts, ~25% removes, sized so
inserts+removes touch ``churn_frac`` of the corpus; bursty arrivals — BURST
back-to-back ops then a lull, the conversational edge pattern) is replayed
through the RequestScheduler against two arms that share the cost model:

  sync      split / merge / restore run inside the mutating request's
            service time (the seed behavior): a query arriving behind a
            maintenance burst queues for the whole burst
  deferred  mutations enqueue on the MaintenanceScheduler and return at the
            base mutation cost; the queue drains only when the device goes
            IDLE, under a STRICT budget sized to the gap before the next
            known arrival — maintenance yields to waiting requests and ops
            too big for the current gap wait for a deeper idle period

The arrival rate is CALIBRATED: a throwaway index replays a slice of the
stream to measure realized churn-time service (queries regenerate clusters
the churn keeps invalidating, so warm-cache service would undershoot), and
the mean arrival gap is set for ``TARGET_UTILIZATION`` including
maintenance.  The queueing regime is therefore scale-invariant: the arms
differ only in WHERE the same maintenance seconds land.

Reported per arm: p50 / p99 / mean TTFT of the query requests
(arrival → first token, queueing included; decode excluded as in the
paper's headline metric).  After the stream both arms hold the same live
corpus; recall@10 of the churned index is compared against an ORACLE index
rebuilt from scratch on the surviving corpus.

Acceptance: recall ratio >= 0.99 after a 30%-churn stream, and deferred
maintenance beats synchronous on p99 TTFT.

Appends to the BENCH trajectory as ``BENCH_online_churn.json``.

``python -m benchmarks.online_churn [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import build_churn_ops, bursty_arrival_times, emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset
from repro.serving.scheduler import RequestScheduler

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_online_churn.json")

DIM = 48
K = 10
NPROBE = 6
PROMPT_TOKENS = 32
CHURN_FRAC = 0.30
TARGET_UTILIZATION = 0.65   # arrival rate vs realized churn-time service
CALIBRATION_FRAC = 0.4      # stream slice replayed to calibrate the gap
BURST = 6                   # ops per arrival burst (conversational traffic)
BURST_GAP_FRAC = 0.1        # intra-burst gap as a fraction of the mean gap


def build_ops(ds, rng, churn_frac: float) -> List[Tuple]:
    """Op payloads (no timestamps yet) via the shared seeded generator
    (benchmarks/common.py); inserts are registered on ``ds`` up front so
    calibration and both arms replay the identical stream."""
    n_ins = n_rem = int(churn_frac * ds.n / 2)
    return build_churn_ops(ds, rng, DIM, n_insert=n_ins, n_remove=n_rem,
                           n_query=n_ins + n_rem)


def _fresh_index(ds, cost, *, nlist: int, slo_s: float,
                 split_max_chars: int) -> EdgeRAGIndex:
    er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost, slo_s=slo_s,
                      split_max_chars=split_max_chars, merge_min_size=2,
                      maintenance="deferred")
    er.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=ds.embeddings,
             seed=1)
    # warm the cache/threshold so cold-start regeneration isn't measured
    for qi in range(len(ds.query_embs)):
        er.search(ds.query_embs[qi], K, NPROBE)
    return er


def serve_op(er, ds, cost, op) -> float:
    """Apply one op; returns its base edge service time (no maintenance)."""
    if op[0] == "query":
        _, _, lat = er.search(ds.query_embs[op[1]], K, NPROBE,
                              query_chars=int(ds.query_chars[op[1]]))
        return lat.retrieval_s + cost.prefill_latency(PROMPT_TOKENS)
    if op[0] == "insert":
        er.insert(op[1], op[2])
        return (cost.embed_latency(len(op[2]))
                + cost.search_latency(er.nlist, DIM))
    er.remove(op[1])
    return cost.search_latency(er.nlist, DIM)


def calibrate_gap(ds, ops, cost, **index_kw) -> float:
    """Mean realized service (base + maintenance) over a stream slice,
    scaled to TARGET_UTILIZATION.  Uses a throwaway index so the measured
    arms start from identical state."""
    cal = _fresh_index(ds, cost, **index_kw)
    cut = ops[:max(1, int(len(ops) * CALIBRATION_FRAC))]
    total = 0.0
    for op in cut:
        total += serve_op(cal, ds, cost, op)
        total += cal.maintenance.drain(None).edge_s
    return (total / len(cut)) / TARGET_UTILIZATION


def run_arm(ds, stream, mode: str, cost, **index_kw
            ) -> Tuple[EdgeRAGIndex, Dict]:
    """Replay the stream; both arms use a deferred-queue index and differ
    only in WHERE the maintenance seconds land (inside the mutating request
    vs idle-gap drains)."""
    er = _fresh_index(ds, cost, **index_kw)
    sched = RequestScheduler()
    op_of = {}
    for t, op in stream:
        op_of[sched.submit(t).rid] = op

    def serve(req) -> float:
        service = serve_op(er, ds, cost, op_of[req.rid])
        if mode == "sync":
            # the seed behavior: the mutation pays its whole maintenance
            # cascade before the next request is admitted
            service += er.maintenance.drain(None).edge_s
        return service

    def idle_drain(gap_s):
        # size the drain to the idle gap; with no more arrivals, quiesce
        if gap_s is None:
            return er.maintenance.drain(None).edge_s
        return er.maintenance.drain(gap_s, strict=True).edge_s

    maintenance_fn = None if mode == "sync" else idle_drain
    sched.run(serve, maintenance_fn=maintenance_fn)
    er.maintenance.drain(None)          # quiesce before recall measurement
    ttfts = np.array([r.latency_s for r in sched.completed
                      if op_of[r.rid][0] == "query"])
    return er, {
        "n_query_reqs": int(len(ttfts)),
        "p50_ttft_s": float(np.percentile(ttfts, 50)),
        "p99_ttft_s": float(np.percentile(ttfts, 99)),
        "mean_ttft_s": float(ttfts.mean()),
        "maintenance_edge_s": er.maintenance.total_edge_s,
        "maintenance_in_stream_s": sched.maintenance_s,
        "maintenance_ops": er.maintenance.n_executed,
    }


def recall_at_k(er, ds, live: set, nprobe: int) -> float:
    hits = 0
    for qi in range(len(ds.query_embs)):
        ids, _, _ = er.search(ds.query_embs[qi], K, nprobe)
        hits += len(set(int(i) for i in ids[0] if i >= 0)
                    & (ds.relevant(qi) & live))
    return hits / (len(ds.query_embs) * K)


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 800 if quick else 2400
    nq = 32 if quick else 96
    nlist = max(16, n_records // 30)
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(12, n_records // 60),
                          n_queries=nq, seed=17)
    cost = EdgeCostModel()
    # slo / split chosen so the stream exercises restores AND split cascades
    mean_cluster_chars = sum(len(t) for t in ds.texts) / nlist
    slo_s = cost.embed_latency(int(1.5 * mean_cluster_chars))
    split_max_chars = int(2.0 * mean_cluster_chars)
    index_kw = dict(nlist=nlist, slo_s=slo_s,
                    split_max_chars=split_max_chars)
    rng = np.random.default_rng(23)
    ops = build_ops(ds, rng, CHURN_FRAC)
    gap_mean_s = calibrate_gap(ds, ops, cost, **index_kw)
    # bursty arrivals at the same mean rate: BURST back-to-back ops, then a
    # lull — the conversational edge pattern.  Sync maintenance lands
    # inside bursts (queries queue behind it); deferred maintenance drains
    # in the lulls.
    times = bursty_arrival_times(rng, len(ops), gap_mean_s, burst=BURST,
                                 burst_gap_frac=BURST_GAP_FRAC)
    stream = list(zip(times, ops))
    emit("online_churn.calibration", gap_mean_s * 1e6,
         f"gap={gap_mean_s*1e3:.1f}ms target_util={TARGET_UTILIZATION}")

    arms: Dict[str, Dict] = {}
    churned = None
    for mode in ("sync", "deferred"):
        er, cell = run_arm(ds, stream, mode, cost, **index_kw)
        arms[mode] = cell
        churned = er        # identical live corpus either arm
        emit(f"online_churn.{mode}", cell["p99_ttft_s"] * 1e6,
             f"p50={cell['p50_ttft_s']*1e3:.1f}ms "
             f"p99={cell['p99_ttft_s']*1e3:.1f}ms "
             f"maint={cell['maintenance_edge_s']:.2f}s")

    live = set(churned._chunk_cluster)
    oracle = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost,
                          slo_s=slo_s, split_max_chars=split_max_chars,
                          merge_min_size=2)
    live_sorted = sorted(live)
    oracle.build(live_sorted, ds.get_chunks(live_sorted), nlist=nlist,
                 embeddings=np.stack([ds.embedder.table[i]
                                      for i in live_sorted]), seed=1)
    # recall probes more broadly than the serving path: the criterion
    # grades index-structure quality after churn, not serving nprobe
    recall_nprobe = max(NPROBE, int(0.6 * nlist))
    r_churned = recall_at_k(churned, ds, live, recall_nprobe)
    r_oracle = recall_at_k(oracle, ds, live, recall_nprobe)
    ratio = r_churned / max(r_oracle, 1e-12)
    emit("online_churn.recall", ratio * 1e6,
         f"churned@10={r_churned:.3f} oracle@10={r_oracle:.3f} "
         f"ratio={ratio:.3f}")

    n_ins = sum(1 for op in ops if op[0] == "insert")
    n_rem = sum(1 for op in ops if op[0] == "remove")
    results = {
        "n_records": n_records, "n_queries": nq, "nlist": nlist,
        "k": K, "nprobe": NPROBE, "slo_s": slo_s,
        "split_max_chars": split_max_chars, "gap_mean_s": gap_mean_s,
        "churn": {"inserts": n_ins, "removes": n_rem,
                  "churn_frac": CHURN_FRAC},
        "recall": {"churned_at10": r_churned, "oracle_at10": r_oracle,
                   "ratio": ratio},
        "arms": arms,
        "p99_speedup_sync_over_deferred":
            arms["sync"]["p99_ttft_s"] / arms["deferred"]["p99_ttft_s"],
        "criteria": {
            "recall_ratio_ok": ratio >= 0.99,
            "deferred_p99_lower":
                arms["deferred"]["p99_ttft_s"] < arms["sync"]["p99_ttft_s"],
        },
    }
    ok = all(results["criteria"].values())
    print(f"# recall ratio >= 0.99 and deferred p99 < sync p99: "
          f"{'PASS' if ok else 'FAIL'}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
