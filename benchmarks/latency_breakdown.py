"""Fig. 3 analogue: RAG latency breakdown (retrieval / prefill) and embedded
database size per BEIR dataset, Flat vs IVF, at paper scale via the edge
cost model."""
from __future__ import annotations

from benchmarks.common import emit
from repro.data.synthetic import BEIR_SPECS
from repro.serving.simulator import EdgeSimulator


def run(n_queries: int = 200):
    for ds, spec in BEIR_SPECS.items():
        sim = EdgeSimulator(ds, n_queries=n_queries)
        for cfg in ("flat", "ivf"):
            r = sim.run(cfg)
            prefill = r.mean_ttft_s - r.mean_retrieval_s
            emit(f"fig3/{ds}/{cfg}/retrieval_s", r.mean_retrieval_s * 1e6,
                 f"prefill_s={prefill:.3f};ttft_s={r.mean_ttft_s:.3f};"
                 f"db_gib={spec.emb_bytes/2**30:.2f};"
                 f"fits={spec.fits_in_memory}")


if __name__ == "__main__":
    run()
