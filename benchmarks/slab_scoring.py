"""Packed-slab batch scoring engine vs the per-query concat + top-k loop.

Isolates the second-level SCORING stage (the latency-dominant step once
embeddings are resolved): the batch's clusters are resolved once, then each
arm repeatedly does one batch's worth of scoring work —

  per_query_loop   the pre-slab path: per query, concatenate its probed
                   clusters (shared clusters copied once PER QUERY) and
                   launch its own top-k
  slab_fp32        pack each unique cluster ONCE into the slab, build the
                   per-(query, row) membership/virtual-index matrix, ONE
                   ragged multi-query launch for the whole batch
  dequant_int8     int8 storage payloads dequantized to a materialized
                   fp32 copy first (the old decode-on-load), then slab-
                   scored — isolates what fusing the decode buys
  slab_int8_fused  int8 slabs scored directly: per-row scales applied to
                   the score block inside the kernel, no fp32 copy

Acceptance (checked here and re-checked by scripts/ci.sh bench-smoke):
batch-16 slab scoring >= 2x the per-query loop's throughput at nprobe 8
(>= 1x required in the quick CI smoke), int8 fused beating
dequant-then-score, and slab/loop recall@10 ratio >= 0.99 (the fp32 slab
is bitwise identical, so the ratio is exactly 1.0 — asserted).

Appends to the BENCH trajectory as ``BENCH_slab_scoring.json``.

``python -m benchmarks.slab_scoring [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.costs import LatencyBreakdown
from repro.core.resolver import SlabLayout, SlabPayload
from repro.data import generate_dataset
from repro.kernels.ivf_topk.ops import topk_ip
from repro.kernels.slab_topk.ops import slab_topk
from repro.models.quantization import dequantize_rows

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_slab_scoring.json")

# d=128: wide enough that the decode/copy traffic the slab engine removes
# (the term fused dequant targets) dominates the fixed per-launch overhead
DIM = 128
K = 10
NPROBE = 8
BATCH = 16


def _resolve(er, queries, nprobe):
    """Plan + RAW execute once; scoring arms replay from these payloads."""
    plan = er.resolver.plan(er._probe(queries, nprobe))
    lats = [LatencyBreakdown() for _ in range(queries.shape[0])]
    payloads = er.resolver.execute(plan, lats, [False] * len(lats),
                                   raw=True)
    return plan, payloads


def _score_loop(er, plan, decoded, queries, k):
    """The pre-slab scoring stage: per query concat + own top-k launch."""
    nq = queries.shape[0]
    out_ids = np.full((nq, k), -1, np.int64)
    out_vals = np.full((nq, k), -np.inf, np.float32)
    for qi, probed in enumerate(plan.probed_per_q):
        if not probed:
            continue
        embs = np.concatenate([decoded[c] for c in probed])
        idmap = np.concatenate([er.clusters[c].ids for c in probed])
        if len(embs) == 0:
            continue
        vals, idx = topk_ip(embs, queries[qi:qi + 1], k)
        vals, idx = np.asarray(vals)[0], np.asarray(idx)[0]
        ok = idx >= 0
        out_vals[qi] = np.where(ok, vals, -np.inf)
        out_ids[qi] = np.where(ok, idmap[np.where(ok, idx, 0)], -1)
    return out_ids, out_vals


def _score_slab(er, plan, payloads, queries, k):
    """The slab engine's scoring stage: pack once + one launch/segment."""
    nq = queries.shape[0]
    slab = SlabLayout.pack(er.dim, list(plan.owner), payloads,
                           lambda cid: er.clusters[cid].ids)
    virts, n_valid, n_valid_seg = slab.query_layout(plan.probed_per_q)
    out_ids = np.full((nq, k), -1, np.int64)
    out_vals = np.full((nq, k), -np.inf, np.float32)
    lane = np.arange(k)[None, :]
    # single representation per run here — the lane-overwrite below is only
    # correct for one segment (the engine's lexsort merge handles mixes)
    assert len(slab.segments) == 1, [s.kind for s in slab.segments]
    for seg in slab.segments:
        vals, rows = slab_topk(seg.emb, queries, virts[seg.kind], k,
                               scales=seg.scales)
        vals, rows = np.asarray(vals), np.asarray(rows)
        valid = lane < n_valid_seg[seg.kind][:, None]
        rows = np.where(valid, rows, 0)
        out_ids = np.where(valid, seg.ids[rows], out_ids)
        out_vals = np.where(valid, vals, out_vals)
    return out_ids, out_vals


def _time_pair(fn_a, fn_b, repeats):
    """Median seconds of two arms measured INTERLEAVED (A, B, A, B, ...).

    The arm comparison feeds a CI regression guard, so the measurement must
    survive noisy boxes: interleaving cancels slow drift (thermal, page
    cache, competing load) that back-to-back blocks would attribute to
    whichever arm ran second, and the median discards scheduler spikes.
    """
    fn_a(), fn_b(), fn_a(), fn_b()     # warm the jit caches
    sa, sb = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out_a = fn_a()
        t1 = time.perf_counter()
        out_b = fn_b()
        sa.append(t1 - t0)
        sb.append(time.perf_counter() - t1)
    return float(np.median(sa)), out_a, float(np.median(sb)), out_b


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 1500 if quick else 4000
    repeats = 8 if quick else 30
    # few, heavy clusters — EdgeRAG's regime (same choice as
    # quantized_tiers): concurrent Zipf queries then share most of their
    # probe sets, which is exactly what slab packing exploits
    nlist = max(16, n_records // 250)
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(16, n_records // 60),
                          n_queries=BATCH, seed=13)
    queries = ds.query_embs[:BATCH]
    cost = EdgeCostModel()

    def build(codec):
        er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost,
                          slo_s=1e-6, store_heavy=True, cache_bytes=0,
                          storage_codec=codec)
        er.build(ds.chunk_ids, ds.texts, nlist=nlist,
                 embeddings=ds.embeddings, seed=1)
        return er

    results: Dict = {"n_records": n_records, "dim": DIM, "nlist": nlist,
                     "k": K, "nprobe": NPROBE, "batch": BATCH,
                     "repeats": repeats, "arms": {}}

    # ---- fp32: slab engine vs per-query loop --------------------------
    er = build("fp32")
    plan, payloads = _resolve(er, queries, NPROBE)
    decoded = {cid: p.emb for cid, p in payloads.items()}
    uniq_rows = sum(p.rows for p in payloads.values())
    concat_rows = sum(er.clusters[c].size
                     for probed in plan.probed_per_q for c in probed)
    results["unique_rows"] = uniq_rows
    results["per_query_concat_rows"] = concat_rows
    results["dedup_factor"] = concat_rows / max(uniq_rows, 1)

    t_loop, (l_ids, _), t_slab, (s_ids, _) = _time_pair(
        lambda: _score_loop(er, plan, decoded, queries, K),
        lambda: _score_slab(er, plan, payloads, queries, K), repeats)
    assert np.array_equal(l_ids, s_ids), \
        "fp32 slab scoring diverged from the per-query loop"

    # ---- int8: fused in-kernel dequant vs dequant-then-score ----------
    er8 = build("int8")
    plan8, payloads8 = _resolve(er8, queries, NPROBE)

    def dequant_then_score():
        fp32 = {cid: SlabPayload("fp32",
                                 dequantize_rows(p.emb, p.scales)
                                 if p.kind == "int8" else p.emb)
                for cid, p in payloads8.items()}
        return _score_slab(er8, plan8, fp32, queries, K)

    t_deq, (d_ids, _), t_fused, (f_ids, _) = _time_pair(
        dequant_then_score,
        lambda: _score_slab(er8, plan8, payloads8, queries, K), repeats)

    def recall(ids):
        hits = sum(len(set(ids[qi].tolist()) & ds.relevant(qi))
                   for qi in range(BATCH))
        return hits / (BATCH * K)

    for name, secs, ids in [("per_query_loop", t_loop, l_ids),
                            ("slab_fp32", t_slab, s_ids),
                            ("dequant_int8", t_deq, d_ids),
                            ("slab_int8_fused", t_fused, f_ids)]:
        results["arms"][name] = {"scoring_s_per_batch": secs,
                                 "qps": BATCH / secs,
                                 "recall_at10": recall(ids)}
        emit(f"slab_scoring.{name}", secs * 1e6,
             f"qps={BATCH / secs:.0f} recall@10={recall(ids):.3f}")

    arms = results["arms"]
    results["speedups"] = {
        "slab_vs_loop_batch16": t_loop / t_slab,
        "int8_fused_vs_dequant": t_deq / t_fused,
    }
    results["recall"] = {
        "loop_at10": arms["per_query_loop"]["recall_at10"],
        "slab_at10": arms["slab_fp32"]["recall_at10"],
        "ratio": (arms["slab_fp32"]["recall_at10"]
                  / max(arms["per_query_loop"]["recall_at10"], 1e-12)),
    }
    results["criteria"] = {
        # quick CI smoke guards >= 1x (no regression); the full run's 2x
        # target is recorded alongside for the repo-root JSON
        "slab_not_slower": results["speedups"]["slab_vs_loop_batch16"] >= 1.0,
        "slab_2x": results["speedups"]["slab_vs_loop_batch16"] >= 2.0,
        "int8_fused_ok": results["speedups"]["int8_fused_vs_dequant"] > 1.0,
        "recall_ratio_ok": results["recall"]["ratio"] >= 0.99,
    }
    print(f"# slab batch-16 speedup {results['speedups']['slab_vs_loop_batch16']:.2f}x "
          f"(2x target: {'PASS' if results['criteria']['slab_2x'] else 'FAIL'}); "
          f"int8 fused vs dequant {results['speedups']['int8_fused_vs_dequant']:.2f}x "
          f"({'PASS' if results['criteria']['int8_fused_ok'] else 'FAIL'}); "
          f"recall ratio {results['recall']['ratio']:.3f} "
          f"({'PASS' if results['criteria']['recall_ratio_ok'] else 'FAIL'})")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
