# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  ``python -m benchmarks.run [--only fig13] [--quick]``
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (batched_retrieval, embed_gen_rate,
                        gen_cost_distribution, generation_quality, kernels,
                        latency_breakdown, quantized_tiers, retrieval_quality,
                        roofline_table, tail_latency, threshold_sweep, ttft)

SUITES = {
    "fig3_latency_breakdown": latency_breakdown.run,
    "fig4_embed_gen_rate": embed_gen_rate.run,
    "fig5_gen_cost_distribution": gen_cost_distribution.run,
    "fig7_threshold_sweep": threshold_sweep.run,
    "fig10_retrieval_quality": retrieval_quality.run,
    "fig11_generation_quality": generation_quality.run,
    "fig12_tail_latency": tail_latency.run,
    "fig13_ttft": ttft.run,
    "kernels": kernels.run,
    "roofline": roofline_table.run,
    # batched fast path; also writes BENCH_retrieval.json at the repo root
    # (batch-1 vs batched QPS, dedup rate, embed calls) so the perf
    # trajectory is tracked across PRs
    "batched_retrieval": batched_retrieval.run,
    # storage codec sweep; writes BENCH_quantized_tiers.json (recall@10 +
    # edge TTFT + byte reduction per fp32/fp16/int8 storage tier)
    "quantized_tiers": quantized_tiers.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="substring filter over suite names")
    args = ap.parse_args()
    failures = []
    for name, fn in SUITES.items():
        if args.only and args.only not in name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
