"""Fig. 7 analogue: retrieval latency and cache hit rate as a function of a
FIXED Minimum Latency Caching Threshold (fever-like workload), plus the
adaptive (Alg. 3) controller's operating point.

The paper's story: threshold 0 caches everything (low hit value, capacity
churn); very high thresholds cache nothing; the sweet spot is in between —
the adaptive controller should land near it."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data.synthetic import scaled_beir


def _run_with_threshold(ds, cost, fixed_thr=None, cache_bytes=96 << 10,
                        n_queries=250):
    er = EdgeRAGIndex(ds.embeddings.shape[1], ds.embedder, ds.get_chunks,
                      cost, slo_s=1.5, cache_bytes=cache_bytes)
    er.build(ds.chunk_ids, ds.texts, nlist=max(64, ds.n // 32),
             embeddings=ds.embeddings)
    if fixed_thr is not None:
        # pin Alg. 3: fixed threshold, controller disabled
        er.threshold.threshold = fixed_thr
        er.threshold.step_s = 0.0
    lats = []
    for qi in range(min(n_queries, len(ds.query_embs))):
        _, _, lat = er.search(ds.query_embs[qi], 10, 8)
        lats.append(lat.retrieval_s)
    return float(np.mean(lats)), er.cache.hit_rate, er.threshold.threshold


def run():
    ds = scaled_beir("fever", n_records=3000, n_queries=250)
    cost = EdgeCostModel()
    for thr_ms in (0, 20, 50, 100, 200, 500, 1000):
        mean_s, hit, _ = _run_with_threshold(ds, cost, thr_ms / 1e3)
        emit(f"fig7/fever/thr_{thr_ms}ms/retrieval_s", mean_s * 1e6,
             f"cache_hit_rate={hit:.3f}")
    mean_s, hit, thr = _run_with_threshold(ds, cost, None)
    emit("fig7/fever/adaptive/retrieval_s", mean_s * 1e6,
         f"cache_hit_rate={hit:.3f};landed_thr_ms={thr*1e3:.0f}")


if __name__ == "__main__":
    run()
