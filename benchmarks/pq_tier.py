"""Disk-native PQ memmap tier: serve a corpus that exceeds the memory budget.

The 100M-vector story in miniature: the cost model's resident-index budget is
shrunk below the corpus's fp32 footprint, so an in-memory fp32 tier cannot
hold the embeddings without thrashing.  Three arms build the same corpus:

  * ``fp32``  — exact baseline, in-memory payloads (over budget by design);
  * ``int8``  — dense quantized tier, in-memory payloads;
  * ``pq``    — product-quantized codes in ``mode="memmap"`` storage: disk
    payloads are ``np.memmap`` views that never fully load, and slab scoring
    runs over per-query ADC LUTs instead of dequantized rows.

Measured per arm: recall@10 vs ground-truth topics (+ ratio to fp32),
retrieved-id overlap with fp32, storage bytes + reduction vs the fp32
footprint, edge TTFT, and storage-load counts.  The PQ arm must keep
recall@10 >= 0.95 of fp32 while storing >= 8x fewer bytes — small enough to
fit the very budget the fp32 corpus blows through.

Appends the grid to the BENCH trajectory as ``BENCH_pq_tier.json``.

``python -m benchmarks.pq_tier [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
from typing import Dict

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.core.storage import StorageBackend
from repro.data import generate_dataset

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_pq_tier.json")

DIM = 64
K = 10
NPROBE = 6
PQ_M = 24           # 3-dim subspaces: 24 B/row of codes vs 256 B fp32
PROMPT_TOKENS = 32
ARMS = ("fp32", "int8", "pq")


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 1200 if quick else 3000
    nq = 32 if quick else 96
    nlist = max(8, n_records // 250)          # few, heavy clusters
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(16, n_records // 60),
                          n_queries=nq, seed=11)
    corpus_fp32 = n_records * DIM * 4
    # Resident-index budget BELOW the corpus's fp32 footprint: the dense
    # tiers are over budget, the PQ tier must fit.  (model_reserved eats all
    # of device_memory except the slice we grant the index.)
    budget = int(0.6 * corpus_fp32)
    cost = EdgeCostModel(device_memory_bytes=6.0e9 + budget,
                         model_reserved_bytes=6.0e9,
                         storage_seq_bw_bytes_per_sec=2e6,
                         storage_seek_s=0.002)
    assert corpus_fp32 > cost.index_memory_budget
    results: Dict = {
        "n_records": n_records, "n_queries": nq, "nlist": nlist, "k": K,
        "pq_m": PQ_M,
        "corpus_fp32_bytes": corpus_fp32,
        "index_memory_budget_bytes": cost.index_memory_budget,
        "corpus_exceeds_budget": corpus_fp32 > cost.index_memory_budget,
        "arms": {},
    }
    ids_by_arm: Dict[str, np.ndarray] = {}
    tmp = tempfile.mkdtemp(prefix="bench_pq_tier_")
    try:
        for arm in ARMS:
            if arm == "pq":
                storage = StorageBackend("memmap", root=os.path.join(tmp, arm),
                                         codec="pq", pq_m=PQ_M)
            else:
                storage = StorageBackend("memory", codec=arm)
            # tiny SLO + no cache: every search exercises the storage tier
            er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost,
                              slo_s=1e-6, store_heavy=True, cache_bytes=0,
                              storage=storage)
            er.build(ds.chunk_ids, ds.texts, nlist=nlist,
                     embeddings=ds.embeddings, seed=1)
            ids_rows, lats = [], []
            for qi in range(nq):
                row, _, lat = er.search(ds.query_embs[qi], K, NPROBE)
                ids_rows.append(row[0])
                lats.append(lat)
            ids = np.stack(ids_rows)
            ids_by_arm[arm] = ids
            hits = sum(len(set(ids[qi].tolist()) & ds.relevant(qi))
                       for qi in range(nq))
            st = er.stats()
            assert st["stored_clusters"] == st["active_clusters"]
            results["arms"][arm] = {
                "mode": er.storage.mode,
                "recall_at10": hits / (nq * K),
                "ttft_edge_s": float(np.mean(
                    [l.retrieval_s + cost.prefill_latency(PROMPT_TOKENS)
                     for l in lats])),
                "storage_bytes": st["storage_bytes"],
                "reduction_vs_fp32": corpus_fp32 / st["storage_bytes"],
                "fits_budget": st["storage_bytes"] <= cost.index_memory_budget,
                "n_storage_loads": sum(l.n_storage_loads for l in lats),
                "pq_lut_s": float(sum(l.l2_pq_lut_s for l in lats)),
                "pq_gather_s": float(sum(l.l2_pq_gather_s for l in lats)),
            }
        fp32 = results["arms"]["fp32"]
        for arm in ARMS:
            cell = results["arms"][arm]
            cell["recall_ratio_vs_fp32"] = (cell["recall_at10"]
                                            / max(fp32["recall_at10"], 1e-12))
            cell["id_overlap_vs_fp32"] = float(np.mean([
                len(set(ids_by_arm[arm][qi].tolist())
                    & set(ids_by_arm["fp32"][qi].tolist())) / K
                for qi in range(nq)]))
            emit(f"pq_tier.{arm}", cell["ttft_edge_s"] * 1e6,
                 f"recall@10={cell['recall_at10']:.3f} "
                 f"ratio={cell['recall_ratio_vs_fp32']:.3f} "
                 f"reduction={cell['reduction_vs_fp32']:.2f}x "
                 f"loads={cell['n_storage_loads']}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    pq = results["arms"]["pq"]
    results["criteria"] = {
        "recall_ratio_ge_0p95": pq["recall_ratio_vs_fp32"] >= 0.95,
        "reduction_ge_8x": pq["reduction_vs_fp32"] >= 8.0,
        "pq_smaller_than_int8": (pq["storage_bytes"]
                                 < results["arms"]["int8"]["storage_bytes"]),
        "pq_fits_budget": pq["fits_budget"],
        "served_from_storage": pq["n_storage_loads"] > 0,
    }
    ok = all(results["criteria"].values())
    results["criteria_met"] = ok
    print(f"# pq memmap tier criteria: {'PASS' if ok else 'FAIL'} "
          f"{results['criteria']}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
