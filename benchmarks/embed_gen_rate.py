"""Fig. 4 analogue: embedding-generation vs storage-load latency across
cluster sizes; reports the break-even point (paper: ~24 kchars ≈ 8 ktokens).

Also measures the REAL embedder wall time on this machine across cluster
sizes (relative curve), plus the v5e-adapted break-even from roofline
constants (DESIGN.md assumption change #2).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.costs import BYTES_PER_EMBEDDING_F32, EdgeCostModel
from repro.data.embedder import HashingEmbedder
from repro.launch.mesh import V5E_HBM_BW, V5E_PEAK_BF16_FLOPS


def run():
    cost = EdgeCostModel()
    chunk_chars = 300
    breakeven = None
    for n_chars in (1_000, 3_000, 8_000, 16_000, 24_000, 48_000, 96_000,
                    200_000):
        n_chunks = max(1, n_chars // chunk_chars)
        nbytes = n_chunks * BYTES_PER_EMBEDDING_F32
        gen_s = cost.embed_latency(n_chars)
        # Fig. 4's load side: scattered per-chunk reads of an IVF layout
        load_s = cost.storage_seek_s + nbytes / cost.storage_rand_bw_bytes_per_sec
        if breakeven is None and gen_s < load_s:
            pass
        emit(f"fig4/cluster_{n_chars}chars/gen_s", gen_s * 1e6,
             f"load_s={load_s:.4f};gen_faster={gen_s < load_s}")
    # break-even char count where gen == load
    # gen = fixed + c/rate ; load = seek + c/chunk*3072/bw
    per_char_load = BYTES_PER_EMBEDDING_F32 / chunk_chars / cost.storage_rand_bw_bytes_per_sec
    per_char_gen = 1.0 / cost.embed_chars_per_sec
    c_star = (cost.embed_fixed_s - cost.storage_seek_s) / (per_char_load - per_char_gen)
    emit("fig4/breakeven_chars", 0.0,
         f"chars={c_star:.0f};paper=24000;"
         f"tokens={c_star/3:.0f};paper_tokens=8000")

    # real embedder wall-time curve (relative shape on this CPU)
    emb = HashingEmbedder(dim=64)
    for n_chunks in (4, 16, 64):
        texts = ["x" * chunk_chars] * n_chunks
        us = time_fn(lambda: emb.embed(texts), iters=3)
        emit(f"fig4/real_embed_{n_chunks}chunks", us,
             f"chars={n_chunks*chunk_chars}")

    # TPU v5e adaptation: gen is compute-bound (2*N flops/token on MXU),
    # "load" is host->HBM DMA at PCIe ~ 8 GB/s per host
    gte_flops_per_token = 2 * 137e6
    v5e_gen_per_chunk = 75 * gte_flops_per_token / V5E_PEAK_BF16_FLOPS
    pcie_load_per_chunk = BYTES_PER_EMBEDDING_F32 / 8e9
    emit("fig4/v5e_gen_vs_hostload_per_chunk_us",
         v5e_gen_per_chunk * 1e6,
         f"host_load_us={pcie_load_per_chunk*1e6:.3f};"
         f"gen_faster={v5e_gen_per_chunk < pcie_load_per_chunk}")


if __name__ == "__main__":
    run()
