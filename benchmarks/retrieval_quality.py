"""Fig. 10 analogue: precision / recall of the two-level index vs the Flat
baseline across datasets, with the §6.2 hyperparameter tuning (nprobe and k
chosen to normalize recall against Flat)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, FlatIndex, IVFIndex
from repro.data.synthetic import scaled_beir

DATASETS = ("scidocs", "fiqa", "quora", "nq", "hotpotqa", "fever")


def pr_at_k(ds, ids, qi, k):
    rel = ds.relevant(qi)
    got = [i for i in ids[:k] if i >= 0]
    tp = sum(1 for i in got if i in rel)
    precision = tp / max(len(got), 1)
    recall = tp / max(min(len(rel), k), 1)
    return precision, recall


def run(n_records: int = 2000, n_queries: int = 60, k: int = 10):
    for name in DATASETS:
        ds = scaled_beir(name, n_records=n_records, n_queries=n_queries)
        cost = EdgeCostModel()
        flat = FlatIndex(ds.embeddings.shape[1], cost)
        flat.add(ds.embeddings, ds.chunk_ids)
        ivf = IVFIndex(ds.embeddings.shape[1], cost)
        nlist = max(32, ds.n // 32)
        ivf.build(ds.embeddings, ds.chunk_ids, nlist=nlist)

        # §6.2: tune nprobe to normalize recall-vs-flat
        flat_ids = [flat.search(ds.query_embs[qi], k)[0][0]
                    for qi in range(n_queries)]
        chosen = None
        for nprobe in (1, 2, 4, 8, 16, 32, nlist):
            overlap = np.mean([
                len(set(flat_ids[qi].tolist())
                    & set(ivf.search(ds.query_embs[qi], k, nprobe)[0][0]
                          .tolist())) / k
                for qi in range(n_queries)])
            chosen = nprobe
            if overlap >= 0.95:
                break
        stats = {"flat": [], "ivf": []}
        for qi in range(n_queries):
            pf, rf = pr_at_k(ds, flat_ids[qi].tolist(), qi, k)
            ii = ivf.search(ds.query_embs[qi], k, chosen)[0][0].tolist()
            pi_, ri = pr_at_k(ds, ii, qi, k)
            stats["flat"].append((pf, rf))
            stats["ivf"].append((pi_, ri))
        for cfg, vals in stats.items():
            p = np.mean([v[0] for v in vals])
            r = np.mean([v[1] for v in vals])
            emit(f"fig10/{name}/{cfg}", 0.0,
                 f"precision={p:.3f};recall={r:.3f};nprobe={chosen};"
                 f"recall_vs_flat_gap={abs(r - np.mean([v[1] for v in stats['flat']])):.3f}")


if __name__ == "__main__":
    run()
