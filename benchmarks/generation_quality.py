"""Fig. 11 proxy: generation quality.

GPT-4o-as-judge can't run offline (documented limitation, DESIGN.md §7).
Proxy: context overlap — the fraction of the Flat baseline's retrieved
context recovered by the EdgeRAG/IVF pipeline at the tuned operating point.
The paper's own observation (§6.3.2) is that generation quality tracks
recall, and EdgeRAG retrieval ≡ IVF retrieval, so overlap-vs-flat is the
quality-relevant quantity we CAN measure."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex, FlatIndex
from repro.data.synthetic import scaled_beir

DATASETS = ("scidocs", "fiqa", "quora", "nq", "hotpotqa", "fever")


def run(n_records: int = 2000, n_queries: int = 50, k: int = 10):
    for name in DATASETS:
        ds = scaled_beir(name, n_records=n_records, n_queries=n_queries)
        cost = EdgeCostModel()
        flat = FlatIndex(ds.embeddings.shape[1], cost)
        flat.add(ds.embeddings, ds.chunk_ids)
        er = EdgeRAGIndex(ds.embeddings.shape[1], ds.embedder, ds.get_chunks,
                          cost, slo_s=1.5)
        nlist = max(32, ds.n // 32)
        er.build(ds.chunk_ids, ds.texts, nlist=nlist,
                 embeddings=ds.embeddings)
        flat_ids = [flat.search(ds.query_embs[qi], k)[0][0].tolist()
                    for qi in range(n_queries)]

        def overlap_at(nprobe):
            return float(np.mean([
                len(set(flat_ids[qi])
                    & set(er.search(ds.query_embs[qi], k, nprobe)[0][0]
                          .tolist())) / k for qi in range(n_queries)]))

        # §6.2 methodology: raise nprobe until recall is normalized vs Flat
        chosen, ov = None, 0.0
        for nprobe in (4, 8, 16, 32, 64, nlist):
            ov = overlap_at(nprobe)
            chosen = nprobe
            if ov >= 0.95:
                break
        emit(f"fig11/{name}/context_overlap_vs_flat", 0.0,
             f"overlap={ov:.3f};within_5pct={ov >= 0.95};nprobe={chosen}")


if __name__ == "__main__":
    run()
